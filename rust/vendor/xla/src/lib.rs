//! Compile-compatible stub of the PJRT/XLA binding `flowrs` links against
//! when built with `--features xla`.
//!
//! The real binding (PJRT CPU client over the XLA C API) is a native
//! artifact that cannot live in this source tree; this stub keeps the
//! exact API surface `flowrs::runtime` uses so the feature always
//! compiles, and fails loudly at runtime (`PjRtClient::cpu` errors, so
//! `Runtime::load` reports the stub before any work is attempted).
//! Replace this directory with the real vendored binding to execute AOT
//! artifacts.

#![allow(dead_code)]

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this is the xla stub — vendor the real PJRT binding in rust/vendor/xla"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

pub struct Literal(());

impl Literal {
    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtDevice(());

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}
