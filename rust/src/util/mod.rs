//! In-tree substrates that would normally be external crates.
//!
//! The build is fully offline (only the `xla` PJRT binding is vendored), so
//! the pieces a typical project pulls from crates.io are implemented here:
//!
//! * [`bytes`] — shared little-endian codec primitives (the wire
//!   protocol, the checkpoint container and transport framing all
//!   build on these).
//! * [`json`] — a strict JSON parser/writer (for `artifacts/manifest.json`
//!   and experiment configs).
//! * [`rng`] — a deterministic xoshiro256++ PRNG with normal sampling
//!   (dataset synthesis, client sampling, property tests).
//! * [`bench`] — a micro-benchmark harness (criterion stand-in) used by
//!   `rust/benches/*`.
//! * [`prop`] — a tiny property-testing driver (proptest stand-in) used by
//!   `rust/tests/proptests.rs`.
//! * [`par`] — the process-wide `--workers` knob and a deterministic
//!   scoped fan-out helper (rayon stand-in) used by the engine, the
//!   selection policies and the aggregation fold.

pub mod bench;
pub mod bytes;
pub mod f16;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
