//! Shared little-endian byte codec primitives.
//!
//! Three subsystems speak hand-rolled little-endian byte formats: the
//! wire protocol ([`crate::proto::codec`]), the checkpoint container
//! ([`crate::persist`]) and transport framing
//! ([`crate::transport::frame`]). Each used to carry its own copy of
//! the same `to_le_bytes` / `from_le_bytes` plumbing with slightly
//! different bounds-check error types. This module is the single
//! implementation all three now build on:
//!
//! * [`LeWriter`] — an append-only little-endian byte sink.
//! * [`LeReader`] — a bounds-checked cursor, parameterized over the
//!   error *constructor* (`Error::Codec` for the wire,
//!   `Error::Persist` for checkpoints, `Error::Transport` for frames)
//!   so each layer keeps its own error category without duplicating
//!   the primitives.
//!
//! Floats are raw IEEE-754 bits in both directions (`f64::to_le_bytes`
//! *is* `to_bits().to_le_bytes()`), so round-trips are exact, NaN
//! payloads included. The encodings are pinned byte-for-byte by golden
//! vectors below and by differential property tests against the
//! pre-refactor hand-rolled encoders in `rust/tests/proptests.rs` and
//! the `proto`/`persist` unit tests.
#![deny(missing_docs)]

use crate::error::{Error, Result};

/// Append-only little-endian byte sink. A thin, inline-friendly layer
/// over `Vec<u8>` — the value is that every producer goes through one
/// implementation, so the byte order and float representation cannot
/// drift between subsystems.
#[derive(Debug, Default)]
pub struct LeWriter {
    buf: Vec<u8>,
}

impl LeWriter {
    /// An empty writer.
    pub fn new() -> Self {
        LeWriter { buf: Vec::new() }
    }

    /// An empty writer with `capacity` bytes preallocated.
    pub fn with_capacity(capacity: usize) -> Self {
        LeWriter { buf: Vec::with_capacity(capacity) }
    }

    /// The bytes written so far.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Number of bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Reserve room for at least `additional` more bytes (bulk loops).
    pub fn reserve(&mut self, additional: usize) {
        self.buf.reserve(additional);
    }

    /// Consume the writer, yielding its buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append raw bytes verbatim.
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`, little-endian.
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `i64`, little-endian two's complement.
    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32` as its raw IEEE-754 bits, little-endian.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its raw IEEE-754 bits, little-endian.
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked little-endian cursor over a byte slice. Every
/// accessor fails through the error constructor the owning layer
/// supplied instead of panicking, so corrupt input degrades to that
/// layer's own clean error category.
pub struct LeReader<'a> {
    buf: &'a [u8],
    pos: usize,
    mk_err: fn(String) -> Error,
}

impl<'a> LeReader<'a> {
    /// A cursor at the start of `buf`; `mk_err` wraps failure messages
    /// (e.g. `Error::Codec`, `Error::Persist`, `Error::Transport`).
    pub fn new(buf: &'a [u8], mk_err: fn(String) -> Error) -> Self {
        LeReader { buf, pos: 0, mk_err }
    }

    /// Current offset from the start of the buffer.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take the next `n` bytes, or fail with a truncation error.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                (self.mk_err)(format!(
                    "truncated input: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len().saturating_sub(self.pos)
                ))
            })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Read a little-endian two's-complement `i64`.
    pub fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    /// Read an `f32` from its raw IEEE-754 bits.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Read an `f64` from its raw IEEE-754 bits.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Fail unless the cursor consumed the whole buffer; `what` names
    /// the payload for the error message ("message", "checkpoint
    /// payload", ...).
    pub fn expect_end(&self, what: &str) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err((self.mk_err)(format!(
                "{} trailing bytes after {what}",
                self.buf.len() - self.pos
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden vectors: the little-endian encodings are pinned
    /// byte-for-byte, independent of any consumer.
    #[test]
    fn writer_encodings_are_pinned() {
        let mut w = LeWriter::new();
        w.u8(0xAB);
        w.u16(0xF10E);
        w.u32(0x0102_0304);
        w.u64(0x1122_3344_5566_7788);
        w.i64(-2);
        w.f32(1.0);
        w.f64(1.5);
        w.raw(b"ok");
        assert_eq!(
            w.into_bytes(),
            vec![
                0xAB, // u8
                0x0E, 0xF1, // u16
                0x04, 0x03, 0x02, 0x01, // u32
                0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // u64
                0xFE, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, // i64 -2
                0x00, 0x00, 0x80, 0x3F, // f32 1.0
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF8, 0x3F, // f64 1.5
                b'o', b'k',
            ]
        );
    }

    #[test]
    fn reader_roundtrips_and_bounds_checks() {
        let mut w = LeWriter::with_capacity(64);
        w.u8(7);
        w.u16(513);
        w.u32(70_000);
        w.u64(1 << 40);
        w.i64(-1234);
        w.f32(-0.5);
        w.f64(f64::from_bits(0x7FF8_0000_0000_0001)); // NaN payload
        let bytes = w.into_bytes();
        let mut r = LeReader::new(&bytes, Error::Codec);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 513);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i64().unwrap(), -1234);
        assert_eq!(r.f32().unwrap(), -0.5);
        assert_eq!(r.f64().unwrap().to_bits(), 0x7FF8_0000_0000_0001);
        r.expect_end("test payload").unwrap();
        // reading past the end fails through the supplied constructor
        let err = r.u8().unwrap_err();
        assert!(matches!(err, Error::Codec(_)), "got {err:?}");
        let mut p = LeReader::new(&bytes[..3], Error::Persist);
        assert!(matches!(p.u32().unwrap_err(), Error::Persist(_)));
        // trailing bytes are reported, not ignored
        let mut t = LeReader::new(&bytes, Error::Transport);
        t.u8().unwrap();
        let err = t.expect_end("frame").unwrap_err();
        assert!(err.to_string().contains("trailing bytes after frame"));
    }

    #[test]
    fn take_overflow_is_an_error_not_a_panic() {
        let mut r = LeReader::new(&[1, 2, 3], Error::Codec);
        r.u8().unwrap();
        assert!(r.take(usize::MAX).is_err());
        assert_eq!(r.remaining(), 2);
        assert_eq!(r.pos(), 1);
    }
}
