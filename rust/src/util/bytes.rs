//! Shared little-endian byte codec primitives.
//!
//! Three subsystems speak hand-rolled little-endian byte formats: the
//! wire protocol ([`crate::proto::codec`]), the checkpoint container
//! ([`crate::persist`]) and transport framing
//! ([`crate::transport::frame`]). Each used to carry its own copy of
//! the same `to_le_bytes` / `from_le_bytes` plumbing with slightly
//! different bounds-check error types. This module is the single
//! implementation all three now build on:
//!
//! * [`LeWriter`] — an append-only little-endian byte sink.
//! * [`LeReader`] — a bounds-checked cursor, parameterized over the
//!   error *constructor* (`Error::Codec` for the wire,
//!   `Error::Persist` for checkpoints, `Error::Transport` for frames)
//!   so each layer keeps its own error category without duplicating
//!   the primitives.
//!
//! Floats are raw IEEE-754 bits in both directions (`f64::to_le_bytes`
//! *is* `to_bits().to_le_bytes()`), so round-trips are exact, NaN
//! payloads included. The encodings are pinned byte-for-byte by golden
//! vectors below and by differential property tests against the
//! pre-refactor hand-rolled encoders in `rust/tests/proptests.rs` and
//! the `proto`/`persist` unit tests.
#![deny(missing_docs)]

use std::sync::Arc;

use crate::error::{Error, Result};

/// A reference-counted, immutable byte buffer holding one received
/// frame payload.
///
/// The zero-copy wire path ([`crate::proto`] v2 frames) decodes tensor
/// data as slices *borrowed out of this buffer* instead of copying into
/// owned `Vec`s, so the buffer must outlive every decoded view — hence
/// the `Arc`. Cloning a `FrameBuf` is a refcount bump, never a byte
/// copy.
///
/// Alignment contract: `Vec<u8>`'s allocation is not *guaranteed* to be
/// 4-byte aligned, although every mainstream allocator returns at least
/// word alignment for heap blocks. Consumers that reinterpret regions
/// of the buffer as `&[f32]` must therefore go through
/// [`FrameBuf::f32_region`], which checks the actual pointer alignment
/// at runtime and reports misalignment so the caller can fall back to a
/// copying path. Correctness never depends on the allocator's choice;
/// only the zero-copy fast path does.
#[derive(Debug, Clone)]
pub struct FrameBuf {
    buf: Arc<Vec<u8>>,
}

impl FrameBuf {
    /// Wrap an owned payload. The `Vec` is moved, not copied.
    pub fn new(bytes: Vec<u8>) -> Self {
        FrameBuf { buf: Arc::new(bytes) }
    }

    /// The whole payload.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Payload length in bytes.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether the payload is empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The shared allocation itself (for views that must hold the
    /// buffer alive past this `FrameBuf` handle).
    pub fn shared(&self) -> Arc<Vec<u8>> {
        Arc::clone(&self.buf)
    }

    /// Reinterpret `len_bytes` bytes at `byte_off` as a `&[f32]`
    /// without copying.
    ///
    /// Returns `None` when the region is out of bounds, its length is
    /// not a multiple of 4, or the region's *actual address* is not
    /// 4-byte aligned (the documented copy-fallback trigger). On
    /// success the cast is sound: the region is in bounds, properly
    /// aligned, and `f32` has no invalid bit patterns.
    pub fn f32_region(&self, byte_off: usize, len_bytes: usize) -> Option<&[f32]> {
        let end = byte_off.checked_add(len_bytes)?;
        if end > self.buf.len() || len_bytes % 4 != 0 {
            return None;
        }
        let region = &self.buf[byte_off..end];
        if region.as_ptr().align_offset(std::mem::align_of::<f32>()) != 0 {
            return None;
        }
        // SAFETY: bounds and 4-byte alignment checked above; f32 accepts
        // every bit pattern; the slice borrows self, so the Arc'd
        // allocation outlives it.
        Some(unsafe {
            std::slice::from_raw_parts(region.as_ptr() as *const f32, len_bytes / 4)
        })
    }
}

/// Append-only little-endian byte sink. A thin, inline-friendly layer
/// over `Vec<u8>` — the value is that every producer goes through one
/// implementation, so the byte order and float representation cannot
/// drift between subsystems.
#[derive(Debug, Default)]
pub struct LeWriter {
    buf: Vec<u8>,
}

impl LeWriter {
    /// An empty writer.
    pub fn new() -> Self {
        LeWriter { buf: Vec::new() }
    }

    /// An empty writer with `capacity` bytes preallocated.
    pub fn with_capacity(capacity: usize) -> Self {
        LeWriter { buf: Vec::with_capacity(capacity) }
    }

    /// The bytes written so far.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Number of bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Reserve room for at least `additional` more bytes (bulk loops).
    pub fn reserve(&mut self, additional: usize) {
        self.buf.reserve(additional);
    }

    /// Consume the writer, yielding its buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append raw bytes verbatim.
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Append one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`, little-endian.
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `i64`, little-endian two's complement.
    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32` as its raw IEEE-754 bits, little-endian.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its raw IEEE-754 bits, little-endian.
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked little-endian cursor over a byte slice. Every
/// accessor fails through the error constructor the owning layer
/// supplied instead of panicking, so corrupt input degrades to that
/// layer's own clean error category.
pub struct LeReader<'a> {
    buf: &'a [u8],
    pos: usize,
    mk_err: fn(String) -> Error,
}

impl<'a> LeReader<'a> {
    /// A cursor at the start of `buf`; `mk_err` wraps failure messages
    /// (e.g. `Error::Codec`, `Error::Persist`, `Error::Transport`).
    pub fn new(buf: &'a [u8], mk_err: fn(String) -> Error) -> Self {
        LeReader { buf, pos: 0, mk_err }
    }

    /// Current offset from the start of the buffer.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Take the next `n` bytes, or fail with a truncation error.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                (self.mk_err)(format!(
                    "truncated input: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len().saturating_sub(self.pos)
                ))
            })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u16`.
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Read a little-endian two's-complement `i64`.
    pub fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    /// Read an `f32` from its raw IEEE-754 bits.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// Read an `f64` from its raw IEEE-754 bits.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Fail unless the cursor consumed the whole buffer; `what` names
    /// the payload for the error message ("message", "checkpoint
    /// payload", ...).
    pub fn expect_end(&self, what: &str) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err((self.mk_err)(format!(
                "{} trailing bytes after {what}",
                self.buf.len() - self.pos
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden vectors: the little-endian encodings are pinned
    /// byte-for-byte, independent of any consumer.
    #[test]
    fn writer_encodings_are_pinned() {
        let mut w = LeWriter::new();
        w.u8(0xAB);
        w.u16(0xF10E);
        w.u32(0x0102_0304);
        w.u64(0x1122_3344_5566_7788);
        w.i64(-2);
        w.f32(1.0);
        w.f64(1.5);
        w.raw(b"ok");
        assert_eq!(
            w.into_bytes(),
            vec![
                0xAB, // u8
                0x0E, 0xF1, // u16
                0x04, 0x03, 0x02, 0x01, // u32
                0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // u64
                0xFE, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, // i64 -2
                0x00, 0x00, 0x80, 0x3F, // f32 1.0
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF8, 0x3F, // f64 1.5
                b'o', b'k',
            ]
        );
    }

    #[test]
    fn reader_roundtrips_and_bounds_checks() {
        let mut w = LeWriter::with_capacity(64);
        w.u8(7);
        w.u16(513);
        w.u32(70_000);
        w.u64(1 << 40);
        w.i64(-1234);
        w.f32(-0.5);
        w.f64(f64::from_bits(0x7FF8_0000_0000_0001)); // NaN payload
        let bytes = w.into_bytes();
        let mut r = LeReader::new(&bytes, Error::Codec);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 513);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i64().unwrap(), -1234);
        assert_eq!(r.f32().unwrap(), -0.5);
        assert_eq!(r.f64().unwrap().to_bits(), 0x7FF8_0000_0000_0001);
        r.expect_end("test payload").unwrap();
        // reading past the end fails through the supplied constructor
        let err = r.u8().unwrap_err();
        assert!(matches!(err, Error::Codec(_)), "got {err:?}");
        let mut p = LeReader::new(&bytes[..3], Error::Persist);
        assert!(matches!(p.u32().unwrap_err(), Error::Persist(_)));
        // trailing bytes are reported, not ignored
        let mut t = LeReader::new(&bytes, Error::Transport);
        t.u8().unwrap();
        let err = t.expect_end("frame").unwrap_err();
        assert!(err.to_string().contains("trailing bytes after frame"));
    }

    #[test]
    fn frame_buf_f32_region_zero_copy_and_bounds() {
        // 4 LE f32s at offset 0: the region IS the allocation start,
        // which every mainstream allocator aligns to >= 4 bytes.
        let mut w = LeWriter::new();
        for v in [1.0f32, -2.5, 0.0, 42.0] {
            w.f32(v);
        }
        let fb = FrameBuf::new(w.into_bytes());
        let base = fb.as_slice().as_ptr() as usize;
        if base % 4 == 0 {
            let view = fb.f32_region(0, 16).expect("aligned region");
            assert_eq!(view, &[1.0, -2.5, 0.0, 42.0]);
            // genuinely zero-copy: the slice points into the buffer
            assert_eq!(view.as_ptr() as usize, base);
            // an offset that breaks 4-alignment must refuse the cast
            assert!(fb.f32_region(1, 4).is_none());
        }
        // out of bounds / ragged lengths are None, never a panic
        assert!(fb.f32_region(0, 17).is_none());
        assert!(fb.f32_region(13, 4).is_none());
        assert!(fb.f32_region(usize::MAX, 4).is_none());
        assert!(fb.f32_region(0, 15).is_none());
        // clones share the allocation
        let c = fb.clone();
        assert_eq!(c.as_slice().as_ptr(), fb.as_slice().as_ptr());
        assert_eq!(fb.len(), 16);
        assert!(!fb.is_empty());
    }

    #[test]
    fn take_overflow_is_an_error_not_a_panic() {
        let mut r = LeReader::new(&[1, 2, 3], Error::Codec);
        r.u8().unwrap();
        assert!(r.take(usize::MAX).is_err());
        assert_eq!(r.remaining(), 2);
        assert_eq!(r.pos(), 1);
    }
}
