//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64, with uniform /
//! normal / shuffle / choice helpers. Every stochastic component of the
//! system (dataset synthesis, partitioning, client sampling, property
//! tests) goes through this so experiments are exactly reproducible from
//! a single seed.

/// xoshiro256++ (Blackman & Vigna). Not cryptographic — simulation-grade.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare_normal: Option<f64>,
}

/// A serializable snapshot of an [`Rng`]'s position in its stream
/// ([`Rng::state`] / [`Rng::restore`]). Restoring it resumes the exact
/// draw sequence — the primitive the checkpoint subsystem
/// ([`crate::persist`]) uses to make killed-and-resumed runs replay
/// bit-identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    /// xoshiro256++ state words.
    pub s: [u64; 4],
    /// Cached second Box–Muller sample, if one is pending.
    pub spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Snapshot the generator's exact position in its stream.
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator at a previously captured position: the
    /// restored generator produces exactly the draws the original would
    /// have produced next.
    pub fn restore(state: &RngState) -> Self {
        Rng { s: state.s, spare_normal: state.spare_normal }
    }

    /// Derive an independent stream, e.g. per client or per class.
    pub fn derive(&self, stream: u64) -> Rng {
        // Mix the stream id through splitmix so derived streams decorrelate.
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free for our simulation purposes:
        // 53 bits of uniformity is plenty for n << 2^32.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices({n}, {k})");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Sample from a Dirichlet(alpha * 1) distribution of dimension `dim`
    /// via Gamma(alpha) marginals (Marsaglia–Tsang for alpha >= 1, boosted
    /// for alpha < 1). Used by the non-IID partitioner.
    pub fn dirichlet(&mut self, alpha: f64, dim: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..dim).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // pathological; fall back to uniform
            return vec![1.0 / dim as f64; dim];
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        let mut c = Rng::seed_from(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn state_roundtrip_resumes_exact_stream() {
        let mut a = Rng::seed_from(42);
        for _ in 0..17 {
            a.next_u64();
        }
        a.normal(); // leaves a spare Box–Muller sample cached
        let snap = a.state();
        let ahead: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let spare_a = a.normal();
        let mut b = Rng::restore(&snap);
        let replay: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(ahead, replay);
        assert_eq!(spare_a.to_bits(), b.normal().to_bits());
    }

    #[test]
    fn derive_decorrelates() {
        let root = Rng::seed_from(1);
        let mut a = root.derive(0);
        let mut b = root.derive(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::seed_from(4);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::seed_from(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(6);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::BTreeSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from(7);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 10);
            let sum: f64 = d.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn dirichlet_concentration_shapes() {
        // small alpha -> spiky; large alpha -> near uniform
        let mut r = Rng::seed_from(8);
        let spiky: f64 = (0..50)
            .map(|_| {
                r.dirichlet(0.1, 10)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 50.0;
        let flat: f64 = (0..50)
            .map(|_| {
                r.dirichlet(100.0, 10)
                    .into_iter()
                    .fold(0.0f64, f64::max)
            })
            .sum::<f64>()
            / 50.0;
        assert!(spiky > 0.5, "spiky={spiky}");
        assert!(flat < 0.2, "flat={flat}");
    }
}
