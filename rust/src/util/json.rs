//! Strict JSON parser and writer (RFC 8259 subset sufficient for the
//! artifact manifest and experiment configs; no trailing commas, no
//! comments, full string-escape handling including `\uXXXX`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // -- typed accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Config(format!("missing key {key:?}"))),
            _ => Err(Error::Config(format!("expected object while reading {key:?}"))),
        }
    }

    /// `get` that tolerates absence.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            return Err(Error::Config(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(Error::Config(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Config(format!("expected object, got {other:?}"))),
        }
    }

    /// Array of usize, e.g. a tensor shape.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    // -- writer -----------------------------------------------------------

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("invalid utf8"))?;
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\nquote\"back\\slash\ttab\u{1F600}";
        let doc = Json::Str(s.into()).to_string();
        assert_eq!(Json::parse(&doc).unwrap(), Json::Str(s.into()));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse(r#"{"a":1} x"#).is_err());
        assert!(Json::parse(r#""\q""#).is_err());
        assert!(Json::parse("01e").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "x", "b": false, "shape": [2, 3]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x");
        assert!(!v.get("b").unwrap().as_bool().unwrap());
        assert_eq!(v.get("shape").unwrap().as_usize_vec().unwrap(), vec![2, 3]);
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn writer_roundtrips_manifest_like_doc() {
        let text = r#"{"version": 1, "models": {"m": {"shape": [1, 2], "lr": 0.05}}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}
