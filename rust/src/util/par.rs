//! Process-wide worker pool knob + deterministic scoped fan-out.
//!
//! Parallelism in this crate is a pure *execution* detail: every sharded
//! computation is specified as "what the sequential loop computes", and the
//! shards are constructed so that merging them in shard order reproduces the
//! sequential result bit-for-bit. The worker count therefore never appears in
//! run fingerprints, checkpoints decide nothing based on it, and a run is
//! free to change `--workers` between kill and resume.
//!
//! The knob is process-global (an [`AtomicUsize`]) rather than threaded
//! through every call site because the hot paths it accelerates — the engine
//! round scan, policy candidate partitioning, and the weighted-average fold —
//! sit below long-stable public signatures (`SelectionPolicy::select`,
//! `Aggregator::weighted_average`) that many tests and benches construct
//! directly.
//!
//! [`run_sharded`] deliberately spawns plain [`std::thread::scope`] threads
//! per call instead of keeping a pool: every use site runs O(population) or
//! O(params) work per shard, so spawn cost is noise, and scoped threads let
//! shards borrow the caller's slices without `Arc` plumbing.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide worker count. Defaults to 1 (fully sequential) so that
/// library users and tests that never touch the knob get the exact
/// historical single-threaded behavior.
static WORKERS: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide worker count (clamped to at least 1).
///
/// Called once at startup from the CLI (`--workers N`) and by
/// `Engine::new` from `ScheduleConfig::workers`; safe to call again — the
/// value only steers how future [`run_sharded`] calls split work, never
/// what they compute.
pub fn set_workers(n: usize) {
    WORKERS.store(n.max(1), Ordering::Relaxed);
}

/// Current process-wide worker count (at least 1).
pub fn workers() -> usize {
    WORKERS.load(Ordering::Relaxed).max(1)
}

/// Run `f(0..shards)` and return the results **in shard order**.
///
/// With one shard this is a plain call on the current thread (no spawn), so
/// `workers == 1` is exactly the sequential code path. With more, each shard
/// runs on its own scoped thread; joins happen in shard index order, so the
/// returned `Vec` is ordered by shard no matter how the OS scheduled them.
/// A panic in any shard propagates to the caller.
pub fn run_sharded<R, F>(shards: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let shards = shards.max(1);
    if shards == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..shards)
            .map(|i| {
                let fr = &f;
                s.spawn(move || fr(i))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard worker panicked"))
            .collect()
    })
}

/// Split `0..len` into `shards` contiguous ranges `(lo, hi)` that cover it
/// in order. Range boundaries depend only on `(len, shards)` — never on
/// thread scheduling — and the first `len % shards` ranges are one longer.
/// Empty ranges are returned (not skipped) so shard index always equals
/// position, which keeps merges positional.
pub fn shard_ranges(len: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1);
    let base = len / shards;
    let rem = len % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0usize;
    for i in 0..shards {
        let size = base + usize::from(i < rem);
        out.push((lo, lo + size));
        lo += size;
    }
    debug_assert_eq!(lo, len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_in_order() {
        for len in [0usize, 1, 7, 8, 9, 1000] {
            for shards in [1usize, 2, 3, 8, 16] {
                let ranges = shard_ranges(len, shards);
                assert_eq!(ranges.len(), shards);
                let mut expect = 0usize;
                for &(lo, hi) in &ranges {
                    assert_eq!(lo, expect);
                    assert!(hi >= lo);
                    expect = hi;
                }
                assert_eq!(expect, len);
                // balanced: sizes differ by at most one
                let sizes: Vec<usize> = ranges.iter().map(|&(a, b)| b - a).collect();
                let min = sizes.iter().min().unwrap();
                let max = sizes.iter().max().unwrap();
                assert!(max - min <= 1, "unbalanced split {sizes:?}");
            }
        }
    }

    #[test]
    fn run_sharded_returns_in_shard_order() {
        for shards in [1usize, 2, 4, 8] {
            let got = run_sharded(shards, |i| i * 10);
            let want: Vec<usize> = (0..shards).map(|i| i * 10).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn workers_knob_clamps_to_one() {
        // Other tests run concurrently in this process; only exercise the
        // clamp through a save/restore so we don't perturb them.
        let before = workers();
        set_workers(0);
        assert_eq!(workers(), 1);
        set_workers(before);
        assert_eq!(workers(), before);
    }
}
