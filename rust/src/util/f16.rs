//! IEEE 754 binary16 (half-precision) conversions, implemented in-tree
//! (offline build — no `half` crate).
//!
//! Used by the communication-compression path: model parameters are
//! quantized to f16 on the wire, halving the paper's per-round payload
//! (the dominant communication cost at FL scale). Round-to-nearest-even,
//! correct subnormal/inf/nan handling both ways.

/// Convert an f32 to its binary16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // re-bias: f32 bias 127, f16 bias 15
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // normal f16
        let half_exp = ((unbiased + 15) as u16) << 10;
        let half_mant = (mant >> 13) as u16;
        // round-to-nearest-even on the 13 dropped bits
        let round_bits = mant & 0x1FFF;
        let halfway = 0x1000;
        let mut out = sign | half_exp | half_mant;
        if round_bits > halfway || (round_bits == halfway && (half_mant & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: correct (next binade)
        }
        return out;
    }
    if unbiased >= -25 {
        // subnormal f16: implicit leading 1 becomes explicit
        let full_mant = mant | 0x0080_0000;
        let shift = (-14 - unbiased) + 13;
        let half_mant = (full_mant >> shift) as u16;
        let round_bits = full_mant & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut out = sign | half_mant;
        if round_bits > halfway || (round_bits == halfway && (half_mant & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow -> signed zero
}

/// Convert a binary16 bit pattern to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign, // signed zero
        (0, m) => {
            // subnormal: value = m × 2^-24; normalize around the top set bit
            let p = 31 - m.leading_zeros(); // 0..=9
            let exp32 = 103 + p; // 127 - 24 + p
            let mant32 = (m & !(1u32 << p)) << (23 - p);
            sign | (exp32 << 23) | mant32
        }
        (0x1F, 0) => sign | 0x7F80_0000,            // inf
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13), // nan
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Quantize a slice.
pub fn quantize(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16_bits(x)).collect()
}

/// Dequantize a slice.
pub fn dequantize(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| f16_bits_to_f32(h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25] {
            let h = f32_to_f16_bits(x);
            assert_eq!(f16_bits_to_f32(h), x, "x={x}");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x0001), 5.960_464_5e-8); // smallest subnormal
    }

    #[test]
    fn overflow_and_underflow() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00); // -> inf
        assert_eq!(f32_to_f16_bits(-1e6), 0xFC00);
        assert_eq!(f32_to_f16_bits(1e-9), 0x0000); // -> +0
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000); // -> -0
    }

    #[test]
    fn nan_propagates() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn subnormals_roundtrip() {
        // all f16 subnormals are exact in f32
        for bits in 1u16..0x0400 {
            let x = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(x), bits, "bits={bits:#06x}");
        }
    }

    #[test]
    fn all_f16_normals_roundtrip() {
        // every finite f16 is exactly representable in f32: f16->f32->f16 is identity
        for bits in 0u16..=0xFFFF {
            let exp = (bits >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/nan handled elsewhere
            }
            let x = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(x), bits, "bits={bits:#06x} x={x}");
        }
    }

    #[test]
    fn quantization_error_bounded() {
        let mut rng = crate::util::rng::Rng::seed_from(0);
        for _ in 0..10_000 {
            let x = (rng.normal() * 2.0) as f32;
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            // relative error bound for f16 normals: 2^-11
            assert!(
                (back - x).abs() <= x.abs() * 4.9e-4 + 6e-8,
                "x={x} back={back}"
            );
        }
    }

    #[test]
    fn slice_helpers() {
        let xs = vec![0.1f32, -0.2, 3.5];
        let back = dequantize(&quantize(&xs));
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
