//! Micro-benchmark harness (criterion stand-in, offline build).
//!
//! Usage in a `[[bench]] harness = false` target:
//! ```ignore
//! let mut b = Bench::new("codec");
//! b.bench("encode_fit_ins_137k", || encode(...));
//! b.finish();
//! ```
//! Prints `name  median  mean  p95  iters` rows and returns the stats so
//! the bench binaries can assert regressions or dump CSV.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result statistics for one benchmark case (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
}

impl Stats {
    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:8.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:8.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:8.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:8.3} s ", ns / 1_000_000_000.0)
    }
}

/// A group of benchmark cases sharing a target measurement time.
pub struct Bench {
    group: String,
    /// wall-clock budget per case
    pub target: Duration,
    /// minimum sample count per case
    pub min_samples: usize,
    /// `--test` smoke mode (criterion convention): run every case once
    /// to prove it still executes, skip the measurement loop. CI uses
    /// `cargo bench --bench <name> -- --test` so bench targets can't
    /// bit-rot without burning bench time.
    pub test_mode: bool,
    pub results: Vec<Stats>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let test_mode = std::env::args().any(|a| a == "--test");
        if test_mode {
            println!("\n== bench group: {group} (test mode: 1 iter/case) ==");
        } else {
            println!("\n== bench group: {group} ==");
            println!(
                "{:<44} {:>11} {:>11} {:>11} {:>8}",
                "case", "median", "mean", "p95", "iters"
            );
        }
        Bench {
            group: group.to_string(),
            target: Duration::from_millis(
                std::env::var("FLOWRS_BENCH_MS")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(400),
            ),
            min_samples: 10,
            test_mode,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which should return something to defeat DCE.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Stats {
        // Warmup + calibration: find iters-per-sample so one sample ~ 1ms.
        let t0 = Instant::now();
        black_box(f());
        if self.test_mode {
            let ns = t0.elapsed().as_nanos() as f64;
            let stats = Stats {
                name: format!("{}/{}", self.group, name),
                median_ns: ns,
                mean_ns: ns,
                p95_ns: ns,
                iters: 1,
            };
            println!("{:<44} ok ({})", stats.name, fmt_ns(ns).trim_start());
            self.results.push(stats);
            return self.results.last().unwrap();
        }
        let once = t0.elapsed().max(Duration::from_nanos(20));
        let per_sample = (Duration::from_millis(1).as_nanos() / once.as_nanos()).max(1) as u64;

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.target || samples.len() < self.min_samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                black_box(f());
            }
            let dt = t.elapsed().as_nanos() as f64 / per_sample as f64;
            samples.push(dt);
            total_iters += per_sample;
            if samples.len() >= 10_000 {
                break;
            }
        }
        let (median, mean, p95) = summarize(&mut samples);
        let stats = Stats {
            name: format!("{}/{}", self.group, name),
            median_ns: median,
            mean_ns: mean,
            p95_ns: p95,
            iters: total_iters,
        };
        println!(
            "{:<44} {:>11} {:>11} {:>11} {:>8}",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            stats.iters
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Throughput variant: also prints MB/s given bytes processed per iter.
    pub fn bench_bytes<T>(&mut self, name: &str, bytes: usize, f: impl FnMut() -> T) {
        let stats = self.bench(name, f).clone();
        let mbps = bytes as f64 / (stats.median_ns / 1e9) / 1e6;
        println!("{:<44} {:>10.1} MB/s", format!("  ({bytes} B/iter)"), mbps);
    }

    pub fn finish(self) -> Vec<Stats> {
        self.results
    }
}

/// Order statistics over one case's samples: `(median, mean, p95)`.
/// `total_cmp` keeps the sort total — a NaN sample (a degenerate timer
/// quotient, or caller-fed data) sorts to the tail instead of
/// panicking the whole bench run mid-sort, which is what the old
/// `partial_cmp(..).unwrap()` comparator did.
fn summarize(samples: &mut [f64]) -> (f64, f64, f64) {
    samples.sort_by(|a, b| a.total_cmp(b));
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
    (median, mean, samples[p95_idx])
}

/// Render bench results as the in-tree JSON baseline format (see
/// `rust/BENCH_selection.json`): one row per case with nanosecond
/// timings, plus metadata marking how the numbers were produced.
/// `note` carries the group's acceptance criterion so regenerating the
/// file never drops it from the tree. Baselines are machine-dependent —
/// regenerate on the target machine rather than comparing across hosts
/// (`mode` records whether the run was a real measurement or a `--test`
/// smoke).
pub fn results_to_json(group: &str, note: &str, results: &[Stats], test_mode: bool) -> String {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let rows: Vec<Json> = results
        .iter()
        .map(|s| {
            Json::Obj(BTreeMap::from([
                ("name".to_string(), Json::Str(s.name.clone())),
                ("median_ns".to_string(), Json::Num(s.median_ns)),
                ("mean_ns".to_string(), Json::Num(s.mean_ns)),
                ("p95_ns".to_string(), Json::Num(s.p95_ns)),
                ("iters".to_string(), Json::Num(s.iters as f64)),
            ]))
        })
        .collect();
    Json::Obj(BTreeMap::from([
        ("group".to_string(), Json::Str(group.into())),
        (
            "mode".to_string(),
            Json::Str(if test_mode { "test" } else { "measure" }.into()),
        ),
        ("machine_dependent".to_string(), Json::Bool(true)),
        ("note".to_string(), Json::Str(note.into())),
        (
            "regenerate".to_string(),
            Json::Str(format!(
                "cd rust && cargo bench --bench {group} -- --json BENCH_{group}.json"
            )),
        ),
        ("results".to_string(), Json::Arr(rows)),
    ]))
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_orders_samples() {
        let mut s = vec![3.0, 1.0, 2.0, 5.0, 4.0];
        let (median, mean, p95) = summarize(&mut s);
        assert_eq!(median, 3.0);
        assert_eq!(mean, 3.0);
        assert_eq!(p95, 5.0);
    }

    /// Regression: one NaN sample used to panic the whole bench run in
    /// the `partial_cmp(..).unwrap()` sort comparator. `total_cmp`
    /// sorts NaN to the tail and the order stats stay finite wherever
    /// the index lands on a real sample.
    #[test]
    fn summarize_survives_nan_samples() {
        let mut s = vec![2.0, f64::NAN, 1.0, 3.0];
        let (median, _mean, p95) = summarize(&mut s);
        assert_eq!(&s[..3], &[1.0, 2.0, 3.0]);
        assert!(s[3].is_nan());
        assert_eq!(median, 3.0); // index len/2 = 2 of the sorted tail-NaN array
        assert!(p95.is_nan()); // the tail index is the NaN itself: visible, not a panic
    }
}
