//! Tiny property-testing driver (proptest stand-in, offline build).
//!
//! ```ignore
//! prop::check("codec roundtrip", 256, |rng| {
//!     let msg = arbitrary_message(rng);
//!     let buf = encode(&msg);
//!     prop::assert_eq_prop(&decode(&buf)?, &msg)
//! });
//! ```
//! Each case gets a fresh RNG derived from a base seed; on failure the
//! driver panics with the case index and seed so the exact input can be
//! replayed with `FLOWRS_PROP_SEED`.

use super::rng::Rng;

/// Outcome of one property evaluation.
pub type PropResult = std::result::Result<(), String>;

/// Run `cases` random cases of `prop`. Panics on the first failure with
/// enough information to replay it deterministically.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    let base_seed: u64 = std::env::var("FLOWRS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xF10E_2026);
    let root = Rng::seed_from(base_seed);
    for case in 0..cases {
        let mut rng = root.derive(case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed at case {case} \
                 (replay with FLOWRS_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// Assert helper returning a `PropResult`.
pub fn ensure(cond: bool, msg: impl FnOnce() -> String) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

/// Equality helper with debug formatting.
pub fn assert_eq_prop<T: PartialEq + std::fmt::Debug>(got: &T, want: &T) -> PropResult {
    if got == want {
        Ok(())
    } else {
        Err(format!("mismatch:\n  got:  {got:?}\n  want: {want:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 32, |rng| {
            count += 1;
            ensure(rng.below(10) < 10, || "impossible".into())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_panics_with_case() {
        check("always fails", 8, |_| Err("nope".into()));
    }
}
