//! `bench_ablations` — ablation studies over the design space DESIGN.md
//! calls out (extension experiments Ext-1..Ext-4):
//!
//! 1. **Wire compression** — f32 vs f16 parameter exchange: bytes moved,
//!    modeled comm time/energy, accuracy delta.
//! 2. **Data heterogeneity** — IID vs Dirichlet(0.5) vs Dirichlet(0.1) vs
//!    2-shard splits, FedAvg vs FedProx.
//! 3. **Dropout resilience** — accuracy vs client failure probability.
//! 4. **Aggregation backend** — Rust loop vs Pallas/PJRT kernel agreement
//!    and round-level throughput.
//!
//! All on the fast head-model workload so the whole suite stays a few
//! minutes of wallclock.
//!
//! ```bash
//! cargo run --release --bin bench_ablations
//! ```

use flowrs::config::{AggBackend, ExperimentConfig, StrategyConfig};
use flowrs::data::Partitioner;
use flowrs::metrics::Table;
use flowrs::runtime::Runtime;
use flowrs::sim;

fn base(name: &str) -> ExperimentConfig {
    ExperimentConfig::default()
        .named(name)
        .model("head")
        .clients(4)
        .rounds(5)
        .epochs(2)
        .lr(0.1)
        .data(128, 100)
        .seed(20260710)
}

fn main() -> flowrs::Result<()> {
    let runtime = Runtime::load_default()?;
    let t0 = std::time::Instant::now();

    // --- Ext-1: wire compression ---------------------------------------
    let mut t = Table::new(
        "Ext-1: f16 wire compression (head, C=4, E=2, 5 rounds)",
        &["wire", "accuracy", "fit MB moved", "comm time (s)", "energy (kJ)"],
    );
    for (label, quant) in [("f32", false), ("f16", true)] {
        let cfg = base(&format!("abl_quant_{label}")).quantized(quant);
        let r = sim::run_experiment(&cfg, &runtime)?;
        let mb: f64 = r
            .history
            .rounds
            .iter()
            .map(|x| (x.down_bytes + x.up_bytes) as f64)
            .sum::<f64>()
            / 1e6;
        let (acc, mins, kj) = r.paper_metrics();
        t.row(vec![
            label.into(),
            format!("{acc:.4}"),
            format!("{mb:.2}"),
            format!("{:.1}", mins * 60.0),
            format!("{kj:.3}"),
        ]);
    }
    print!("{}", t.render());

    // --- Ext-2/3: heterogeneity × strategy -------------------------------
    let mut t = Table::new(
        "Ext-2: data heterogeneity x strategy (head, C=4, E=2, 5 rounds)",
        &["partition", "strategy", "accuracy", "eval loss"],
    );
    let partitions: Vec<(&str, Partitioner)> = vec![
        ("iid", Partitioner::Iid),
        ("dirichlet:0.5", Partitioner::Dirichlet { alpha: 0.5 }),
        ("dirichlet:0.1", Partitioner::Dirichlet { alpha: 0.1 }),
        ("shards:2", Partitioner::Shards { shards_per_client: 2 }),
    ];
    for (plabel, partitioner) in &partitions {
        for (slabel, strategy) in [
            ("fedavg", StrategyConfig::FedAvg),
            ("fedprox(0.1)", StrategyConfig::FedProx { mu: 0.1 }),
        ] {
            let cfg = base(&format!("abl_{plabel}_{slabel}"))
                .partitioner(partitioner.clone())
                .strategy(strategy);
            let r = sim::run_experiment(&cfg, &runtime)?;
            let last = r.history.rounds.last().unwrap();
            t.row(vec![
                plabel.to_string(),
                slabel.into(),
                format!("{:.4}", last.accuracy),
                format!("{:.4}", last.eval_loss),
            ]);
        }
    }
    print!("{}", t.render());

    // --- Ext-3: dropout resilience ---------------------------------------
    let mut t = Table::new(
        "Ext-3: client dropout resilience (head, C=4, E=2, 5 rounds)",
        &["dropout", "accuracy", "completed fits", "failures"],
    );
    for p in [0.0, 0.2, 0.4] {
        let cfg = base(&format!("abl_drop_{p}")).dropout(p);
        let r = sim::run_experiment(&cfg, &runtime)?;
        let done: usize = r.history.rounds.iter().map(|x| x.fit_completed).sum();
        let fail: usize = r.history.rounds.iter().map(|x| x.fit_failures).sum();
        t.row(vec![
            format!("{p:.1}"),
            format!("{:.4}", r.history.final_accuracy()),
            done.to_string(),
            fail.to_string(),
        ]);
    }
    print!("{}", t.render());

    // --- Ext-4: aggregation backend ---------------------------------------
    let mut t = Table::new(
        "Ext-4: aggregation backend (head, C=4, E=2, 5 rounds)",
        &["backend", "accuracy", "eval loss", "wallclock (s)"],
    );
    for (label, backend) in [("rust", AggBackend::Rust), ("pjrt", AggBackend::Pjrt)] {
        let cfg = base(&format!("abl_agg_{label}")).agg(backend);
        let w0 = std::time::Instant::now();
        let r = sim::run_experiment(&cfg, &runtime)?;
        let wall = w0.elapsed().as_secs_f64();
        let last = r.history.rounds.last().unwrap();
        t.row(vec![
            label.into(),
            format!("{:.4}", last.accuracy),
            format!("{:.4}", last.eval_loss),
            format!("{wall:.1}"),
        ]);
    }
    print!("{}", t.render());

    println!("\nablations total: {:.1}s wallclock", t0.elapsed().as_secs_f64());
    Ok(())
}
