//! `bench_tables` — regenerate every table in the paper's evaluation.
//!
//! * **Table 2a** — Jetson TX2, CIFAR, C=10, E ∈ {1, 5, 10}: accuracy /
//!   convergence time / energy.
//! * **Table 2b** — Android device-farm, head model, E=5, C ∈ {4, 7, 10}.
//! * **Table 3**  — TX2 GPU vs CPU, E=10, CPU with τ cutoffs.
//!
//! Numbers are produced by the full stack (real PJRT training, modeled
//! device costs). Absolute values depend on the synthetic-data difficulty
//! and the calibrated cost model (DESIGN.md §6); the *shape* — who wins,
//! by what factor, where the trade-offs fall — is the reproduction target.
//! The paper's own numbers are printed alongside for comparison.
//!
//! ```bash
//! cargo run --release --bin bench_tables -- --table all
//! cargo run --release --bin bench_tables -- --table 2a --rounds 40   # paper-scale
//! cargo run --release --bin bench_tables -- --quick                  # CI smoke
//! ```

use std::path::Path;

use flowrs::config::{ExperimentConfig, StrategyConfig};
use flowrs::metrics::{write_report, Table};
use flowrs::runtime::Runtime;
use flowrs::sim::{self, SimReport};
use flowrs::telemetry::log;

struct Opts {
    table: String,
    rounds_2a: u64,
    rounds_2b: u64,
    rounds_3: u64,
    out_dir: String,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts {
        table: "all".into(),
        rounds_2a: 12,
        rounds_2b: 8,
        rounds_3: 8,
        out_dir: "reports".into(),
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--table" => {
                opts.table = args[i + 1].clone();
                i += 2;
            }
            "--rounds" => {
                let r: u64 = args[i + 1].parse().expect("--rounds wants a number");
                opts.rounds_2a = r;
                opts.rounds_2b = r;
                opts.rounds_3 = r;
                i += 2;
            }
            "--out-dir" => {
                opts.out_dir = args[i + 1].clone();
                i += 2;
            }
            "--quick" => {
                opts.rounds_2a = 2;
                opts.rounds_2b = 2;
                opts.rounds_3 = 2;
                i += 1;
            }
            other => panic!("unknown flag {other:?}"),
        }
    }
    opts
}

fn main() -> flowrs::Result<()> {
    let opts = parse_opts();
    let runtime = Runtime::load_default()?;
    let t0 = std::time::Instant::now();
    match opts.table.as_str() {
        "2a" => table_2a(&runtime, &opts)?,
        "2b" => table_2b(&runtime, &opts)?,
        "3" => table_3(&runtime, &opts)?,
        "all" => {
            table_2a(&runtime, &opts)?;
            table_2b(&runtime, &opts)?;
            table_3(&runtime, &opts)?;
        }
        other => panic!("unknown table {other:?} (2a | 2b | 3 | all)"),
    }
    println!(
        "\ntotal: {:.1}s wallclock, {} PJRT executions",
        t0.elapsed().as_secs_f64(),
        runtime.executions()
    );
    Ok(())
}

/// Shared base config for the Jetson CIFAR workload.
fn cifar_base(rounds: u64) -> ExperimentConfig {
    ExperimentConfig::default()
        .model("cifar_cnn")
        .clients(10)
        .rounds(rounds)
        .lr(0.065)
        .data(256, 100) // 8 steps/epoch at batch 32 — matches the cost calibration
        .seed(20260710)
}

fn save(report: &SimReport, out_dir: &str, name: &str) {
    let path = format!("{out_dir}/{name}.csv");
    if let Err(e) = write_report(Path::new(&path), &report.history.to_csv()) {
        log::warn(&format!("could not write {path}: {e}"));
    }
}

fn table_2a(runtime: &Runtime, opts: &Opts) -> flowrs::Result<()> {
    println!("\n=== Table 2a: TX2 CIFAR, C=10, varying local epochs E ===");
    println!(
        "(paper @ 40 rounds: E=1 -> 0.48 / 17.63 min / 10.21 kJ; \
         E=5 -> 0.64 / 36.83 / 50.54; E=10 -> 0.67 / 80.32 / 100.95)"
    );
    let mut table = Table::new(
        &format!(
            "Table 2a reproduction — C=10 TX2-GPU clients, {} rounds",
            opts.rounds_2a
        ),
        &["Local Epochs (E)", "Accuracy", "Time (min)", "Energy (kJ)"],
    );
    for e in [1i64, 5, 10] {
        let cfg = cifar_base(opts.rounds_2a)
            .named(&format!("table2a_e{e}"))
            .epochs(e)
            .devices(&["jetson_tx2_gpu"]);
        let report = sim::run_experiment(&cfg, runtime)?;
        save(&report, &opts.out_dir, &format!("table2a_e{e}"));
        table.row(flowrs::metrics::paper_row(&e.to_string(), &report));
    }
    print!("{}", table.render());
    println!("shape check: accuracy, time and energy must all rise with E.");
    Ok(())
}

fn table_2b(runtime: &Runtime, opts: &Opts) -> flowrs::Result<()> {
    println!("\n=== Table 2b: Android head model, E=5, varying cohort size C ===");
    println!(
        "(paper @ 20 rounds: C=4 -> 0.84 / 30.7 min / 10.4 kJ; \
         C=7 -> 0.85 / 31.3 / 19.72; C=10 -> 0.87 / 31.8 / 28.0)"
    );
    let mut table = Table::new(
        &format!(
            "Table 2b reproduction — AWS phone mix, E=5, {} rounds",
            opts.rounds_2b
        ),
        &["Clients (C)", "Accuracy", "Time (min)", "Energy (kJ)"],
    );
    for c in [4usize, 7, 10] {
        let cfg = ExperimentConfig::default()
            .named(&format!("table2b_c{c}"))
            .model("head") // devices default to the AWS farm
            .clients(c)
            .rounds(opts.rounds_2b)
            .epochs(5)
            .lr(0.1)
            .data(160, 100)
            .seed(20260710);
        let report = sim::run_experiment(&cfg, runtime)?;
        save(&report, &opts.out_dir, &format!("table2b_c{c}"));
        table.row(flowrs::metrics::paper_row(&c.to_string(), &report));
    }
    print!("{}", table.render());
    println!(
        "shape check: accuracy rises with C; time ~flat (same devices); energy ~linear in C."
    );
    Ok(())
}

fn table_3(runtime: &Runtime, opts: &Opts) -> flowrs::Result<()> {
    println!("\n=== Table 3: computational heterogeneity + tau cutoff, E=10 ===");
    println!(
        "(paper: GPU 0.67/80.32 min; CPU t=0 0.67/102 min (1.27x); \
         CPU t=2.23 0.66/89.15 (1.11x); CPU t=1.99 0.63/80.34 (1.0x))"
    );
    // τ per the paper: the GPU's per-round compute time (1.99 min at E=10,
    // 8 steps/epoch) becomes the CPU deadline; 2.23 min is the softer cut.
    let cost = flowrs::sim::cost::CostModel::default();
    let gpu = flowrs::device::profiles::by_name("jetson_tx2_gpu")?;
    let tau_tight = cost.compute(gpu, 10 * 8).time_s; // = GPU round compute
    let tau_loose = tau_tight * (2.23 / 1.99);

    let configs: Vec<(String, ExperimentConfig)> = vec![
        (
            "GPU (t=0)".into(),
            cifar_base(opts.rounds_3)
                .named("table3_gpu")
                .epochs(10)
                .devices(&["jetson_tx2_gpu"]),
        ),
        (
            "CPU (t=0)".into(),
            cifar_base(opts.rounds_3)
                .named("table3_cpu")
                .epochs(10)
                .devices(&["jetson_tx2_cpu"]),
        ),
        (
            format!("CPU (t={:.2} min)", tau_loose / 60.0),
            cifar_base(opts.rounds_3)
                .named("table3_cpu_tau_loose")
                .epochs(10)
                .devices(&["jetson_tx2_cpu"])
                .strategy(StrategyConfig::FedAvgCutoff {
                    taus: vec![("jetson_tx2_cpu".into(), tau_loose)],
                    default_tau_s: None,
                }),
        ),
        (
            format!("CPU (t={:.2} min)", tau_tight / 60.0),
            cifar_base(opts.rounds_3)
                .named("table3_cpu_tau_tight")
                .epochs(10)
                .devices(&["jetson_tx2_cpu"])
                .strategy(StrategyConfig::FedAvgCutoff {
                    taus: vec![("jetson_tx2_cpu".into(), tau_tight)],
                    default_tau_s: None,
                }),
        ),
    ];

    let mut table = Table::new(
        &format!("Table 3 reproduction — C=10, E=10, {} rounds", opts.rounds_3),
        &["config", "Accuracy", "Time (min)", "vs GPU", "truncated fits"],
    );
    let mut gpu_time: Option<f64> = None;
    for (label, cfg) in configs {
        let name = cfg.name.clone();
        let report = sim::run_experiment(&cfg, runtime)?;
        save(&report, &opts.out_dir, &name);
        let (acc, mins, _) = report.paper_metrics();
        let truncated: usize = report
            .history
            .rounds
            .iter()
            .map(|r| r.truncated_clients)
            .sum();
        let gpu_t = *gpu_time.get_or_insert(mins);
        table.row(vec![
            label,
            format!("{acc:.2}"),
            format!("{mins:.2}"),
            format!("{:.2}x", mins / gpu_t),
            truncated.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "shape check: CPU t=0 ~ 1.27x GPU time; t=GPU-equivalent ~ 1.0x with a small\n\
         accuracy drop; the looser tau sits between."
    );
    Ok(())
}
