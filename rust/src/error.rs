//! Unified error type for the flowrs stack.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the coordinator can fail.
#[derive(Debug)]
pub enum Error {
    /// Wire-format encode/decode failures (bad magic, truncation, ...).
    Codec(String),
    /// Transport-level I/O (TCP, in-proc channel closed, frame too large).
    Transport(String),
    /// PJRT runtime: artifact loading, compilation, execution.
    Runtime(String),
    /// Manifest / artifact directory problems.
    Artifact(String),
    /// Configuration validation.
    Config(String),
    /// FL-protocol level: a client misbehaved or a round could not proceed.
    Protocol(String),
    /// Strategy-level aggregation failures (no results, shape mismatch, ...).
    Aggregation(String),
    /// Client-side training failures.
    Client(String),
    /// Timeouts waiting for clients.
    Timeout(String),
    /// Checkpoint persistence: corrupt/truncated files, incompatible
    /// configs on resume.
    Persist(String),
    /// Underlying std I/O error.
    Io(std::io::Error),
}

impl Error {
    /// True iff this is the transport's *frame-boundary EOF* — the peer
    /// hung up cleanly between messages. These are the only two
    /// messages the transport layer produces for that case
    /// (`transport::frame` for sockets, `transport::inproc` for
    /// channels); anything else — mid-frame truncation, connect/bind
    /// failures, oversized frames — is a real fault and must not be
    /// treated as a clean shutdown.
    pub fn is_clean_close(&self) -> bool {
        matches!(
            self,
            Error::Transport(m) if m == "connection closed" || m == "in-proc peer closed"
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Aggregation(m) => write!(f, "aggregation error: {m}"),
            Error::Client(m) => write!(f, "client error: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Persist(m) => write!(f, "persist error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        let e = Error::Codec("bad magic".into());
        assert!(e.to_string().contains("codec"));
        let e = Error::Timeout("fit round 3".into());
        assert!(e.to_string().contains("timeout"));
    }

    #[test]
    fn clean_close_matches_only_frame_boundary_eof() {
        assert!(Error::Transport("connection closed".into()).is_clean_close());
        assert!(Error::Transport("in-proc peer closed".into()).is_clean_close());
        // mid-frame truncation, dial failures, and non-transport errors
        // are real faults, never a clean shutdown
        assert!(!Error::Transport("truncated frame: unexpected EOF".into()).is_clean_close());
        assert!(!Error::Transport("connect: refused".into()).is_clean_close());
        assert!(!Error::Codec("connection closed".into()).is_clean_close());
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
