//! Device heterogeneity substrate: hardware profiles for every device in
//! the paper's evaluation (Table 1 phones + Jetson TX2 GPU/CPU + RPi) and
//! the AWS-Device-Farm-style allocator.
//!
//! The paper measured time/energy on physical hardware; here each device
//! is a calibrated cost profile (see `sim::cost`) while the *numerics* of
//! local training run for real through the PJRT runtime. DESIGN.md §2 and
//! §6 describe the calibration.

pub mod farm;
pub mod profiles;

pub use farm::DeviceFarm;

/// Processor class a workload runs on (Table 3 contrasts TX2 GPU vs CPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Processor {
    Gpu,
    Cpu,
}

/// Device category (Table 1 flavor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Embedded,
    Phone,
    Tablet,
    Sbc,
}

/// A hardware cost profile. `compute_factor` is the per-train-step time
/// multiplier relative to the Jetson TX2 GPU reference (=1.0); power and
/// bandwidth figures are estimates from public spec sheets, good enough
/// to reproduce the paper's *trends* (they were never going to match the
/// authors' wall sockets).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub os: &'static str,
    pub kind: DeviceKind,
    pub processor: Processor,
    /// Train-step time multiplier vs TX2 GPU.
    pub compute_factor: f64,
    /// Average power while training (W).
    pub train_power_w: f64,
    /// Average idle power (W) — paid while waiting for stragglers.
    pub idle_power_w: f64,
    /// Radio/NIC power while transferring (W).
    pub radio_power_w: f64,
    /// Link bandwidth (Mbit/s), symmetric.
    pub bandwidth_mbps: f64,
}

impl DeviceProfile {
    /// Modeled time for one training step given the reference step time.
    pub fn step_time_s(&self, t_step_ref_s: f64) -> f64 {
        t_step_ref_s * self.compute_factor
    }
}

#[cfg(test)]
mod tests {
    use super::profiles;
    use super::*;

    #[test]
    fn tx2_cpu_is_1_27x_gpu() {
        // Table 3's headline ratio is baked into the profiles.
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let cpu = profiles::by_name("jetson_tx2_cpu").unwrap();
        let ratio = cpu.compute_factor / gpu.compute_factor;
        assert!((ratio - 1.27).abs() < 1e-9, "ratio={ratio}");
        assert_eq!(gpu.processor, Processor::Gpu);
        assert_eq!(cpu.processor, Processor::Cpu);
    }

    #[test]
    fn step_time_scales() {
        let cpu = profiles::by_name("jetson_tx2_cpu").unwrap();
        assert!((cpu.step_time_s(2.0) - 2.54).abs() < 1e-9);
    }
}
