//! AWS-Device-Farm-style allocator: check out N devices from an inventory,
//! cycling through the available models the way the paper scaled "to a
//! reasonably large number of Android clients with different OS versions".

use super::DeviceProfile;
use crate::error::{Error, Result};

/// A pool of physical devices available for checkout.
#[derive(Debug, Clone)]
pub struct DeviceFarm {
    inventory: Vec<&'static DeviceProfile>,
    next: usize,
}

impl DeviceFarm {
    pub fn new(inventory: Vec<&'static DeviceProfile>) -> Result<Self> {
        if inventory.is_empty() {
            return Err(Error::Config("device farm inventory is empty".into()));
        }
        Ok(DeviceFarm { inventory, next: 0 })
    }

    /// The paper's Android farm (Table 1).
    pub fn aws_android() -> Self {
        DeviceFarm::new(super::profiles::aws_device_farm_phones()).expect("non-empty")
    }

    /// A homogeneous farm of one device model (the Jetson experiments).
    pub fn homogeneous(device: &str) -> Result<Self> {
        DeviceFarm::new(vec![super::profiles::by_name(device)?])
    }

    /// Check out the next device (round-robin over the inventory, like
    /// requesting "any available Pixel/Galaxy" from the real farm).
    pub fn checkout(&mut self) -> &'static DeviceProfile {
        let p = self.inventory[self.next % self.inventory.len()];
        self.next += 1;
        p
    }

    /// Check out `n` devices.
    pub fn checkout_n(&mut self, n: usize) -> Vec<&'static DeviceProfile> {
        (0..n).map(|_| self.checkout()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_inventory() {
        let mut farm = DeviceFarm::aws_android();
        let got = farm.checkout_n(7);
        assert_eq!(got[0].name, "pixel4");
        assert_eq!(got[4].name, "galaxy_tab_s4");
        assert_eq!(got[5].name, "pixel4"); // wrapped
        assert_eq!(got[6].name, "pixel3");
    }

    #[test]
    fn homogeneous_farm() {
        let mut farm = DeviceFarm::homogeneous("jetson_tx2_gpu").unwrap();
        assert!(farm.checkout_n(10).iter().all(|p| p.name == "jetson_tx2_gpu"));
        assert!(DeviceFarm::homogeneous("toaster").is_err());
    }

    #[test]
    fn empty_inventory_rejected() {
        assert!(DeviceFarm::new(vec![]).is_err());
    }
}
