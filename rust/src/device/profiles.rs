//! The device inventory: every device the paper's evaluation touches.
//!
//! Calibration notes (all figures are public-spec estimates; the paper's
//! own Table 2/3 numbers pin only the TX2 GPU step time and the 1.27×
//! GPU→CPU ratio):
//!
//! * **Jetson TX2 GPU** — the reference processor (`compute_factor = 1.0`).
//!   Table 2a: E=10 → 80.32 min / 40 rounds ≈ 2.008 min/round; with 8
//!   batches/epoch the implied per-step time is ≈1.5 s (ResNet-18 class
//!   workload) — that constant lives in `sim::cost::CostModel::default`.
//!   `train_power_w` is the *incremental* power attributed to training
//!   (above the always-on baseline), back-derived from the paper's own
//!   energy rows: Table 2a E=10 reports 100.95 kJ over 40 rounds × 10
//!   clients × ≈118 s compute → ≈2.1 W per TX2.
//! * **Jetson TX2 CPU** — Table 3 measures CPU training at 1.27× the GPU
//!   time; slightly higher incremental draw than the GPU path.
//! * **Phones/tablets** (Table 1) — factors interpolated from Geekbench-5
//!   multicore ratios vs TX2-class silicon; incremental powers derived
//!   the same way from Table 2b (10.4 kJ / 20 rounds / 4 phones ≈ 1.4 W).
//! * **Raspberry Pi 4** — CPU-only, far slower on conv workloads.

use super::{DeviceKind, DeviceProfile, Processor};
use crate::error::{Error, Result};

/// The full inventory.
pub const ALL: &[DeviceProfile] = &[
    DeviceProfile {
        name: "jetson_tx2_gpu",
        os: "Linux 4.9 (L4T)",
        kind: DeviceKind::Embedded,
        processor: Processor::Gpu,
        compute_factor: 1.0,
        train_power_w: 2.1,
        idle_power_w: 1.4,
        radio_power_w: 1.0,
        bandwidth_mbps: 100.0,
    },
    DeviceProfile {
        name: "jetson_tx2_cpu",
        os: "Linux 4.9 (L4T)",
        kind: DeviceKind::Embedded,
        processor: Processor::Cpu,
        compute_factor: 1.27,
        train_power_w: 2.4,
        idle_power_w: 1.4,
        radio_power_w: 1.0,
        bandwidth_mbps: 100.0,
    },
    DeviceProfile {
        name: "pixel4",
        os: "Android 10",
        kind: DeviceKind::Phone,
        processor: Processor::Cpu,
        compute_factor: 1.8,
        train_power_w: 1.3,
        idle_power_w: 0.6,
        radio_power_w: 0.8,
        bandwidth_mbps: 50.0,
    },
    DeviceProfile {
        name: "pixel3",
        os: "Android 10",
        kind: DeviceKind::Phone,
        processor: Processor::Cpu,
        compute_factor: 2.2,
        train_power_w: 1.4,
        idle_power_w: 0.6,
        radio_power_w: 0.8,
        bandwidth_mbps: 50.0,
    },
    DeviceProfile {
        name: "pixel2",
        os: "Android 9",
        kind: DeviceKind::Phone,
        processor: Processor::Cpu,
        compute_factor: 2.8,
        train_power_w: 1.5,
        idle_power_w: 0.65,
        radio_power_w: 0.8,
        bandwidth_mbps: 40.0,
    },
    DeviceProfile {
        name: "galaxy_tab_s6",
        os: "Android 9",
        kind: DeviceKind::Tablet,
        processor: Processor::Cpu,
        compute_factor: 1.9,
        train_power_w: 1.45,
        idle_power_w: 0.7,
        radio_power_w: 0.9,
        bandwidth_mbps: 50.0,
    },
    DeviceProfile {
        name: "galaxy_tab_s4",
        os: "Android 8.1.0",
        kind: DeviceKind::Tablet,
        processor: Processor::Cpu,
        compute_factor: 2.6,
        train_power_w: 1.55,
        idle_power_w: 0.75,
        radio_power_w: 0.9,
        bandwidth_mbps: 40.0,
    },
    DeviceProfile {
        name: "raspberry_pi4",
        os: "Raspbian",
        kind: DeviceKind::Sbc,
        processor: Processor::Cpu,
        compute_factor: 6.0,
        train_power_w: 3.0,
        idle_power_w: 2.0,
        radio_power_w: 0.5,
        bandwidth_mbps: 100.0,
    },
];

/// Look a profile up by name.
pub fn by_name(name: &str) -> Result<&'static DeviceProfile> {
    ALL.iter()
        .find(|p| p.name == name)
        .ok_or_else(|| {
            let known: Vec<&str> = ALL.iter().map(|p| p.name).collect();
            Error::Config(format!("unknown device {name:?}; known: {known:?}"))
        })
}

/// The paper's Android cohort (Table 1), in farm checkout order.
pub fn aws_device_farm_phones() -> Vec<&'static DeviceProfile> {
    ["pixel4", "pixel3", "pixel2", "galaxy_tab_s6", "galaxy_tab_s4"]
        .iter()
        .map(|n| by_name(n).expect("inventory is static"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_works() {
        assert_eq!(by_name("pixel4").unwrap().os, "Android 10");
        assert!(by_name("iphone99").is_err());
    }

    #[test]
    fn inventory_is_sane() {
        for p in ALL {
            assert!(p.compute_factor >= 1.0, "{}", p.name);
            assert!(p.train_power_w > p.idle_power_w, "{}", p.name);
            assert!(p.bandwidth_mbps > 0.0, "{}", p.name);
        }
    }

    #[test]
    fn farm_matches_table1() {
        let phones = aws_device_farm_phones();
        assert_eq!(phones.len(), 5);
        assert_eq!(phones[0].name, "pixel4");
        assert_eq!(phones[4].name, "galaxy_tab_s4");
    }
}
