//! Trace-driven availability and device-class scenarios.
//!
//! The paper's core finding is that on-device FL cost is dominated by
//! *which* devices are available and *what class of hardware* they are
//! — smartphones, Jetson TX2s and Raspberry Pis differ by an order of
//! magnitude in per-round compute and energy. The synthetic
//! [`ChurnModel`](super::availability::ChurnModel) on/off cycle covers
//! none of the structure real deployments show (day/night rhythms,
//! charging-gated participation, flash crowds), so this module makes
//! *recorded* availability a first-class input:
//!
//! * [`TraceSet`] — per-device explicit toggle schedules plus optional
//!   hardware-class tags, loaded from a documented CSV or JSON file
//!   (format spec: `rust/src/sched/TRACES.md`).
//! * [`scenario_trace_set`] — a library of named generators
//!   (`diurnal`, `charging-gated`, `flash-crowd`) that synthesize
//!   deployment-shaped trace sets deterministically from a seed.
//! * [`AvailabilitySource`] — the abstraction the engine consumes: the
//!   pre-existing synthetic model and trace sets behind one surface,
//!   yielding a [`DeviceSchedule`] (and optionally a pinned
//!   [`DeviceProfile`]) per device.
//!
//! Class tags feed straight into the engine's cost accounting: a
//! device tagged `rpi` is modeled with the Raspberry Pi's compute-time
//! and power figures wherever the cost model is consulted (dispatch
//! timing, energy, policy feasibility), exactly as if the device mix
//! had assigned it that profile.
#![deny(missing_docs)]

use std::path::Path;
use std::sync::Arc;

use crate::device::{profiles, DeviceProfile};
use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::availability::{Availability, AvailabilityTrace, Cycle, DeviceSchedule};

/// The exact header line a trace CSV must start with.
pub const CSV_HEADER: &str = "device,init,class,toggles_s";

/// Names of the built-in scenarios, in the order `flowrs sched
/// --scenario` documents them.
pub const SCENARIOS: &[&str] = &["diurnal", "charging-gated", "flash-crowd"];

/// Seconds in a day (the diurnal generators' base period).
const DAY_S: f64 = 86_400.0;

/// Resolve a trace class tag: a shorthand alias (`phone`, `tablet`,
/// `jetson`, `rpi`) or any exact device-profile name from the
/// inventory.
pub fn resolve_class(tag: &str) -> Result<&'static DeviceProfile> {
    let name = match tag {
        "phone" => "pixel4",
        "tablet" => "galaxy_tab_s6",
        "jetson" => "jetson_tx2_gpu",
        "rpi" => "raspberry_pi4",
        other => other,
    };
    profiles::by_name(name).map_err(|_| {
        Error::Config(format!(
            "unknown device class {tag:?} (phone | tablet | jetson | rpi or an \
             exact profile name; see `flowrs devices`)"
        ))
    })
}

/// One device's recorded schedule plus its optional hardware-class tag.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// The device's availability trace (shared with the index).
    pub trace: Arc<AvailabilityTrace>,
    /// Hardware class pinned by the trace (`None` = the device draws
    /// its profile from the configured device mix).
    pub class: Option<&'static DeviceProfile>,
}

/// A recorded availability scenario: one [`TraceEntry`] per device,
/// dense over device ids `0..len`.
#[derive(Debug, Clone, Default)]
pub struct TraceSet {
    /// Per-device entries, indexed by device id.
    pub devices: Vec<TraceEntry>,
}

impl TraceSet {
    /// Number of devices the trace describes.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Whether the trace describes no devices at all.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Load a trace file: JSON if the content starts with `{`, CSV
    /// otherwise (see `rust/src/sched/TRACES.md`).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read trace {}: {e}", path.display()))
        })?;
        Self::parse(&text)
            .map_err(|e| Error::Config(format!("trace {}: {e}", path.display())))
    }

    /// Parse trace text: JSON if it starts with `{`, CSV otherwise.
    pub fn parse(text: &str) -> Result<Self> {
        if text.trim_start().starts_with('{') {
            Self::parse_json(text)
        } else {
            Self::parse_csv(text)
        }
    }

    /// Parse the CSV form. Blank lines and `#` comments are skipped;
    /// the first remaining line must be exactly [`CSV_HEADER`].
    pub fn parse_csv(text: &str) -> Result<Self> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        match lines.next() {
            Some(h) if h == CSV_HEADER => {}
            other => {
                return Err(Error::Config(format!(
                    "trace CSV must start with the header {CSV_HEADER:?}, found {other:?}"
                )))
            }
        }
        let mut devices = Vec::new();
        for line in lines {
            let cols: Vec<&str> = line.splitn(4, ',').collect();
            if cols.len() != 4 {
                return Err(Error::Config(format!(
                    "trace row needs 4 columns ({CSV_HEADER}): {line:?}"
                )));
            }
            let device: usize = cols[0]
                .parse()
                .map_err(|_| Error::Config(format!("bad device id {:?}", cols[0])))?;
            if device != devices.len() {
                return Err(Error::Config(format!(
                    "trace device ids must be dense and ascending: row {} is \
                     tagged device {device}",
                    devices.len()
                )));
            }
            let initially_on = parse_init(cols[1])?;
            let class = match cols[2] {
                "" => None,
                tag => Some(resolve_class(tag)?),
            };
            let toggles_s = parse_toggles(cols[3])?;
            devices.push(TraceEntry {
                trace: Arc::new(AvailabilityTrace { initially_on, toggles_s }),
                class,
            });
        }
        let set = TraceSet { devices };
        set.validate()?;
        Ok(set)
    }

    /// Parse the JSON form:
    /// `{"devices": [{"device": 0, "initially_on": true,
    /// "class": "phone", "toggles_s": [30.5, 120.0]}, ...]}` —
    /// `class` and `toggles_s` are optional per device.
    pub fn parse_json(text: &str) -> Result<Self> {
        let doc = Json::parse(text)?;
        let arr = doc.get("devices")?.as_arr()?;
        let mut devices = Vec::with_capacity(arr.len());
        for (i, d) in arr.iter().enumerate() {
            let device = d.get("device")?.as_usize()?;
            if device != i {
                return Err(Error::Config(format!(
                    "trace device ids must be dense and ascending: entry {i} is \
                     tagged device {device}"
                )));
            }
            let initially_on = d.get("initially_on")?.as_bool()?;
            let class = match d.opt("class") {
                Some(v) => Some(resolve_class(v.as_str()?)?),
                None => None,
            };
            let toggles_s = match d.opt("toggles_s") {
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(Json::as_f64)
                    .collect::<Result<Vec<f64>>>()?,
                None => Vec::new(),
            };
            devices.push(TraceEntry {
                trace: Arc::new(AvailabilityTrace { initially_on, toggles_s }),
                class,
            });
        }
        let set = TraceSet { devices };
        set.validate()?;
        Ok(set)
    }

    /// Check the trace invariants the engine depends on: at least one
    /// device, and per device strictly increasing, finite, positive
    /// toggle times.
    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            return Err(Error::Config("trace describes no devices".into()));
        }
        for (i, e) in self.devices.iter().enumerate() {
            let t = &e.trace.toggles_s;
            for (j, &x) in t.iter().enumerate() {
                if !x.is_finite() || x <= 0.0 {
                    return Err(Error::Config(format!(
                        "device {i}: toggle {x} must be finite and > 0"
                    )));
                }
                if j > 0 && x <= t[j - 1] {
                    return Err(Error::Config(format!(
                        "device {i}: toggle times must be strictly increasing \
                         ({} then {x})",
                        t[j - 1]
                    )));
                }
            }
        }
        Ok(())
    }

    /// Serialize to the CSV form. Toggle times print with Rust's
    /// shortest round-trip `f64` formatting, so
    /// `parse_csv(to_csv(set))` reproduces the set bit-exactly.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(CSV_HEADER);
        out.push('\n');
        for (i, e) in self.devices.iter().enumerate() {
            let toggles = e
                .trace
                .toggles_s
                .iter()
                .map(|t| format!("{t}"))
                .collect::<Vec<_>>()
                .join(";");
            out.push_str(&format!(
                "{i},{},{},{toggles}\n",
                u8::from(e.trace.initially_on),
                e.class.map(|c| c.name).unwrap_or(""),
            ));
        }
        out
    }
}

fn parse_init(s: &str) -> Result<bool> {
    match s {
        "1" | "on" => Ok(true),
        "0" | "off" => Ok(false),
        other => Err(Error::Config(format!(
            "bad init column {other:?} (1 | 0 | on | off)"
        ))),
    }
}

fn parse_toggles(s: &str) -> Result<Vec<f64>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(';')
        .map(|x| {
            x.trim()
                .parse::<f64>()
                .map_err(|_| Error::Config(format!("bad toggle time {x:?}")))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Scenario library
// ---------------------------------------------------------------------------

/// Generate a named scenario as an explicit [`TraceSet`] over
/// `[0, horizon_s)`, deterministically from `seed`. Devices freeze in
/// their final state past the horizon, so pick one beyond the virtual
/// time the run will reach (the `scenario_horizon_s` config knob).
///
/// * `diurnal` — phones and tablets plugged in overnight: one 8–11 h
///   charging window per 24 h day, per-device jitter on when it opens.
/// * `charging-gated` — the Flower on-device constraint (train only
///   while charging *and* idle): one short 1.5–3 h evening session per
///   day, phones only. Low duty, strongly time-of-day correlated.
/// * `flash-crowd` — sparse uncorrelated background availability
///   (20–50 min windows, hours apart) plus a synchronized surge in
///   `[3600 s, 7200 s)` where the whole population is online at once.
pub fn scenario_trace_set(
    name: &str,
    population: usize,
    seed: u64,
    horizon_s: f64,
) -> Result<TraceSet> {
    if population == 0 {
        return Err(Error::Config("scenario population must be > 0".into()));
    }
    if !(horizon_s > 0.0) || !horizon_s.is_finite() {
        return Err(Error::Config(
            "scenario horizon must be finite and > 0".into(),
        ));
    }
    for &(n, f) in SCENARIO_TABLE {
        if n == name {
            return Ok(f(population, seed, horizon_s));
        }
    }
    Err(Error::Config(format!(
        "unknown scenario {name:?} ({})",
        SCENARIOS.join(" | ")
    )))
}

/// A scenario generator: `(population, seed, horizon_s) -> TraceSet`.
type ScenarioFn = fn(usize, u64, f64) -> TraceSet;

/// The single scenario registry — [`SCENARIOS`] and every dispatch /
/// error message derive from this, so adding a scenario is one entry
/// here plus its generator (consistency pinned by a unit test).
const SCENARIO_TABLE: &[(&str, ScenarioFn)] = &[
    ("diurnal", diurnal),
    ("charging-gated", charging_gated),
    ("flash-crowd", flash_crowd),
];

/// Draw one class from a static `(profile name, weight)` mix.
fn pick_class(
    rng: &mut Rng,
    classes: &[(&'static str, f64)],
) -> &'static DeviceProfile {
    let total: f64 = classes.iter().map(|&(_, w)| w).sum();
    let mut r = rng.f64() * total;
    let mut name = classes[classes.len() - 1].0;
    for &(n, w) in classes {
        if r < w {
            name = n;
            break;
        }
        r -= w;
    }
    profiles::by_name(name).expect("scenario classes are static inventory names")
}

/// Build the daily-window trace for one device: online during
/// `[start_s, start_s + len_s)` (seconds-of-day, wrapping) each day.
fn daily_window(start_s: f64, len_s: f64, horizon_s: f64) -> AvailabilityTrace {
    debug_assert!(len_s < DAY_S && start_s >= 0.0 && start_s < DAY_S);
    // (t + (DAY - start)) mod DAY < len  ⇔  t-of-day ∈ [start, start+len)
    Cycle { on_s: len_s, off_s: DAY_S - len_s, phase_s: DAY_S - start_s }
        .materialize(horizon_s)
}

/// Day/night cycles: devices charge (and train) overnight.
fn diurnal(population: usize, seed: u64, horizon_s: f64) -> TraceSet {
    let classes: [(&str, f64); 5] = [
        ("pixel4", 0.30),
        ("pixel3", 0.25),
        ("pixel2", 0.15),
        ("galaxy_tab_s6", 0.18),
        ("galaxy_tab_s4", 0.12),
    ];
    let root = Rng::seed_from(seed ^ 0xD1A1);
    let mut devices = Vec::with_capacity(population);
    for d in 0..population as u64 {
        let mut rng = root.derive(d);
        let start_s = 72_000.0 + rng.f64() * 14_400.0; // plugged in 20:00–24:00
        let len_s = 28_800.0 + rng.f64() * 10_800.0; // 8–11 h on the charger
        let class = pick_class(&mut rng, &classes);
        devices.push(TraceEntry {
            trace: Arc::new(daily_window(start_s % DAY_S, len_s, horizon_s)),
            class: Some(class),
        });
    }
    TraceSet { devices }
}

/// Charging- and idle-gated participation (the Flower on-device
/// constraint): one short evening session per day, phones only.
fn charging_gated(population: usize, seed: u64, horizon_s: f64) -> TraceSet {
    let classes: [(&str, f64); 3] =
        [("pixel4", 0.40), ("pixel3", 0.35), ("pixel2", 0.25)];
    let root = Rng::seed_from(seed ^ 0xC4A6);
    let mut devices = Vec::with_capacity(population);
    for d in 0..population as u64 {
        let mut rng = root.derive(d);
        let start_s = (68_400.0 + rng.f64() * 21_600.0) % DAY_S; // 19:00–01:00
        let len_s = 5_400.0 + rng.f64() * 5_400.0; // 1.5–3 h charging + idle
        let class = pick_class(&mut rng, &classes);
        devices.push(TraceEntry {
            trace: Arc::new(daily_window(start_s, len_s, horizon_s)),
            class: Some(class),
        });
    }
    TraceSet { devices }
}

/// Sparse background availability plus one synchronized surge.
fn flash_crowd(population: usize, seed: u64, horizon_s: f64) -> TraceSet {
    const SURGE_START_S: f64 = 3_600.0;
    const SURGE_END_S: f64 = 7_200.0;
    let root = Rng::seed_from(seed ^ 0xF1A5);
    let mut devices = Vec::with_capacity(population);
    for d in 0..population as u64 {
        let mut rng = root.derive(d);
        let on_s = 1_200.0 + rng.f64() * 1_800.0; // 20–50 min windows
        let off_s = 9_000.0 + rng.f64() * 9_000.0; // 2.5–5 h gaps
        let phase_s = rng.f64() * (on_s + off_s);
        let base = Cycle { on_s, off_s, phase_s }.materialize(horizon_s);
        let trace =
            union_with_window(&base, SURGE_START_S, SURGE_END_S.min(horizon_s), horizon_s);
        devices.push(TraceEntry { trace: Arc::new(trace), class: None });
    }
    TraceSet { devices }
}

/// Union a trace's on-intervals with the extra window `[from_s, to_s)`.
fn union_with_window(
    base: &AvailabilityTrace,
    from_s: f64,
    to_s: f64,
    horizon_s: f64,
) -> AvailabilityTrace {
    if to_s <= from_s {
        return base.clone();
    }
    // materialize the base's on-intervals over [0, horizon)
    let mut intervals: Vec<(f64, f64)> = Vec::new();
    let mut on = base.initially_on;
    let mut t = 0.0;
    for &x in &base.toggles_s {
        if on {
            intervals.push((t, x));
        }
        on = !on;
        t = x;
    }
    if on {
        intervals.push((t, horizon_s));
    }
    intervals.push((from_s, to_s));
    intervals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut merged: Vec<(f64, f64)> = Vec::new();
    for (a, b) in intervals {
        match merged.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => merged.push((a, b)),
        }
    }
    // re-emit as initial state + toggles strictly inside (0, horizon)
    let initially_on = merged.first().map(|&(a, _)| a <= 0.0).unwrap_or(false);
    let mut toggles_s = Vec::new();
    for (a, b) in merged {
        if a > 0.0 && a < horizon_s {
            toggles_s.push(a);
        }
        if b > a && b < horizon_s {
            toggles_s.push(b);
        }
    }
    AvailabilityTrace { initially_on, toggles_s }
}

// ---------------------------------------------------------------------------
// AvailabilitySource
// ---------------------------------------------------------------------------

/// Where a population's availability schedules come from: the
/// synthetic model (always-on / churn) or an explicit trace set
/// (recorded file or generated scenario). This is the one surface the
/// engine consumes, so a replayed deployment trace drives exactly the
/// machinery the synthetic model does.
#[derive(Debug, Clone)]
pub enum AvailabilitySource {
    /// Synthetic model — the pre-trace behavior, bit-identical.
    Model(Availability),
    /// Explicit per-device traces with optional class tags.
    Trace(TraceSet),
}

impl AvailabilitySource {
    /// Build the source a [`crate::config::ScheduleConfig`] describes:
    /// an explicit `trace_file`, a named `scenario`, or the
    /// churn/always-on model. A trace file must describe exactly
    /// `population` devices (scenarios scale to any population).
    pub fn from_config(cfg: &crate::config::ScheduleConfig) -> Result<Self> {
        match (&cfg.trace_file, &cfg.scenario) {
            (Some(_), Some(_)) => Err(Error::Config(
                "trace_file and scenario are mutually exclusive".into(),
            )),
            (Some(path), None) => {
                let set = TraceSet::from_file(Path::new(path))?;
                if set.len() != cfg.population {
                    return Err(Error::Config(format!(
                        "trace {path:?} describes {} devices; set population {} \
                         to match (configured: {})",
                        set.len(),
                        set.len(),
                        cfg.population
                    )));
                }
                Ok(AvailabilitySource::Trace(set))
            }
            (None, Some(name)) => Ok(AvailabilitySource::Trace(scenario_trace_set(
                name,
                cfg.population,
                cfg.seed,
                cfg.scenario_horizon_s,
            )?)),
            (None, None) => Ok(AvailabilitySource::Model(Availability::from_spec(
                cfg.churn.as_ref(),
                cfg.seed ^ 0xC4A2,
            ))),
        }
    }

    /// The device's schedule under this source.
    pub fn schedule(&self, device: u64) -> DeviceSchedule {
        match self {
            AvailabilitySource::Model(a) => DeviceSchedule::Cycle(a.cycle(device)),
            AvailabilitySource::Trace(t) => {
                DeviceSchedule::Trace(Arc::clone(&t.devices[device as usize].trace))
            }
        }
    }

    /// The hardware class the source pins for `device`, if any — the
    /// engine's cost accounting then models the device with that
    /// profile instead of drawing one from the mix.
    pub fn class(&self, device: u64) -> Option<&'static DeviceProfile> {
        match self {
            AvailabilitySource::Model(_) => None,
            AvailabilitySource::Trace(t) => t.devices[device as usize].class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(initially_on: bool, toggles: &[f64], class: Option<&str>) -> TraceEntry {
        TraceEntry {
            trace: Arc::new(AvailabilityTrace {
                initially_on,
                toggles_s: toggles.to_vec(),
            }),
            class: class.map(|c| resolve_class(c).unwrap()),
        }
    }

    #[test]
    fn csv_roundtrip_is_exact() {
        let set = TraceSet {
            devices: vec![
                entry(true, &[30.5, 120.0, 400.25], Some("phone")),
                entry(false, &[10.0], Some("rpi")),
                entry(true, &[], None),
                entry(false, &[0.1, 0.2, 0.30000000000000004], Some("jetson_tx2_cpu")),
            ],
        };
        let text = set.to_csv();
        let back = TraceSet::parse(&text).unwrap();
        assert_eq!(back.len(), set.len());
        for (a, b) in set.devices.iter().zip(&back.devices) {
            assert_eq!(a.trace.initially_on, b.trace.initially_on);
            assert_eq!(a.trace.toggles_s, b.trace.toggles_s, "toggles must round-trip bit-exactly");
            assert_eq!(a.class.map(|c| c.name), b.class.map(|c| c.name));
        }
    }

    #[test]
    fn csv_parser_accepts_comments_aliases_and_on_off() {
        let text = "\
# recorded 2026-07-01, anonymized
device,init,class,toggles_s

0,on,phone,30;60
1,off,raspberry_pi4,15.5
2,1,,\n";
        let set = TraceSet::parse(text).unwrap();
        assert_eq!(set.len(), 3);
        assert!(set.devices[0].trace.initially_on);
        assert_eq!(set.devices[0].class.unwrap().name, "pixel4");
        assert!(!set.devices[1].trace.initially_on);
        assert_eq!(set.devices[1].class.unwrap().name, "raspberry_pi4");
        assert!(set.devices[2].class.is_none());
        assert!(set.devices[2].trace.toggles_s.is_empty());
    }

    #[test]
    fn json_parser_accepts_optional_fields() {
        let text = r#"{
            "devices": [
                {"device": 0, "initially_on": true, "class": "jetson",
                 "toggles_s": [30.5, 120.0]},
                {"device": 1, "initially_on": false}
            ]
        }"#;
        let set = TraceSet::parse(text).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.devices[0].class.unwrap().name, "jetson_tx2_gpu");
        assert_eq!(set.devices[0].trace.toggles_s, vec![30.5, 120.0]);
        assert!(set.devices[1].class.is_none());
        assert!(!set.devices[1].trace.initially_on);
    }

    #[test]
    fn malformed_traces_are_rejected() {
        let hdr = "device,init,class,toggles_s\n";
        // unsorted toggles
        assert!(TraceSet::parse(&format!("{hdr}0,1,,30;20\n")).is_err());
        // duplicate toggle
        assert!(TraceSet::parse(&format!("{hdr}0,1,,30;30\n")).is_err());
        // unknown class
        assert!(TraceSet::parse(&format!("{hdr}0,1,nokia3310,30\n")).is_err());
        // non-positive / non-finite toggle times
        assert!(TraceSet::parse(&format!("{hdr}0,1,,0\n")).is_err());
        assert!(TraceSet::parse(&format!("{hdr}0,1,,-5\n")).is_err());
        assert!(TraceSet::parse(&format!("{hdr}0,1,,inf\n")).is_err());
        // bad init column
        assert!(TraceSet::parse(&format!("{hdr}0,yes,,30\n")).is_err());
        // sparse / out-of-order device ids
        assert!(TraceSet::parse(&format!("{hdr}1,1,,30\n")).is_err());
        assert!(TraceSet::parse(&format!("{hdr}0,1,,30\n2,1,,40\n")).is_err());
        // missing header, wrong column count, garbage numbers
        assert!(TraceSet::parse("0,1,,30\n").is_err());
        assert!(TraceSet::parse(&format!("{hdr}0,1,30\n")).is_err());
        assert!(TraceSet::parse(&format!("{hdr}0,1,,x\n")).is_err());
        // empty trace set
        assert!(TraceSet::parse(hdr).is_err());
        // JSON: sparse ids and unknown class
        assert!(TraceSet::parse(
            r#"{"devices": [{"device": 1, "initially_on": true}]}"#
        )
        .is_err());
        assert!(TraceSet::parse(
            r#"{"devices": [{"device": 0, "initially_on": true, "class": "vax"}]}"#
        )
        .is_err());
    }

    #[test]
    fn scenario_registry_is_consistent() {
        // SCENARIOS (the list validate() and docs use) and
        // SCENARIO_TABLE (the dispatch) must never drift apart.
        let table_names: Vec<&str> = SCENARIO_TABLE.iter().map(|&(n, _)| n).collect();
        assert_eq!(table_names, SCENARIOS.to_vec());
    }

    #[test]
    fn class_aliases_resolve() {
        assert_eq!(resolve_class("phone").unwrap().name, "pixel4");
        assert_eq!(resolve_class("tablet").unwrap().name, "galaxy_tab_s6");
        assert_eq!(resolve_class("jetson").unwrap().name, "jetson_tx2_gpu");
        assert_eq!(resolve_class("rpi").unwrap().name, "raspberry_pi4");
        assert_eq!(resolve_class("pixel3").unwrap().name, "pixel3");
        assert!(resolve_class("vax").is_err());
    }

    #[test]
    fn scenarios_are_deterministic_and_well_formed() {
        for &name in SCENARIOS {
            let a = scenario_trace_set(name, 200, 42, 172_800.0).unwrap();
            let b = scenario_trace_set(name, 200, 42, 172_800.0).unwrap();
            let c = scenario_trace_set(name, 200, 43, 172_800.0).unwrap();
            assert_eq!(a.len(), 200);
            a.validate().unwrap();
            let eq = |x: &TraceSet, y: &TraceSet| {
                x.devices.iter().zip(&y.devices).all(|(p, q)| {
                    p.trace.initially_on == q.trace.initially_on
                        && p.trace.toggles_s == q.trace.toggles_s
                        && p.class.map(|c| c.name) == q.class.map(|c| c.name)
                })
            };
            assert!(eq(&a, &b), "{name} not deterministic");
            assert!(!eq(&a, &c), "{name} ignores the seed");
            // every scenario device toggles at least once over 2 days
            assert!(
                a.devices.iter().all(|e| !e.trace.toggles_s.is_empty()),
                "{name} produced a toggle-free device"
            );
        }
        assert!(scenario_trace_set("weekend", 10, 1, 1000.0).is_err());
        assert!(scenario_trace_set("diurnal", 0, 1, 1000.0).is_err());
        assert!(scenario_trace_set("diurnal", 10, 1, -1.0).is_err());
    }

    #[test]
    fn diurnal_is_day_night_shaped() {
        let set = scenario_trace_set("diurnal", 500, 7, 172_800.0).unwrap();
        let online_at = |t: f64| {
            set.devices.iter().filter(|e| e.trace.is_on(t)).count()
        };
        // midnight (well inside the charging window) vs midday
        let night = online_at(2.0 * 3600.0);
        let noon = online_at(12.0 * 3600.0);
        assert!(
            night > 400 && noon < 100,
            "diurnal shape wrong: night={night}, noon={noon} of 500"
        );
        // phone/tablet classes only
        assert!(set.devices.iter().all(|e| {
            matches!(
                e.class.unwrap().name,
                "pixel4" | "pixel3" | "pixel2" | "galaxy_tab_s6" | "galaxy_tab_s4"
            )
        }));
    }

    #[test]
    fn charging_gated_has_low_evening_duty() {
        let set = scenario_trace_set("charging-gated", 500, 7, 172_800.0).unwrap();
        let online_at = |t: f64| {
            set.devices.iter().filter(|e| e.trace.is_on(t)).count()
        };
        // ~2.25 h of 24 h → ≈ 9% duty; at 21:00 sessions overlap most
        let evening = online_at(21.0 * 3600.0);
        let noon = online_at(12.0 * 3600.0);
        assert!(evening > 50, "evening={evening} of 500");
        assert!(noon < 25, "noon={noon} of 500");
        assert!(set
            .devices
            .iter()
            .all(|e| e.class.unwrap().name.starts_with("pixel")));
    }

    #[test]
    fn flash_crowd_surges_everyone_online() {
        let set = scenario_trace_set("flash-crowd", 300, 7, 172_800.0).unwrap();
        let online_at = |t: f64| {
            set.devices.iter().filter(|e| e.trace.is_on(t)).count()
        };
        // inside the surge window the whole population is online
        assert_eq!(online_at(5_000.0), 300);
        // background duty is sparse (20–50 min per 2.5–5 h)
        let background = online_at(50_000.0);
        assert!(
            background < 120,
            "background availability too dense: {background} of 300"
        );
        assert!(set.devices.iter().all(|e| e.class.is_none()));
    }

    #[test]
    fn union_with_window_merges_and_preserves_invariants() {
        let base = AvailabilityTrace {
            initially_on: true,
            toggles_s: vec![100.0, 3_700.0, 3_800.0, 10_000.0],
        };
        let merged = union_with_window(&base, 3_600.0, 7_200.0, 20_000.0);
        // on [0,100) ∪ [3700,3800) ∪ [10000,20000) ∪ surge [3600,7200)
        //   = [0,100) ∪ [3600,7200) ∪ [10000,20000)
        assert!(merged.initially_on);
        assert_eq!(merged.toggles_s, vec![100.0, 3_600.0, 7_200.0, 10_000.0]);
        assert!(merged.is_on(5_000.0));
        assert!(!merged.is_on(8_000.0));
        assert!(merged.is_on(15_000.0));
        // still a valid strictly-increasing trace
        TraceSet { devices: vec![TraceEntry { trace: Arc::new(merged), class: None }] }
            .validate()
            .unwrap();
    }
}
