//! The event-driven population engine: virtual federations of 100k–1M
//! devices, scheduled in virtual time.
//!
//! The in-proc simulator ([`crate::sim::run_experiment`]) runs one OS
//! thread per client and tops out at tens of devices. This engine flips
//! the representation: the *population* is a flat array of cost profiles
//! and availability cycles, a round is a binary-heap event queue over
//! modeled completion times, and only the selected cohort trains
//! numerics — either for real through a [`CohortTrainer`] backed by the
//! PJRT runtime ([`crate::sim::population`]) or through the closed-form
//! [`SurrogateTrainer`]. A 100k-device round is a few milliseconds of
//! wall clock; a 1M-device experiment completes in seconds.
//!
//! Per round:
//! 1. scan availability at the current virtual time,
//! 2. ask the configured [`SelectionPolicy`] for a cohort,
//! 3. push one completion event per selected client (modeled download +
//!    compute + upload time) and drain the heap in virtual-time order:
//!    clients past the τ deadline — or offline by their completion time
//!    (mid-round churn) — are *dropped* and their energy wasted,
//! 4. train numerics for the clients that reported, advance the clock to
//!    `min(τ, slowest completion)` + server overhead.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::ScheduleConfig;
use crate::device::{profiles, DeviceProfile};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::availability::{Availability, Cycle};
use super::policy::{Candidate, SelectionContext, SelectionPolicy};

// ---------------------------------------------------------------------------
// Population
// ---------------------------------------------------------------------------

/// One virtual device: a cost profile, an availability cycle, and the
/// scheduler-visible training history.
#[derive(Debug, Clone)]
pub struct VirtualDevice {
    pub device: &'static DeviceProfile,
    pub num_examples: u64,
    pub cycle: Cycle,
    /// Data-difficulty skew in [0, 1): gives utility policies per-client
    /// signal under the surrogate trainer.
    pub skew: f64,
    pub last_loss: Option<f64>,
    pub last_selected_round: Option<u64>,
}

/// The whole virtual federation.
#[derive(Debug, Clone, Default)]
pub struct Population {
    pub devices: Vec<VirtualDevice>,
}

/// The default population mix when the config doesn't pin one: phones
/// dominate, with tablet / embedded / SBC tails (paper Table 1 hardware).
pub fn default_device_mix() -> Vec<(&'static DeviceProfile, f64)> {
    [
        ("pixel4", 0.20),
        ("pixel3", 0.20),
        ("pixel2", 0.15),
        ("galaxy_tab_s6", 0.10),
        ("galaxy_tab_s4", 0.10),
        ("jetson_tx2_gpu", 0.05),
        ("jetson_tx2_cpu", 0.05),
        ("raspberry_pi4", 0.15),
    ]
    .iter()
    .map(|&(name, w)| (profiles::by_name(name).expect("inventory is static"), w))
    .collect()
}

impl Population {
    /// Synthesize a population from the config: profiles drawn from the
    /// device mix, data sizes and availability cycles from the seed.
    pub fn synthesize(cfg: &ScheduleConfig) -> Result<Population> {
        let mix: Vec<(&'static DeviceProfile, f64)> = if cfg.device_mix.is_empty() {
            default_device_mix()
        } else {
            cfg.device_mix
                .iter()
                .map(|(name, w)| Ok((profiles::by_name(name)?, *w)))
                .collect::<Result<_>>()?
        };
        let total_w: f64 = mix.iter().map(|&(_, w)| w).sum();
        if total_w <= 0.0 || total_w.is_nan() {
            return Err(Error::Config("device mix weights must sum > 0".into()));
        }
        let availability = Availability::from_spec(cfg.churn.as_ref(), cfg.seed ^ 0xC4A2);
        let mut rng = Rng::seed_from(cfg.seed ^ 0x0F0B);
        let mut devices = Vec::with_capacity(cfg.population);
        for i in 0..cfg.population {
            let mut r = rng.f64() * total_w;
            let mut profile = mix[mix.len() - 1].0;
            for &(p, w) in &mix {
                if r < w {
                    profile = p;
                    break;
                }
                r -= w;
            }
            devices.push(VirtualDevice {
                device: profile,
                num_examples: 64 + rng.below(448) as u64,
                cycle: availability.cycle(i as u64),
                skew: rng.f64(),
                last_loss: None,
                last_selected_round: None,
            });
        }
        Ok(Population { devices })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Cohort numerics
// ---------------------------------------------------------------------------

/// Numerics backend for the selected cohort. The engine models *costs*;
/// this trait supplies the *learning*: real PJRT training
/// ([`crate::sim::population::RuntimeCohortTrainer`]) or the closed-form
/// surrogate below.
pub trait CohortTrainer {
    /// Train one round over `cohort` (indices into `pop.devices`, only
    /// the clients that actually reported). Returns per-client train
    /// losses aligned with `cohort`, plus the global (eval_loss,
    /// accuracy) after aggregation.
    fn train_round(
        &mut self,
        round: u64,
        pop: &Population,
        cohort: &[usize],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)>;

    /// One async buffer flush: `folds` pairs a reporting device index
    /// with its staleness weight in (0, 1] (`(1+s)^-alpha`). Returns the
    /// same `(losses, eval_loss, accuracy)` triple as [`train_round`],
    /// losses aligned with `folds`. The default ignores the weights;
    /// trainers that can discount stale work override it.
    ///
    /// [`train_round`]: CohortTrainer::train_round
    fn train_flush(
        &mut self,
        version: u64,
        pop: &Population,
        folds: &[(usize, f64)],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)> {
        let cohort: Vec<usize> = folds.iter().map(|&(i, _)| i).collect();
        self.train_round(version, pop, &cohort, steps_per_client)
    }
}

/// Closed-form training stand-in for population-scale runs without AOT
/// artifacts: global accuracy follows a saturating curve in cumulative
/// completed cohort steps, and per-client loss adds a device-specific
/// skew so utility-based policies have signal. Deterministic; accuracy
/// is monotone in useful work, which is exactly the property the
/// scheduler experiments measure (time-to-accuracy per policy).
#[derive(Debug, Clone)]
pub struct SurrogateTrainer {
    progress_steps: f64,
    /// Accuracy ceiling (the paper's CIFAR workload plateaus ≈ 0.68).
    pub ceiling: f64,
    /// Cohort-steps at which accuracy reaches half the ceiling.
    pub half_steps: f64,
}

impl Default for SurrogateTrainer {
    fn default() -> Self {
        SurrogateTrainer { progress_steps: 0.0, ceiling: 0.68, half_steps: 4_000.0 }
    }
}

impl SurrogateTrainer {
    /// `(eval_loss, accuracy)` at the current cumulative progress.
    fn metrics(&self) -> (f64, f64) {
        let acc = if self.progress_steps > 0.0 {
            self.ceiling * self.progress_steps / (self.progress_steps + self.half_steps)
        } else {
            0.0
        };
        (2.3 * (1.0 - acc / self.ceiling) + 0.05, acc)
    }
}

impl CohortTrainer for SurrogateTrainer {
    fn train_round(
        &mut self,
        _round: u64,
        pop: &Population,
        cohort: &[usize],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)> {
        self.progress_steps += (cohort.len() as u64 * steps_per_client) as f64;
        let (eval_loss, acc) = self.metrics();
        let losses = cohort
            .iter()
            .map(|&i| eval_loss * (0.75 + 0.5 * pop.devices[i].skew))
            .collect();
        Ok((losses, eval_loss, acc))
    }

    /// Async flush: stale folds contribute their *discounted* step count
    /// to the progress curve — the surrogate's closed-form version of
    /// "stale updates help less".
    fn train_flush(
        &mut self,
        _version: u64,
        pop: &Population,
        folds: &[(usize, f64)],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)> {
        let weight: f64 = folds.iter().map(|&(_, w)| w).sum();
        self.progress_steps += weight * steps_per_client as f64;
        let (eval_loss, acc) = self.metrics();
        let losses = folds
            .iter()
            .map(|&(i, _)| eval_loss * (0.75 + 0.5 * pop.devices[i].skew))
            .collect();
        Ok((losses, eval_loss, acc))
    }
}

// ---------------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------------

/// Everything the engine learned in one round.
#[derive(Debug, Clone, Default)]
pub struct PopulationRound {
    pub round: u64,
    /// Devices online at round start.
    pub available: usize,
    pub selected: usize,
    /// Clients whose result arrived in time (and still online).
    pub completed: usize,
    pub dropped_deadline: usize,
    pub dropped_churn: usize,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub accuracy: f64,
    /// Useful train steps (completed clients only).
    pub steps: u64,
    pub round_time_s: f64,
    pub cum_time_s: f64,
    pub round_energy_j: f64,
    /// Energy burned by dropped clients (subset of `round_energy_j`).
    pub wasted_energy_j: f64,
    /// Async mode only: mean/max staleness (model versions between a
    /// fold's dispatch and its flush) over this flush — 0 in sync rounds.
    pub mean_staleness: f64,
    pub max_staleness: u64,
    /// Async mode only: dispatches still in flight when this version
    /// flushed.
    pub in_flight: usize,
}

/// A full population-scale experiment.
#[derive(Debug, Clone)]
pub struct PopulationReport {
    pub name: String,
    pub policy: String,
    pub population: usize,
    pub rounds: Vec<PopulationRound>,
}

impl PopulationReport {
    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.accuracy).unwrap_or(f64::NAN)
    }

    pub fn total_time_s(&self) -> f64 {
        self.rounds.last().map(|r| r.cum_time_s).unwrap_or(0.0)
    }

    pub fn total_energy_j(&self) -> f64 {
        self.rounds.iter().map(|r| r.round_energy_j).sum()
    }

    pub fn wasted_energy_j(&self) -> f64 {
        self.rounds.iter().map(|r| r.wasted_energy_j).sum()
    }

    pub fn selected_total(&self) -> usize {
        self.rounds.iter().map(|r| r.selected).sum()
    }

    pub fn completed_total(&self) -> usize {
        self.rounds.iter().map(|r| r.completed).sum()
    }

    pub fn dropped_total(&self) -> usize {
        self.selected_total() - self.completed_total()
    }

    /// Fraction of selected clients whose results were usable.
    pub fn hit_rate(&self) -> f64 {
        let selected = self.selected_total();
        if selected == 0 {
            return 1.0;
        }
        self.completed_total() as f64 / selected as f64
    }

    /// Virtual time at which accuracy first reached `target`.
    pub fn time_to_accuracy_s(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.accuracy >= target)
            .map(|r| r.cum_time_s)
    }

    /// Completion-weighted mean staleness (0 for a synchronous run).
    pub fn mean_staleness(&self) -> f64 {
        let (sum, n) = self.rounds.iter().fold((0.0f64, 0u64), |(s, n), r| {
            (
                s + r.mean_staleness * r.completed as f64,
                n + r.completed as u64,
            )
        });
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// CSV export (header + one row per round).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,available,selected,completed,dropped_deadline,dropped_churn,\
             train_loss,eval_loss,accuracy,steps,round_time_s,cum_time_s,\
             round_energy_j,wasted_energy_j,mean_staleness,max_staleness,in_flight\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.6},{:.6},{:.6},{},{:.3},{:.3},{:.3},{:.3},{:.3},{},{}\n",
                r.round,
                r.available,
                r.selected,
                r.completed,
                r.dropped_deadline,
                r.dropped_churn,
                r.train_loss,
                r.eval_loss,
                r.accuracy,
                r.steps,
                r.round_time_s,
                r.cum_time_s,
                r.round_energy_j,
                r.wasted_energy_j,
                r.mean_staleness,
                r.max_staleness,
                r.in_flight,
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// How an async dispatch resolves. Everything about a dispatch is
/// modeled, so its fate is known the moment it is issued; the event is
/// queued at the time the server *learns* the outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Fold,
    DropDeadline,
    DropChurn,
}

/// A client-completion event on the virtual-time queue. `outcome` and
/// `base_version` only matter in async mode (a device is never in flight
/// twice, so `device_idx` still breaks ordering ties uniquely); in async
/// mode `finish_s` is the *resolve* time — fold at the modeled finish,
/// churn drop at the disconnect, deadline drop at τ — and `energy_j` is
/// already prorated to the work done by then.
#[derive(Debug, Clone, Copy)]
struct Completion {
    finish_s: f64,
    device_idx: usize,
    energy_j: f64,
    base_version: u64,
    outcome: Outcome,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.finish_s
            .total_cmp(&other.finish_s)
            .then(self.device_idx.cmp(&other.device_idx))
    }
}

/// The population-scale scheduler engine.
pub struct Engine<T: CohortTrainer> {
    cfg: ScheduleConfig,
    policy: Box<dyn SelectionPolicy>,
    trainer: T,
    pop: Population,
    clock_s: f64,
}

impl<T: CohortTrainer> Engine<T> {
    pub fn new(cfg: &ScheduleConfig, trainer: T) -> Result<Self> {
        cfg.validate()?;
        let policy = cfg.policy.build(cfg.seed ^ 0x5E1);
        let pop = Population::synthesize(cfg)?;
        Ok(Engine { cfg: cfg.clone(), policy, trainer, pop, clock_s: 0.0 })
    }

    pub fn population(&self) -> &Population {
        &self.pop
    }

    pub fn virtual_time_s(&self) -> f64 {
        self.clock_s
    }

    /// Run the configured number of rounds (early-stopping on the target
    /// accuracy, if set). With `cfg.async_buffer` set this runs the
    /// event-driven async mode instead — each "round" in the report is
    /// then one model version (buffer flush).
    pub fn run(mut self) -> Result<PopulationReport> {
        if self.cfg.async_buffer.is_some() {
            return self.run_async();
        }
        let mut rounds = Vec::new();
        for round in 1..=self.cfg.rounds {
            let rec = self.run_round(round)?;
            let acc = rec.accuracy;
            rounds.push(rec);
            if let Some(target) = self.cfg.target_accuracy {
                if acc >= target {
                    break;
                }
            }
        }
        Ok(PopulationReport {
            name: self.cfg.name.clone(),
            policy: self.policy.name().to_string(),
            population: self.cfg.population,
            rounds,
        })
    }

    /// Advance one round of virtual time. Public so benches can time a
    /// single round; [`Engine::run`] is the normal entry point.
    pub fn run_round(&mut self, round: u64) -> Result<PopulationRound> {
        let entry = self.clock_s;
        let steps = self.cfg.epochs.max(0) as u64 * self.cfg.steps_per_epoch;

        // 1. availability scan. Under extreme churn an instant can have
        // zero devices online; the server would simply wait, so the
        // clock fast-forwards to the next arrival instead of failing
        // (the dead air still counts toward this round's time).
        let mut now = entry;
        let mut avail: Vec<u32> = Vec::new();
        let mut rescans = 0u32;
        loop {
            for (i, d) in self.pop.devices.iter().enumerate() {
                if d.cycle.is_on(now) {
                    avail.push(i as u32);
                }
            }
            if !avail.is_empty() {
                break;
            }
            rescans += 1;
            if rescans > 1_000 {
                return Err(Error::Protocol(format!(
                    "round {round}: no devices ever available (t={now:.0}s)"
                )));
            }
            let mut dt = f64::INFINITY;
            for d in &self.pop.devices {
                // every device is offline here, so the delay is positive
                dt = dt.min(d.cycle.next_on_delay_s(now));
            }
            if !dt.is_finite() {
                return Err(Error::Protocol(format!(
                    "round {round}: no devices ever available (t={now:.0}s)"
                )));
            }
            // epsilon guards float-boundary stalls (pos == period)
            now += dt.max(1e-6);
        }

        // 2. cohort selection over available devices only
        let candidates: Vec<Candidate> = avail
            .iter()
            .map(|&i| {
                let d = &self.pop.devices[i as usize];
                Candidate {
                    device: d.device,
                    num_examples: d.num_examples,
                    last_loss: d.last_loss,
                    rounds_since_selected: d
                        .last_selected_round
                        .map(|r| round.saturating_sub(r)),
                }
            })
            .collect();
        let ctx = SelectionContext {
            round,
            cost: &self.cfg.cost,
            steps_per_round: steps,
            model_bytes: self.cfg.model_bytes,
            target_cohort: self.cfg.cohort_size,
            deadline_s: self.cfg.deadline_s,
        };
        let picked = self.policy.select(&ctx, &candidates);
        let cohort: Vec<usize> = picked.iter().map(|&j| avail[j] as usize).collect();
        if cohort.is_empty() {
            return Err(Error::Protocol(format!(
                "round {round}: policy selected no clients ({} available)",
                avail.len()
            )));
        }

        // 3. completion events over modeled costs, drained in time order
        let mut heap: BinaryHeap<Reverse<Completion>> =
            BinaryHeap::with_capacity(cohort.len());
        for &i in &cohort {
            let d = &self.pop.devices[i];
            heap.push(Reverse(Completion {
                finish_s: now + ctx.modeled_round_time_s(d.device),
                device_idx: i,
                energy_j: ctx.modeled_round_energy_j(d.device),
                base_version: 0,
                outcome: Outcome::Fold, // sync mode classifies at drain
            }));
        }
        let deadline_abs = self.cfg.deadline_s.map(|tau| now + tau);
        let mut done: Vec<Completion> = Vec::new();
        let mut dropped_deadline = 0usize;
        let mut dropped_churn = 0usize;
        let mut wasted_j = 0f64;
        let mut slowest_all = now;
        while let Some(Reverse(ev)) = heap.pop() {
            slowest_all = slowest_all.max(ev.finish_s);
            let d = &self.pop.devices[ev.device_idx];
            // The device was online at dispatch (it came from the
            // availability scan); its connection survives only until the
            // current on-dwell ends.
            let first_off_s = d.cycle.on_dwell_end_s(now);
            let round_cutoff = deadline_abs.unwrap_or(f64::INFINITY).min(ev.finish_s);
            if first_off_s < round_cutoff {
                // Went offline mid-round before it could report: its work
                // never arrives; energy burned up to the disconnect.
                dropped_churn += 1;
                let frac = ((first_off_s - now) / (ev.finish_s - now)).clamp(0.0, 1.0);
                wasted_j += ev.energy_j * frac;
            } else if let Some(dl) = deadline_abs.filter(|&dl| ev.finish_s > dl) {
                // Kept computing until τ, then the server moved on.
                dropped_deadline += 1;
                let frac = ((dl - now) / (ev.finish_s - now)).clamp(0.0, 1.0);
                wasted_j += ev.energy_j * frac;
            } else {
                done.push(ev);
            }
        }

        // 4. round closes at τ if anyone is missing, else at the slowest
        // reporter (no deadline: the server waits out the stragglers)
        let completed = done.len();
        let slowest_ok = done.iter().fold(now, |a, e| a.max(e.finish_s));
        let round_end = match deadline_abs {
            Some(dl) if completed < cohort.len() => dl,
            Some(_) => slowest_ok,
            None => slowest_all,
        };

        let mut energy_j = wasted_j;
        for ev in &done {
            energy_j += ev.energy_j;
            let wait = (round_end - ev.finish_s).max(0.0);
            energy_j += self
                .cfg
                .cost
                .idle(self.pop.devices[ev.device_idx].device, wait)
                .energy_j;
        }

        // 5. numerics for the cohort that actually reported
        let done_idx: Vec<usize> = done.iter().map(|e| e.device_idx).collect();
        let (losses, eval_loss, accuracy) =
            self.trainer.train_round(round, &self.pop, &done_idx, steps)?;
        debug_assert_eq!(losses.len(), done_idx.len());
        for (&i, &l) in done_idx.iter().zip(&losses) {
            self.pop.devices[i].last_loss = Some(l);
        }
        for &i in &cohort {
            self.pop.devices[i].last_selected_round = Some(round);
        }
        let train_loss = if losses.is_empty() {
            f64::NAN
        } else {
            losses.iter().sum::<f64>() / losses.len() as f64
        };

        // measured from round entry so availability dead air is charged
        let round_time_s = (round_end - entry) + self.cfg.cost.server_overhead_s;
        self.clock_s = entry + round_time_s;

        Ok(PopulationRound {
            round,
            available: avail.len(),
            selected: cohort.len(),
            completed,
            dropped_deadline,
            dropped_churn,
            train_loss,
            eval_loss,
            accuracy,
            steps: completed as u64 * steps,
            round_time_s,
            cum_time_s: self.clock_s,
            round_energy_j: energy_j,
            wasted_energy_j: wasted_j,
            mean_staleness: 0.0, // barrier rounds are never stale
            max_staleness: 0,
            in_flight: 0,
        })
    }

    // -----------------------------------------------------------------
    // Async (FedBuff-style) mode
    // -----------------------------------------------------------------

    /// Event-driven async mode: keep up to `effective_concurrency()`
    /// dispatches in flight, fold each device-finish event into a buffer,
    /// and flush a model version every `async_buffer` folds — no cohort
    /// barrier, so a straggler only ever delays its *own* contribution.
    /// Staleness (versions flushed between a fold's dispatch and its
    /// arrival) discounts its training weight by `(1+s)^-alpha` via
    /// [`CohortTrainer::train_flush`].
    ///
    /// `deadline_s` becomes a per-dispatch cutoff: a device that would
    /// finish more than τ after its dispatch is dropped at τ (energy up
    /// to the cutoff wasted) and its concurrency slot frees *at the
    /// cutoff*, not at the hypothetical finish — likewise a churn drop
    /// resolves at the disconnect. The virtual clock therefore never
    /// advances past the moment the server learns an outcome.
    fn run_async(mut self) -> Result<PopulationReport> {
        let k_flush = self
            .cfg
            .async_buffer
            .expect("run_async requires cfg.async_buffer");
        let alpha = self.cfg.staleness_alpha;
        let max_in_flight = self.cfg.effective_concurrency().max(1);
        let steps = self.cfg.epochs.max(0) as u64 * self.cfg.steps_per_epoch;

        let mut rounds: Vec<PopulationRound> = Vec::new();
        let mut version: u64 = 0;
        let mut now = self.clock_s;
        let mut last_flush_s = now;
        let mut in_flight = vec![false; self.pop.devices.len()];
        let mut in_flight_count = 0usize;
        let mut heap: BinaryHeap<Reverse<Completion>> = BinaryHeap::new();
        let mut buffer: Vec<(usize, u64)> = Vec::new(); // (device, staleness)
        // accumulators since the last flush
        let mut dropped_deadline = 0usize;
        let mut dropped_churn = 0usize;
        let mut wasted_j = 0f64;
        let mut energy_j = 0f64;
        let mut avail_count = 0usize;
        let mut events_since_flush = 0u64;
        let mut rescans = 0u32;

        while version < self.cfg.rounds {
            // ---- top up: keep the in-flight window full ----------------
            if in_flight_count < max_in_flight {
                let mut avail: Vec<u32> = Vec::new();
                for (i, d) in self.pop.devices.iter().enumerate() {
                    if !in_flight[i] && d.cycle.is_on(now) {
                        avail.push(i as u32);
                    }
                }
                avail_count = avail.len() + in_flight_count;
                if !avail.is_empty() {
                    let candidates: Vec<Candidate> = avail
                        .iter()
                        .map(|&i| {
                            let d = &self.pop.devices[i as usize];
                            Candidate {
                                device: d.device,
                                num_examples: d.num_examples,
                                last_loss: d.last_loss,
                                rounds_since_selected: d
                                    .last_selected_round
                                    .map(|r| (version + 1).saturating_sub(r)),
                            }
                        })
                        .collect();
                    let ctx = SelectionContext {
                        round: version + 1,
                        cost: &self.cfg.cost,
                        steps_per_round: steps,
                        model_bytes: self.cfg.model_bytes,
                        target_cohort: max_in_flight - in_flight_count,
                        deadline_s: self.cfg.deadline_s,
                    };
                    let picked = self.policy.select(&ctx, &candidates);
                    for j in picked {
                        let i = avail[j] as usize;
                        let (full_finish_s, full_energy_j, first_off_s) = {
                            let d = &self.pop.devices[i];
                            (
                                now + ctx.modeled_round_time_s(d.device),
                                ctx.modeled_round_energy_j(d.device),
                                // online at dispatch; the connection
                                // survives only to this on-dwell's end
                                d.cycle.on_dwell_end_s(now),
                            )
                        };
                        let deadline_abs = self
                            .cfg
                            .deadline_s
                            .map(|tau| now + tau)
                            .unwrap_or(f64::INFINITY);
                        // The dispatch's fate is fully modeled, so decide
                        // it now and queue the event at the moment the
                        // server *learns* it: a doomed dispatch frees its
                        // slot at the cutoff and never drags the clock to
                        // its hypothetical finish.
                        let (resolve_s, outcome) = if first_off_s
                            < deadline_abs.min(full_finish_s)
                        {
                            (first_off_s, Outcome::DropChurn)
                        } else if full_finish_s > deadline_abs {
                            (deadline_abs, Outcome::DropDeadline)
                        } else {
                            (full_finish_s, Outcome::Fold)
                        };
                        // energy up to the resolve point (all of it for a
                        // fold, the burned fraction for a drop)
                        let frac =
                            ((resolve_s - now) / (full_finish_s - now)).clamp(0.0, 1.0);
                        in_flight[i] = true;
                        in_flight_count += 1;
                        self.pop.devices[i].last_selected_round = Some(version + 1);
                        heap.push(Reverse(Completion {
                            finish_s: resolve_s,
                            device_idx: i,
                            energy_j: full_energy_j * frac,
                            base_version: version,
                            outcome,
                        }));
                    }
                }
            }

            // ---- drain one completion event ----------------------------
            let Some(Reverse(ev)) = heap.pop() else {
                // Nothing in flight. Every *built-in* policy dispatches
                // at least one online candidate, so this means nobody is
                // online — but a custom policy may decline; diagnose that
                // accurately (like the sync loop) instead of blaming
                // availability.
                let online = self
                    .pop
                    .devices
                    .iter()
                    .filter(|d| d.cycle.is_on(now))
                    .count();
                if online > 0 {
                    return Err(Error::Protocol(format!(
                        "async version {}: policy selected no clients \
                         ({online} available)",
                        version + 1
                    )));
                }
                // Nobody online: fast-forward to the next device arrival
                // (the dead air is charged to the flush in progress,
                // exactly like the sync loop).
                rescans += 1;
                if rescans > 1_000 {
                    return Err(Error::Protocol(format!(
                        "async version {}: no devices ever available (t={now:.0}s)",
                        version + 1
                    )));
                }
                let mut dt = f64::INFINITY;
                for d in &self.pop.devices {
                    dt = dt.min(d.cycle.next_on_delay_s(now));
                }
                if !dt.is_finite() {
                    return Err(Error::Protocol(format!(
                        "async version {}: no devices ever available (t={now:.0}s)",
                        version + 1
                    )));
                }
                now += dt.max(1e-6);
                continue;
            };
            rescans = 0;
            events_since_flush += 1;
            if events_since_flush > 10_000u64.max(1_000 * k_flush as u64) {
                return Err(Error::Protocol(format!(
                    "async version {}: buffer starved ({} events without {} \
                     usable folds — deadline/churn drop everything)",
                    version + 1,
                    events_since_flush,
                    k_flush
                )));
            }
            now = now.max(ev.finish_s);
            let i = ev.device_idx;
            in_flight[i] = false;
            in_flight_count -= 1;
            energy_j += ev.energy_j;
            match ev.outcome {
                Outcome::Fold => buffer.push((i, version - ev.base_version)),
                Outcome::DropChurn => {
                    dropped_churn += 1;
                    wasted_j += ev.energy_j;
                }
                Outcome::DropDeadline => {
                    dropped_deadline += 1;
                    wasted_j += ev.energy_j;
                }
            }

            // ---- flush: a new model version every K folds --------------
            if buffer.len() >= k_flush {
                version += 1;
                let folds: Vec<(usize, f64)> = buffer
                    .iter()
                    .map(|&(i, s)| (i, crate::strategy::fedbuff::staleness_discount(s, alpha)))
                    .collect();
                let (losses, eval_loss, accuracy) =
                    self.trainer.train_flush(version, &self.pop, &folds, steps)?;
                debug_assert_eq!(losses.len(), buffer.len());
                for (&(di, _), &l) in buffer.iter().zip(&losses) {
                    self.pop.devices[di].last_loss = Some(l);
                }
                let completed = buffer.len();
                let staleness_sum: u64 = buffer.iter().map(|&(_, s)| s).sum();
                let max_staleness = buffer.iter().map(|&(_, s)| s).max().unwrap_or(0);
                let train_loss = if losses.is_empty() {
                    f64::NAN
                } else {
                    losses.iter().sum::<f64>() / losses.len() as f64
                };
                let round_time_s = (now - last_flush_s) + self.cfg.cost.server_overhead_s;
                now += self.cfg.cost.server_overhead_s;
                last_flush_s = now;
                self.clock_s = now;
                rounds.push(PopulationRound {
                    round: version,
                    available: avail_count,
                    // resolution-based, like the sync loop's accounting:
                    // dispatches *settled* this window (folds + drops), so
                    // selected - completed = drops and hit_rate/dropped
                    // keep their meaning; outstanding work is `in_flight`
                    selected: completed + dropped_deadline + dropped_churn,
                    completed,
                    dropped_deadline,
                    dropped_churn,
                    train_loss,
                    eval_loss,
                    accuracy,
                    steps: completed as u64 * steps,
                    round_time_s,
                    cum_time_s: self.clock_s,
                    round_energy_j: energy_j,
                    wasted_energy_j: wasted_j,
                    mean_staleness: staleness_sum as f64 / completed as f64,
                    max_staleness,
                    in_flight: in_flight_count,
                });
                buffer.clear();
                dropped_deadline = 0;
                dropped_churn = 0;
                wasted_j = 0.0;
                energy_j = 0.0;
                events_since_flush = 0;
                if let Some(target) = self.cfg.target_accuracy {
                    if accuracy >= target {
                        break;
                    }
                }
            }
        }
        self.clock_s = now;
        Ok(PopulationReport {
            name: self.cfg.name.clone(),
            policy: self.policy.name().to_string(),
            population: self.cfg.population,
            rounds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyConfig, ScheduleConfig};
    use crate::sched::availability::ChurnSpec;

    fn cfg() -> ScheduleConfig {
        ScheduleConfig::default()
            .named("engine-test")
            .population(2_000)
            .cohort(50)
            .rounds(5)
            .seed(7)
    }

    #[test]
    fn rounds_advance_virtual_time_and_accuracy() {
        let report = Engine::new(&cfg(), SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 5);
        assert!(report.rounds.windows(2).all(|w| w[1].cum_time_s > w[0].cum_time_s));
        assert!(report.rounds.windows(2).all(|w| w[1].accuracy >= w[0].accuracy));
        assert!(report.final_accuracy() > 0.0);
        // no deadline, no churn: everyone selected completes
        assert!(report.rounds.iter().all(|r| r.completed == r.selected));
        assert_eq!(report.dropped_total(), 0);
        assert!(report.wasted_energy_j() == 0.0);
        assert!(report.total_energy_j() > 0.0);
    }

    #[test]
    fn deadline_drops_stragglers_and_wastes_energy() {
        // 8 steps ≈ 11.8 s on TX2 GPU, ≈ 71 s on the RPi; τ = 30 s drops
        // every RPi a uniform policy happens to pick.
        let c = cfg()
            .policy(PolicyConfig::Uniform)
            .deadline(Some(30.0))
            .rounds(6);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert!(report.dropped_total() > 0, "no drops under a tight τ");
        assert!(report.wasted_energy_j() > 0.0);
        assert!(report.hit_rate() < 1.0);
        // the round can never run past τ + server overhead (1 s default)
        assert!(report.rounds.iter().all(|r| r.round_time_s <= 31.0 + 1e-9));
        // accounting invariant
        for r in &report.rounds {
            assert_eq!(r.completed + r.dropped_deadline + r.dropped_churn, r.selected);
        }
    }

    #[test]
    fn churn_rotates_availability() {
        let c = cfg()
            .population(5_000)
            .churn(Some(ChurnSpec { mean_on_s: 500.0, mean_off_s: 500.0 }))
            .rounds(8);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        for r in &report.rounds {
            assert!(
                r.available > 1_000 && r.available < 4_000,
                "round {}: available={} of 5000",
                r.round,
                r.available
            );
        }
    }

    #[test]
    fn dead_air_fast_forwards_instead_of_failing() {
        // duty ≈ 0.1%: most scan instants have zero devices online, so
        // the engine must jump the clock to the next arrival, not error.
        let c = cfg()
            .population(50)
            .cohort(5)
            .rounds(8)
            .seed(11)
            .churn(Some(ChurnSpec { mean_on_s: 10.0, mean_off_s: 10_000.0 }));
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 8);
        assert!(report.rounds.iter().all(|r| r.available >= 1));
        assert!(report
            .rounds
            .windows(2)
            .all(|w| w[1].cum_time_s > w[0].cum_time_s));
    }

    #[test]
    fn engine_runs_are_deterministic() {
        let c = cfg().policy(PolicyConfig::UtilityBased { alpha: 2.0, explore_frac: 0.2 });
        let a = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let b = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn target_accuracy_stops_early() {
        let mut c = cfg().rounds(50);
        c.target_accuracy = Some(0.3);
        let report = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert!(report.rounds.len() < 50);
        assert!(report.final_accuracy() >= 0.3);
    }

    #[test]
    fn async_mode_flushes_versions_and_tracks_staleness() {
        let c = cfg().buffered(8).rounds(10);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 10);
        for r in &report.rounds {
            assert_eq!(r.completed, 8, "every flush folds exactly K results");
            assert!(r.round_time_s > 0.0);
            assert!(r.in_flight <= c.effective_concurrency());
        }
        assert!(report
            .rounds
            .windows(2)
            .all(|w| w[1].cum_time_s > w[0].cum_time_s));
        assert!(report
            .rounds
            .windows(2)
            .all(|w| w[1].accuracy >= w[0].accuracy));
        // the default mix is heterogeneous (RPi 6× slower than TX2 GPU):
        // versions flush while stragglers are still in flight, so some
        // folds must land stale
        assert!(
            report.rounds.iter().any(|r| r.max_staleness > 0),
            "no stale folds despite a heterogeneous mix"
        );
        assert!(report.mean_staleness() > 0.0);
        // no deadline, no churn: nothing is dropped in async mode either
        assert_eq!(report.dropped_total(), 0);
        assert_eq!(report.wasted_energy_j(), 0.0);
    }

    #[test]
    fn async_runs_are_deterministic() {
        let c = cfg().buffered(8).rounds(8).seed(23);
        let a = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let b = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn async_deadline_drops_per_dispatch_but_still_flushes() {
        // τ = 30 s drops every RPi/Pixel-2 dispatch (modeled 33–71 s)
        // while the fast classes keep the buffer filling. 20 versions so
        // the run outlasts the slow events (first drop pops at ≈ 31 s).
        let c = cfg().buffered(4).deadline(Some(30.0)).rounds(20);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 20);
        assert!(report.dropped_total() > 0, "no drops under a tight τ");
        assert!(report.wasted_energy_j() > 0.0);
        // accounting invariant, same shape as the sync loop: every
        // settled dispatch either folded or was dropped
        for r in &report.rounds {
            assert_eq!(r.completed, 4);
            assert_eq!(r.completed + r.dropped_deadline + r.dropped_churn, r.selected);
        }
        assert!(report.hit_rate() < 1.0);
    }

    #[test]
    fn async_mode_survives_churn() {
        let c = cfg()
            .population(2_000)
            .buffered(8)
            .churn(Some(ChurnSpec { mean_on_s: 500.0, mean_off_s: 500.0 }))
            .rounds(6);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 6);
        assert!(report.rounds.iter().all(|r| r.completed == 8));
    }

    #[test]
    fn async_target_accuracy_stops_early() {
        let mut c = cfg().buffered(8).rounds(500);
        c.target_accuracy = Some(0.3);
        let report = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert!(report.rounds.len() < 500);
        assert!(report.final_accuracy() >= 0.3);
    }

    #[test]
    fn population_synthesis_honors_mix_and_seed() {
        let mut c = cfg().population(10_000);
        c.device_mix = vec![("pixel4".into(), 3.0), ("raspberry_pi4".into(), 1.0)];
        let pop = Population::synthesize(&c).unwrap();
        assert_eq!(pop.len(), 10_000);
        let pixels = pop.devices.iter().filter(|d| d.device.name == "pixel4").count();
        assert!(
            (7_000..8_000).contains(&pixels),
            "pixel share {pixels} off the 3:1 mix"
        );
        let again = Population::synthesize(&c).unwrap();
        assert_eq!(pop.devices.len(), again.devices.len());
        assert!(pop
            .devices
            .iter()
            .zip(&again.devices)
            .all(|(a, b)| a.device.name == b.device.name && a.num_examples == b.num_examples));
    }

    #[test]
    fn unknown_device_in_mix_rejected() {
        let mut c = cfg();
        c.device_mix = vec![("nokia3310".into(), 1.0)];
        assert!(Population::synthesize(&c).is_err());
    }
}
