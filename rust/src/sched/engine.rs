//! The event-driven population engine: virtual federations of 100k–1M
//! devices, scheduled in virtual time by **one** execution core.
//!
//! The in-proc simulator ([`crate::sim::run_experiment`]) runs one OS
//! thread per client and tops out at tens of devices. This engine flips
//! the representation: the *population* is a flat array of cost profiles
//! and availability cycles, execution is a binary-heap event queue over
//! modeled completion times, and only the selected cohort trains
//! numerics — either for real through a [`CohortTrainer`] backed by the
//! PJRT runtime ([`crate::sim::population`]) or through the closed-form
//! [`SurrogateTrainer`]. A 100k-device round is a few milliseconds of
//! wall clock; a 1M-device experiment completes in seconds.
//!
//! Synchronous FedAvg and FedBuff-style async are *the same loop*
//! parameterized by [`ExecMode`]: every dispatch's fate is modeled at
//! issue time, settles as one event, folds into a buffer, and the buffer
//! flushes into a model version —
//!
//! * [`ExecMode::Sync`] is the degenerate case of buffered async: the
//!   buffer is the whole cohort, the flush is the round barrier, events
//!   resolve at their full modeled finish (the server waits), and every
//!   fold has staleness 0 so its weight is exactly 1.
//! * [`ExecMode::Async`] streams: a bounded window of dispatches stays
//!   in flight (topped up per event through the O(1)-amortized
//!   [`AvailabilityIndex`]), each event resolves the moment the server
//!   *learns* the outcome (fold at the finish, drop at the τ cutoff or
//!   the disconnect), and every `k_flush` folds flush a staleness-
//!   discounted model version.
//!
//! Availability cost: the barrier mode scans availability once per round
//! (the O(population) candidate build dominates anyway); the streaming
//! mode advances the incremental index instead, so per-event top-up no
//! longer rescans the population — the hot-path win the 1M-device bench
//! and CI smoke pin down.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::config::{EdgeAssignment, ScheduleConfig};
use crate::device::{profiles, DeviceProfile};
use crate::error::{Error, Result};
use crate::obs::{Event, Fate, NullSink, ObsSink};
use crate::persist::{
    CheckpointStore, DeviceState, EdgeParkedFold, EdgeTierState, EngineCheckpoint,
    InFlightDispatch, ShardSeeds,
};
use crate::telemetry::log;
use crate::util::par;
use crate::util::rng::{Rng, RngState};

use super::availability::{shard_map, shard_min_by, shard_scan_indices};
use super::availability::{AvailabilityIndex, DeviceSchedule};
use super::policy::{Candidate, SelectionContext, SelectionPolicy};
use super::trace::AvailabilitySource;

// ---------------------------------------------------------------------------
// Population
// ---------------------------------------------------------------------------

/// One virtual device: a cost profile, an availability schedule
/// (synthetic cycle or recorded trace), and the scheduler-visible
/// training history.
#[derive(Debug, Clone)]
pub struct VirtualDevice {
    pub device: &'static DeviceProfile,
    pub num_examples: u64,
    pub schedule: DeviceSchedule,
    /// Data-difficulty skew in [0, 1): gives utility policies per-client
    /// signal under the surrogate trainer.
    pub skew: f64,
    pub last_loss: Option<f64>,
    pub last_selected_round: Option<u64>,
    /// Lifetime selection count (fairness policies cap this).
    pub times_selected: u64,
}

/// The whole virtual federation.
#[derive(Debug, Clone, Default)]
pub struct Population {
    pub devices: Vec<VirtualDevice>,
}

/// The default population mix when the config doesn't pin one: phones
/// dominate, with tablet / embedded / SBC tails (paper Table 1 hardware).
pub fn default_device_mix() -> Vec<(&'static DeviceProfile, f64)> {
    [
        ("pixel4", 0.20),
        ("pixel3", 0.20),
        ("pixel2", 0.15),
        ("galaxy_tab_s6", 0.10),
        ("galaxy_tab_s4", 0.10),
        ("jetson_tx2_gpu", 0.05),
        ("jetson_tx2_cpu", 0.05),
        ("raspberry_pi4", 0.15),
    ]
    .iter()
    .map(|&(name, w)| (profiles::by_name(name).expect("inventory is static"), w))
    .collect()
}

impl Population {
    /// Synthesize a population from the config: profiles drawn from the
    /// device mix, data sizes from the seed, and availability schedules
    /// from the configured [`AvailabilitySource`] (churn model, trace
    /// file, or scenario generator). Devices a trace tags with a
    /// hardware class get that profile instead of a mix draw — the mix
    /// draw is still consumed so class tags never shift other devices'
    /// random streams.
    pub fn synthesize(cfg: &ScheduleConfig) -> Result<Population> {
        let mix: Vec<(&'static DeviceProfile, f64)> = if cfg.device_mix.is_empty() {
            default_device_mix()
        } else {
            cfg.device_mix
                .iter()
                .map(|(name, w)| Ok((profiles::by_name(name)?, *w)))
                .collect::<Result<_>>()?
        };
        let total_w: f64 = mix.iter().map(|&(_, w)| w).sum();
        if total_w <= 0.0 || total_w.is_nan() {
            return Err(Error::Config("device mix weights must sum > 0".into()));
        }
        let source = AvailabilitySource::from_config(cfg)?;
        let rng = Rng::seed_from(cfg.seed ^ 0x0F0B);
        // Parallel synthesis is a pure execution detail: shard-start RNG
        // states are *positions in the one canonical stream* (recorded by
        // fast-forwarding it), never independently seeded — so the
        // population is bit-identical to the sequential build for every
        // worker count, and a checkpoint written under `--workers 1` can
        // resume under `--workers 8` (and vice versa).
        let workers = par::workers().min(cfg.population.max(1));
        let ranges = par::shard_ranges(cfg.population, workers);
        let starts = synthesis_shard_starts(&rng, &ranges);
        let shards = par::run_sharded(ranges.len(), |s| {
            let (lo, hi) = ranges[s];
            let mut rng = Rng::restore(&starts[s]);
            let built = synthesize_range(&mix, total_w, &source, &mut rng, lo, hi);
            (built, rng.state())
        });
        // Continuity proof (debug builds): each shard consumed exactly its
        // slice of the canonical stream, so its end state is the next
        // shard's recorded start.
        for (s, (_, end)) in shards.iter().enumerate().take(ranges.len() - 1) {
            debug_assert_eq!(
                end.s, starts[s + 1].s,
                "synthesis shard {s} drifted off the canonical RNG stream"
            );
        }
        let mut devices = Vec::with_capacity(cfg.population);
        for (shard, _) in shards {
            devices.extend(shard);
        }
        Ok(Population { devices })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }
}

/// Record the canonical synthesis stream's state at each shard start by
/// replaying the exact per-device draw pattern of
/// [`Population::synthesize`] — profile-mix `f64`, example-count
/// `below(448)`, skew `f64`. The last shard's range is not replayed
/// (nobody starts after it), so the single-shard case does no extra work.
fn synthesis_shard_starts(rng: &Rng, ranges: &[(usize, usize)]) -> Vec<RngState> {
    let mut rng = Rng::restore(&rng.state());
    let mut starts = Vec::with_capacity(ranges.len());
    for (k, &(lo, hi)) in ranges.iter().enumerate() {
        starts.push(rng.state());
        if k + 1 == ranges.len() {
            break;
        }
        for _ in lo..hi {
            rng.f64();
            rng.below(448);
            rng.f64();
        }
    }
    starts
}

/// Synthesize devices `lo..hi` from an RNG positioned at device `lo` of
/// the canonical stream — the body of the original sequential loop,
/// range-parameterized so shards can run it concurrently.
fn synthesize_range(
    mix: &[(&'static DeviceProfile, f64)],
    total_w: f64,
    source: &AvailabilitySource,
    rng: &mut Rng,
    lo: usize,
    hi: usize,
) -> Vec<VirtualDevice> {
    let mut devices = Vec::with_capacity(hi - lo);
    for i in lo..hi {
        let mut r = rng.f64() * total_w;
        let mut profile = mix[mix.len() - 1].0;
        for &(p, w) in mix {
            if r < w {
                profile = p;
                break;
            }
            r -= w;
        }
        if let Some(class) = source.class(i as u64) {
            profile = class;
        }
        devices.push(VirtualDevice {
            device: profile,
            num_examples: 64 + rng.below(448) as u64,
            schedule: source.schedule(i as u64),
            skew: rng.f64(),
            last_loss: None,
            last_selected_round: None,
            times_selected: 0,
        });
    }
    devices
}

/// The parallel-synthesis audit record persisted in checkpoints
/// ([`ShardSeeds`]): the canonical stream's shard-start states for
/// `workers` shards of `cfg`'s population. A resume recomputes this for
/// the checkpoint's recorded worker count and refuses to run if the
/// states diverge — pinning the "shard streams are fast-forward
/// positions, not independent seeds" contract across versions.
pub(crate) fn synthesis_shard_seeds(cfg: &ScheduleConfig, workers: usize) -> ShardSeeds {
    let workers = workers.max(1).min(cfg.population.max(1));
    let ranges = par::shard_ranges(cfg.population, workers);
    let rng = Rng::seed_from(cfg.seed ^ 0x0F0B);
    ShardSeeds {
        workers: workers as u64,
        starts: synthesis_shard_starts(&rng, &ranges),
    }
}

// ---------------------------------------------------------------------------
// Cohort numerics
// ---------------------------------------------------------------------------

/// Numerics backend for the selected cohort. The engine models *costs*;
/// this trait supplies the *learning*: real PJRT training
/// ([`crate::sim::population::RuntimeCohortTrainer`]) or the closed-form
/// surrogate below.
///
/// There is exactly one numeric entry point — [`train_flush`] — shared
/// by both execution modes: a barrier round is a flush whose folds all
/// carry weight 1.0 ([`train_round`] is the provided wrapper that says
/// so). That is what makes FedAvg the degenerate case of FedBuff at the
/// trainer layer, with no parallel arithmetic path to drift.
///
/// [`train_flush`]: CohortTrainer::train_flush
/// [`train_round`]: CohortTrainer::train_round
pub trait CohortTrainer {
    /// One aggregation step: `folds` pairs a reporting device index
    /// (into `pop.devices`) with its fold weight in (0, 1] — the
    /// staleness discount `(1+s)^-alpha` in async mode, exactly 1.0 in a
    /// barrier round. Returns per-client train losses aligned with
    /// `folds`, plus the global (eval_loss, accuracy) after aggregation.
    fn train_flush(
        &mut self,
        version: u64,
        pop: &Population,
        folds: &[(usize, f64)],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)>;

    /// Barrier round over `cohort`: every fold carries weight 1.0.
    /// Provided so synchronous callers funnel through the same numeric
    /// kernel as async flushes.
    fn train_round(
        &mut self,
        round: u64,
        pop: &Population,
        cohort: &[usize],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)> {
        let folds: Vec<(usize, f64)> = cohort.iter().map(|&i| (i, 1.0)).collect();
        self.train_flush(round, pop, &folds, steps_per_client)
    }

    /// Checkpointing hook: serialize the trainer's mutable numeric
    /// state (an opaque blob; format is the trainer's own). The default
    /// `None` marks the trainer as not checkpointable — the engine then
    /// refuses to write a checkpoint rather than writing one that
    /// cannot restore the numerics.
    fn checkpoint_state(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore state captured by [`CohortTrainer::checkpoint_state`].
    /// The default errors, matching the default `None` above.
    fn restore_state(&mut self, _state: &[u8]) -> Result<()> {
        Err(Error::Persist(
            "this CohortTrainer does not support checkpoint restore".into(),
        ))
    }
}

/// Closed-form training stand-in for population-scale runs without AOT
/// artifacts: global accuracy follows a saturating curve in cumulative
/// completed (staleness-weighted) cohort steps, and per-client loss adds
/// a device-specific skew so utility-based policies have signal.
/// Deterministic; accuracy is monotone in useful work, which is exactly
/// the property the scheduler experiments measure (time-to-accuracy per
/// policy).
#[derive(Debug, Clone)]
pub struct SurrogateTrainer {
    progress_steps: f64,
    /// Accuracy ceiling (the paper's CIFAR workload plateaus ≈ 0.68).
    pub ceiling: f64,
    /// Cohort-steps at which accuracy reaches half the ceiling.
    pub half_steps: f64,
}

impl Default for SurrogateTrainer {
    fn default() -> Self {
        SurrogateTrainer { progress_steps: 0.0, ceiling: 0.68, half_steps: 4_000.0 }
    }
}

impl SurrogateTrainer {
    /// `(eval_loss, accuracy)` at the current cumulative progress.
    fn metrics(&self) -> (f64, f64) {
        let acc = if self.progress_steps > 0.0 {
            self.ceiling * self.progress_steps / (self.progress_steps + self.half_steps)
        } else {
            0.0
        };
        (2.3 * (1.0 - acc / self.ceiling) + 0.05, acc)
    }
}

impl CohortTrainer for SurrogateTrainer {
    /// Each fold contributes its *weighted* step count to the progress
    /// curve — the surrogate's closed-form version of "stale updates
    /// help less"; barrier folds (weight 1.0) contribute fully.
    fn train_flush(
        &mut self,
        _version: u64,
        pop: &Population,
        folds: &[(usize, f64)],
        steps_per_client: u64,
    ) -> Result<(Vec<f64>, f64, f64)> {
        let weight: f64 = folds.iter().map(|&(_, w)| w).sum();
        self.progress_steps += weight * steps_per_client as f64;
        let (eval_loss, acc) = self.metrics();
        let losses = folds
            .iter()
            .map(|&(i, _)| eval_loss * (0.75 + 0.5 * pop.devices[i].skew))
            .collect();
        Ok((losses, eval_loss, acc))
    }

    /// The surrogate's whole state is its closed-form curve position:
    /// three f64s, stored as raw bits so resume is bit-exact.
    fn checkpoint_state(&self) -> Option<Vec<u8>> {
        let mut e = crate::persist::Enc::new();
        e.f64(self.progress_steps);
        e.f64(self.ceiling);
        e.f64(self.half_steps);
        Some(e.into_bytes())
    }

    fn restore_state(&mut self, state: &[u8]) -> Result<()> {
        let mut d = crate::persist::Dec::new(state);
        self.progress_steps = d.f64()?;
        self.ceiling = d.f64()?;
        self.half_steps = d.f64()?;
        d.done()
    }
}

// ---------------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------------

/// Everything the engine learned in one round (barrier mode) or one
/// model version (async mode).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PopulationRound {
    pub round: u64,
    /// Devices online at round start (sync) / at the last top-up (async,
    /// including in-flight).
    pub available: usize,
    pub selected: usize,
    /// Clients whose result arrived in time (and still online).
    pub completed: usize,
    pub dropped_deadline: usize,
    pub dropped_churn: usize,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub accuracy: f64,
    /// Useful train steps (completed clients only).
    pub steps: u64,
    pub round_time_s: f64,
    pub cum_time_s: f64,
    pub round_energy_j: f64,
    /// Energy burned by dropped clients (subset of `round_energy_j`).
    pub wasted_energy_j: f64,
    /// Async mode only: mean/max staleness (model versions between a
    /// fold's dispatch and its flush) over this flush — 0 in sync rounds.
    pub mean_staleness: f64,
    pub max_staleness: u64,
    /// Async mode only: dispatches still in flight when this version
    /// flushed.
    pub in_flight: usize,
    /// Downlink wire bytes this round (every dispatch issued in the
    /// window, including ones that later drop — the broadcast is spent
    /// either way), from the strategy's wire model.
    pub bytes_down: u64,
    /// Uplink wire bytes this round (folded results only; a dropped
    /// client never completes its upload).
    pub bytes_up: u64,
}

/// A full population-scale experiment.
#[derive(Debug, Clone)]
pub struct PopulationReport {
    pub name: String,
    pub policy: String,
    pub population: usize,
    pub rounds: Vec<PopulationRound>,
}

impl PopulationReport {
    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.accuracy).unwrap_or(f64::NAN)
    }

    pub fn total_time_s(&self) -> f64 {
        self.rounds.last().map(|r| r.cum_time_s).unwrap_or(0.0)
    }

    pub fn total_energy_j(&self) -> f64 {
        self.rounds.iter().map(|r| r.round_energy_j).sum()
    }

    pub fn wasted_energy_j(&self) -> f64 {
        self.rounds.iter().map(|r| r.wasted_energy_j).sum()
    }

    pub fn selected_total(&self) -> usize {
        self.rounds.iter().map(|r| r.selected).sum()
    }

    pub fn completed_total(&self) -> usize {
        self.rounds.iter().map(|r| r.completed).sum()
    }

    pub fn dropped_total(&self) -> usize {
        self.selected_total() - self.completed_total()
    }

    /// Fraction of selected clients whose results were usable.
    pub fn hit_rate(&self) -> f64 {
        let selected = self.selected_total();
        if selected == 0 {
            return 1.0;
        }
        self.completed_total() as f64 / selected as f64
    }

    /// Virtual time at which accuracy first reached `target`.
    pub fn time_to_accuracy_s(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.accuracy >= target)
            .map(|r| r.cum_time_s)
    }

    /// Completion-weighted mean staleness (0 for a synchronous run).
    pub fn mean_staleness(&self) -> f64 {
        let (sum, n) = self.rounds.iter().fold((0.0f64, 0u64), |(s, n), r| {
            (
                s + r.mean_staleness * r.completed as f64,
                n + r.completed as u64,
            )
        });
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Total downlink + uplink wire bytes across the run.
    pub fn total_bytes(&self) -> u64 {
        self.rounds.iter().map(|r| r.bytes_down + r.bytes_up).sum()
    }

    /// CSV export (header + one row per round).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,available,selected,completed,dropped_deadline,dropped_churn,\
             train_loss,eval_loss,accuracy,steps,round_time_s,cum_time_s,\
             round_energy_j,wasted_energy_j,mean_staleness,max_staleness,in_flight,\
             bytes_down,bytes_up\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{:.6},{:.6},{:.6},{},{:.3},{:.3},{:.3},{:.3},{:.3},{},{},{},{}\n",
                r.round,
                r.available,
                r.selected,
                r.completed,
                r.dropped_deadline,
                r.dropped_churn,
                r.train_loss,
                r.eval_loss,
                r.accuracy,
                r.steps,
                r.round_time_s,
                r.cum_time_s,
                r.round_energy_j,
                r.wasted_energy_j,
                r.mean_staleness,
                r.max_staleness,
                r.in_flight,
                r.bytes_down,
                r.bytes_up,
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// The unified execution core
// ---------------------------------------------------------------------------

/// How the single virtual-time loop executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Barrier rounds: dispatch a full cohort, settle every dispatch,
    /// flush once per round — buffered async degenerated to K = cohort
    /// size with zero staleness.
    Sync,
    /// FedBuff streaming: a bounded window stays in flight, a model
    /// version flushes every `k_flush` folds.
    Async { k_flush: usize },
}

/// How a dispatch resolves. Everything about a dispatch is modeled, so
/// its fate is known the moment it is issued; the event is queued at the
/// time the server *learns* the outcome (async) or at the modeled finish
/// (sync — the barrier waits regardless).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Fold,
    DropDeadline,
    DropChurn,
}

/// A dispatch-resolution event on the virtual-time queue. A device is
/// never in flight twice, so `device_idx` breaks ordering ties uniquely.
/// `energy_j` is already prorated to the work done by `resolve_s` (all
/// of it for a fold, the burned fraction for a drop).
#[derive(Debug, Clone, Copy)]
struct Completion {
    resolve_s: f64,
    device_idx: usize,
    energy_j: f64,
    base_version: u64,
    outcome: Outcome,
}

impl PartialEq for Completion {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Completion {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.resolve_s
            .total_cmp(&other.resolve_s)
            .then(self.device_idx.cmp(&other.device_idx))
    }
}

/// One buffered (arrived, usable) result awaiting the next flush.
#[derive(Debug, Clone, Copy)]
struct BufferedFold {
    device_idx: usize,
    staleness: u64,
    resolve_s: f64,
}

/// One device fold parked at an edge aggregator (async mode) awaiting
/// the edge's ship quorum. Staleness is deliberately *not* stored —
/// it is computed at ship time, so a fold that sits at its edge across
/// a cloud flush ages (the per-edge staleness the two-tier scenarios
/// measure).
#[derive(Debug, Clone, Copy)]
struct EdgeBuffered {
    device_idx: usize,
    base_version: u64,
    resolve_s: f64,
}

/// Engine-side state of the edge-aggregator tier (`--edges N`, N > 1).
/// The engine holds it as an `Option`: `None` is the flat single-tier
/// shape, and every tier hook lives behind that `Option` — the
/// structural guarantee that `--edges 1` runs are byte-identical to the
/// pre-tier engine (CSV, events.jsonl, costs.csv). Normative semantics
/// live in `rust/src/sched/TOPOLOGY.md`.
struct EdgeTier {
    edges: usize,
    assignment: EdgeAssignment,
    population: usize,
    /// Edge↔cloud leg payload, each way: always the full f32 tensor.
    /// The device-leg strategy (f16, secagg framing) stops at the edge —
    /// an edge folds its shard locally and ships one dense model
    /// upstream regardless of how its devices talked to it.
    leg_bytes: u64,
    /// Async ship quorum per edge: `max(1, k_flush.div_ceil(edges))`.
    /// Unused (0) in sync mode, where the barrier is the ship point.
    quorum: usize,
    /// Async: folds parked per edge awaiting the ship quorum.
    buffers: Vec<Vec<EdgeBuffered>>,
    /// Which model version each edge last pulled (`u64::MAX` = never):
    /// one cloud→edge broadcast per version per alive edge, booked at
    /// the first member dispatch. Deliberately *not* checkpointed — at
    /// a flush boundary every entry is stale relative to the
    /// just-incremented version, so a resumed engine re-books the next
    /// broadcast exactly like the uninterrupted one.
    seen_version: Vec<u64>,
    alive: Vec<bool>,
    /// Pending `--edge-fail E@T` injection; cleared once applied (the
    /// `alive` flag then carries the death permanently, checkpoints
    /// included).
    fail: Option<(usize, f64)>,
}

impl EdgeTier {
    fn new(cfg: &ScheduleConfig, quorum: usize) -> EdgeTier {
        EdgeTier {
            edges: cfg.edges,
            assignment: cfg.edge_assignment,
            population: cfg.population,
            // Symmetric leg; either direction of `edge_leg` is the payload.
            leg_bytes: crate::strategy::wire::WireModel::edge_leg(cfg.model_bytes as u64).bytes_up,
            quorum,
            buffers: vec![Vec::new(); cfg.edges],
            seen_version: vec![u64::MAX; cfg.edges],
            alive: vec![true; cfg.edges],
            fail: cfg.edge_fail.map(|(e, t)| (e as usize, t)),
        }
    }

    /// Which edge owns `device_idx` — a pure integer function of the
    /// index (see [`EdgeAssignment`]), mirrored verbatim by the Python
    /// differential port.
    fn edge_of(&self, device_idx: usize) -> usize {
        match self.assignment {
            EdgeAssignment::RoundRobin => device_idx % self.edges,
            EdgeAssignment::Skew => {
                let mut start = 0usize;
                for e in 0..self.edges - 1 {
                    let share = self.population >> (e + 1);
                    if device_idx < start + share {
                        return e;
                    }
                    start += share;
                }
                self.edges - 1
            }
        }
    }
}

/// The scheduler-visible view of one device when selecting for
/// round/version `round` — the single construction site for engine
/// candidates, so policy-facing fields cannot drift between the barrier
/// scan and the streaming materialization.
fn candidate_of(pop: &Population, device_idx: usize, round: u64) -> Candidate {
    let d = &pop.devices[device_idx];
    Candidate {
        device: d.device,
        num_examples: d.num_examples,
        last_loss: d.last_loss,
        rounds_since_selected: d.last_selected_round.map(|r| round.saturating_sub(r)),
        times_selected: d.times_selected,
    }
}

/// The population-scale scheduler engine — one event-driven core for
/// both execution modes (see the module docs).
pub struct Engine<T: CohortTrainer> {
    cfg: ScheduleConfig,
    policy: Box<dyn SelectionPolicy>,
    trainer: T,
    pop: Population,
    clock_s: f64,
    // ---- unified execution state ----
    mode: ExecMode,
    /// Modeled local train steps per dispatch.
    steps: u64,
    /// Per-dispatch wire traffic from the strategy
    /// ([`crate::strategy::wire::WireModel`]); derived once in
    /// [`Engine::new`] from the strategy config, the model size, and
    /// the mask-exchange group (cohort in sync, `k_flush` in async).
    wire: crate::strategy::wire::WireModel,
    /// Model versions flushed so far (== rounds completed in sync mode).
    version: u64,
    /// Event-loop virtual time.
    now_s: f64,
    /// Sync: wall entry of the open round (availability dead air is
    /// charged from here).
    entry_s: f64,
    /// Sync: round start after the dead-air fast-forward — the deadline
    /// anchor and idle-energy baseline.
    round_now_s: f64,
    /// Async: virtual time of the previous flush (+ server overhead).
    last_flush_s: f64,
    /// Sync: a round has been dispatched and not yet flushed.
    round_open: bool,
    heap: BinaryHeap<Reverse<Completion>>,
    in_flight: usize,
    buffer: Vec<BufferedFold>,
    // accumulators since the last flush
    dropped_deadline: usize,
    dropped_churn: usize,
    wasted_j: f64,
    energy_j: f64,
    /// Wire-byte books since the last flush: downlink counts at
    /// dispatch (drops included — the broadcast is spent either way),
    /// uplink counts at fold (a drop never completes its upload).
    /// Always zero at a flush boundary, so checkpoints need no extra
    /// state for them.
    bytes_down_acc: u64,
    bytes_up_acc: u64,
    /// Sync: slowest modeled finish over *all* dispatches (with no
    /// deadline the barrier waits even for doomed stragglers).
    slowest_all_s: f64,
    avail_count: usize,
    events_since_flush: u64,
    rescans: u32,
    /// Streaming availability membership (async mode only; the barrier
    /// mode's once-per-round scan stays exact and allocation-free).
    index: Option<AvailabilityIndex>,
    /// Edge-aggregator tier (`--edges N`, N > 1); `None` = flat.
    tier: Option<EdgeTier>,
    /// Rounds restored from a checkpoint ([`Engine::resume`]); `run`
    /// prepends them so a resumed report splices seamlessly onto the
    /// uninterrupted trace.
    prior_rounds: Vec<PopulationRound>,
    /// Typed event sink ([`crate::obs`]); [`NullSink`] by default.
    /// Events are stamped with **virtual time** and emitted in a
    /// deterministic order (dispatch order, then heap-pop settle
    /// order), so for a fixed seed the stream is byte-identical across
    /// runs — and across kill/resume, because `checkpoint` is only
    /// legal at a flush boundary and resume re-queues in-flight work
    /// without re-emitting its dispatch events.
    obs: Arc<dyn ObsSink>,
}

impl<T: CohortTrainer> Engine<T> {
    pub fn new(cfg: &ScheduleConfig, trainer: T) -> Result<Self> {
        cfg.validate()?;
        // Worker count is an execution knob, not an identity knob: it is
        // excluded from the fingerprint, and every sharded path merges in
        // shard order, so any value reproduces the --workers 1 bytes.
        par::set_workers(cfg.workers);
        crate::obs::registry()
            .gauge("sched_workers")
            .set(cfg.workers.max(1) as f64);
        let policy = cfg.policy.build(cfg.seed ^ 0x5E1);
        let pop = Population::synthesize(cfg)?;
        let mode = match cfg.async_buffer {
            Some(k) => ExecMode::Async { k_flush: k },
            None => ExecMode::Sync,
        };
        let index = match mode {
            ExecMode::Async { .. } => Some(AvailabilityIndex::from_schedules(
                pop.devices.iter().map(|d| d.schedule.clone()).collect(),
                0.0,
            )),
            ExecMode::Sync => None,
        };
        let steps = cfg.epochs.max(0) as u64 * cfg.steps_per_epoch;
        // The secagg mask-exchange group is whatever cohort folds
        // together: the full cohort in a barrier round, the flush
        // quorum in streaming mode.
        let group = match mode {
            ExecMode::Sync => cfg.cohort_size as u64,
            ExecMode::Async { k_flush } => k_flush as u64,
        };
        let wire = crate::strategy::wire::WireModel::for_strategy(
            &cfg.strategy,
            cfg.model_bytes as u64,
            group,
        );
        let tier = (cfg.edges > 1).then(|| {
            let quorum = match mode {
                ExecMode::Sync => 0,
                ExecMode::Async { k_flush } => k_flush.div_ceil(cfg.edges).max(1),
            };
            EdgeTier::new(cfg, quorum)
        });
        Ok(Engine {
            cfg: cfg.clone(),
            policy,
            trainer,
            pop,
            clock_s: 0.0,
            mode,
            steps,
            wire,
            version: 0,
            now_s: 0.0,
            entry_s: 0.0,
            round_now_s: 0.0,
            last_flush_s: 0.0,
            round_open: false,
            heap: BinaryHeap::new(),
            in_flight: 0,
            buffer: Vec::new(),
            dropped_deadline: 0,
            dropped_churn: 0,
            wasted_j: 0.0,
            energy_j: 0.0,
            bytes_down_acc: 0,
            bytes_up_acc: 0,
            slowest_all_s: 0.0,
            avail_count: 0,
            events_since_flush: 0,
            rescans: 0,
            index,
            tier,
            prior_rounds: Vec::new(),
            obs: Arc::new(NullSink),
        })
    }

    /// Attach a typed event sink (see [`crate::obs`]). The default
    /// [`NullSink`] costs one virtual call per event; instrumentation
    /// never consumes randomness or perturbs the trajectory, so golden
    /// traces are bit-identical with obs on or off.
    pub fn set_obs(&mut self, sink: Arc<dyn ObsSink>) {
        self.obs = sink;
    }

    pub fn population(&self) -> &Population {
        &self.pop
    }

    pub fn virtual_time_s(&self) -> f64 {
        self.clock_s
    }

    /// The execution mode this engine was configured with.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Run the configured number of rounds / model versions
    /// (early-stopping on the target accuracy, if set). One loop, both
    /// modes: each iteration advances the core to its next flush.
    ///
    /// With [`crate::config::ScheduleConfig::checkpoint_dir`] set, an
    /// atomic checkpoint is written every
    /// `checkpoint_every_rounds` flushes (and once more at exit, so the
    /// final state is always durable). A resumed engine
    /// ([`Engine::resume`]) prepends the checkpointed rounds, so the
    /// returned report covers the whole logical run.
    pub fn run(mut self) -> Result<PopulationReport> {
        let store = match &self.cfg.checkpoint_dir {
            Some(dir) => Some(CheckpointStore::open(dir)?),
            None => None,
        };
        let every = self.cfg.checkpoint_every_rounds.max(1);
        let mut rounds = std::mem::take(&mut self.prior_rounds);
        // A checkpoint for the resume point already exists on disk.
        let mut last_saved = if self.version > 0 { Some(self.version) } else { None };
        let mut reached = match self.cfg.target_accuracy {
            Some(t) => rounds.last().map(|r| r.accuracy >= t).unwrap_or(false),
            None => false,
        };
        while !reached && self.version < self.cfg.rounds {
            let rec = self.step_flush()?;
            let acc = rec.accuracy;
            rounds.push(rec);
            if let Some(target) = self.cfg.target_accuracy {
                if acc >= target {
                    reached = true;
                }
            }
            if let Some(store) = &store {
                if self.version % every == 0 {
                    let path = store.save(&self.checkpoint(&rounds)?.to_writer())?;
                    log::info(&format!("checkpoint written: {}", path.display()));
                    last_saved = Some(self.version);
                }
            }
        }
        if let Some(store) = &store {
            if last_saved != Some(self.version) {
                let path = store.save(&self.checkpoint(&rounds)?.to_writer())?;
                log::info(&format!("final checkpoint written: {}", path.display()));
            }
        }
        Ok(PopulationReport {
            name: self.cfg.name.clone(),
            policy: self.policy.name().to_string(),
            population: self.cfg.population,
            rounds,
        })
    }

    /// Advance one barrier round of virtual time. Public so benches can
    /// time a single round; [`Engine::run`] is the normal entry point.
    pub fn run_round(&mut self, round: u64) -> Result<PopulationRound> {
        if self.mode != ExecMode::Sync {
            return Err(Error::Config(
                "run_round drives the barrier mode; use run_version for async engines".into(),
            ));
        }
        self.version = round.saturating_sub(1);
        self.step_flush()
    }

    /// Advance the streaming engine by one model version (buffer flush).
    /// Public so benches can time per-event costs at population scale.
    pub fn run_version(&mut self) -> Result<PopulationRound> {
        if self.mode == ExecMode::Sync {
            return Err(Error::Config(
                "run_version drives the streaming mode; use run_round for sync engines".into(),
            ));
        }
        self.step_flush()
    }

    /// The unified virtual-time loop: dispatch, settle one event, flush
    /// when the mode says so. Returns at the next flush.
    fn step_flush(&mut self) -> Result<PopulationRound> {
        loop {
            self.dispatch()?;
            let Some(Reverse(ev)) = self.heap.pop() else {
                // Nothing in flight (streaming mode only: a barrier
                // dispatch always queues its whole cohort or errors).
                self.fast_forward()?;
                continue;
            };
            self.rescans = 0;
            self.events_since_flush += 1;
            if let ExecMode::Async { k_flush } = self.mode {
                if self.events_since_flush > 10_000u64.max(1_000 * k_flush as u64) {
                    return Err(Error::Protocol(format!(
                        "async version {}: buffer starved ({} events without {} \
                         usable folds — deadline/churn drop everything)",
                        self.version + 1,
                        self.events_since_flush,
                        k_flush
                    )));
                }
            }
            self.settle(ev);
            let ready = match self.mode {
                ExecMode::Sync => self.round_open && self.heap.is_empty(),
                ExecMode::Async { k_flush } => self.buffer.len() >= k_flush,
            };
            if ready {
                return self.flush();
            }
        }
    }

    /// Mode-dependent dispatch: open a barrier round, or top up the
    /// streaming window. Both paths model every dispatch's fate at issue
    /// time and queue it through [`Engine::push_dispatch`].
    fn dispatch(&mut self) -> Result<()> {
        match self.mode {
            ExecMode::Sync => {
                if self.round_open {
                    return Ok(());
                }
                self.begin_round()
            }
            ExecMode::Async { .. } => self.top_up(),
        }
    }

    /// Open one barrier round: scan availability at the current virtual
    /// time (fast-forwarding through dead air, which is charged to the
    /// round), select a cohort, and dispatch all of it.
    fn begin_round(&mut self) -> Result<()> {
        let round = self.version + 1;
        let entry = self.clock_s;

        // Availability scan, sharded over `--workers` threads (per-shard
        // index slices merged in shard order == ascending id order, so
        // the scan is byte-identical to the sequential one). Under
        // extreme churn an instant can have zero devices online; the
        // server would simply wait, so the clock fast-forwards to the
        // next arrival instead of failing (the dead air still counts
        // toward this round's time).
        let workers = par::workers();
        let mut now = entry;
        let mut rescans = 0u32;
        let avail: Vec<u32> = loop {
            let avail =
                shard_scan_indices(&self.pop.devices, workers, |d| d.schedule.is_on(now));
            if !avail.is_empty() {
                break avail;
            }
            rescans += 1;
            if rescans > 1_000 {
                return Err(Error::Protocol(format!(
                    "round {round}: no devices ever available (t={now:.0}s)"
                )));
            }
            // every device is offline here, so each delay is positive
            // (infinite for a trace that never comes back); the min of
            // per-shard minima is exactly the global min
            let dt =
                shard_min_by(&self.pop.devices, workers, |d| d.schedule.next_on_delay_s(now));
            if !dt.is_finite() {
                return Err(Error::Protocol(format!(
                    "round {round}: no devices ever available (t={now:.0}s)"
                )));
            }
            // epsilon guards float-boundary stalls (pos == period)
            now += dt.max(1e-6);
        };

        // Cohort selection over available devices only (candidate
        // construction is pure per-device, so it shards the same way).
        let candidates: Vec<Candidate> =
            shard_map(&avail, workers, |&i| candidate_of(&self.pop, i as usize, round));
        let ctx = SelectionContext {
            round,
            cost: &self.cfg.cost,
            steps_per_round: self.steps,
            bytes_down: self.wire.bytes_down,
            bytes_up: self.wire.bytes_up,
            target_cohort: self.cfg.cohort_size,
            deadline_s: self.cfg.deadline_s,
        };
        let picked = self.policy.select(&ctx, &candidates);
        if picked.is_empty() {
            return Err(Error::Protocol(format!(
                "round {round}: policy selected no clients ({} available)",
                avail.len()
            )));
        }
        self.obs.emit(&Event::RoundStart {
            t_s: now,
            round,
            available: avail.len() as u64,
            selected: picked.len() as u64,
        });
        let dispatches: Vec<(usize, f64, f64)> = picked
            .iter()
            .map(|&j| {
                let i = avail[j] as usize;
                let d = self.pop.devices[i].device;
                (i, ctx.modeled_round_time_s(d), ctx.modeled_round_energy_j(d))
            })
            .collect();

        let deadline_abs = self
            .cfg
            .deadline_s
            .map(|tau| now + tau)
            .unwrap_or(f64::INFINITY);
        for (i, full_time_s, full_energy_j) in dispatches {
            // Barrier events resolve at the full modeled finish: the
            // server waits out even doomed dispatches (classification
            // still happens at issue time — the predicates are pure
            // functions of the model).
            self.push_dispatch(i, now, full_time_s, full_energy_j, deadline_abs, false);
        }
        self.entry_s = entry;
        self.round_now_s = now;
        self.now_s = now;
        self.avail_count = avail.len();
        self.slowest_all_s = now;
        self.round_open = true;
        Ok(())
    }

    /// Top up the streaming window through the availability index:
    /// uniform policies sample straight off it (O(want) amortized);
    /// policies that score the whole pool get a materialized candidate
    /// view (inherently O(available)). Retries immediately when every
    /// sampled device was a float-boundary skip (each skip shrinks the
    /// idle pool, so the retry terminates) — otherwise an empty heap
    /// after such a round would be misdiagnosed as the policy declining.
    fn top_up(&mut self) -> Result<()> {
        loop {
            let (dispatched, skipped) = self.try_top_up()?;
            if dispatched > 0 || skipped == 0 {
                return Ok(());
            }
        }
    }

    /// One top-up attempt; returns `(dispatched, boundary_skips)`.
    fn try_top_up(&mut self) -> Result<(usize, usize)> {
        let window = self.cfg.effective_concurrency().max(1);
        if self.in_flight >= window {
            return Ok((0, 0));
        }
        let now = self.now_s;
        let index = self.index.as_mut().expect("streaming mode has an index");
        index.advance(now);
        self.avail_count = index.idle_online_len() + self.in_flight;
        if index.idle_online_len() == 0 {
            return Ok((0, 0));
        }
        let want = window - self.in_flight;
        let ctx = SelectionContext {
            round: self.version + 1,
            cost: &self.cfg.cost,
            steps_per_round: self.steps,
            bytes_down: self.wire.bytes_down,
            bytes_up: self.wire.bytes_up,
            target_cohort: want,
            deadline_s: self.cfg.deadline_s,
        };
        let chosen: Vec<u32> = match self.policy.select_streaming(&ctx, &mut *index, want) {
            Some(devices) => devices,
            None => {
                let snapshot = index.idle_online_sorted();
                let candidates: Vec<Candidate> = snapshot
                    .iter()
                    .map(|&i| candidate_of(&self.pop, i as usize, self.version + 1))
                    .collect();
                self.policy
                    .select(&ctx, &candidates)
                    .into_iter()
                    .map(|j| snapshot[j])
                    .collect()
            }
        };
        let dispatches: Vec<(usize, f64, f64)> = chosen
            .iter()
            .map(|&dev| {
                let d = self.pop.devices[dev as usize].device;
                (
                    dev as usize,
                    ctx.modeled_round_time_s(d),
                    ctx.modeled_round_energy_j(d),
                )
            })
            .collect();
        let deadline_abs = self
            .cfg
            .deadline_s
            .map(|tau| now + tau)
            .unwrap_or(f64::INFINITY);
        let mut dispatched = 0usize;
        let mut skipped = 0usize;
        for (i, full_time_s, full_energy_j) in dispatches {
            // The wheel's scheduled transition and a point `is_on` query
            // can disagree by a rounding error at a toggle boundary, so
            // a sampled device may already be past its disconnect. The
            // pre-index rescan filtered on `is_on(now)` implicitly; do
            // the same here — reconcile the index and skip the dispatch
            // (the retry loop above won't see the device again).
            if !self.pop.devices[i].schedule.is_on(now) {
                self.index
                    .as_mut()
                    .expect("streaming mode has an index")
                    .resync_device(i as u32, now);
                skipped += 1;
                continue;
            }
            self.index
                .as_mut()
                .expect("streaming mode has an index")
                .mark_busy(i as u32);
            // Streaming events resolve at the cutoff: a doomed dispatch
            // frees its slot the moment the server learns the outcome
            // and never drags the clock to its hypothetical finish.
            self.push_dispatch(i, now, full_time_s, full_energy_j, deadline_abs, true);
            dispatched += 1;
        }
        Ok((dispatched, skipped))
    }

    /// Model one dispatch's fate at issue time and queue its resolution
    /// event. The fate is a pure function of the model: a device online
    /// at dispatch keeps its connection only to the end of the current
    /// on-dwell (churn drop at the disconnect), a finish past τ is a
    /// deadline drop at τ, anything else folds at the modeled finish.
    /// Energy is prorated to the resolve point.
    fn push_dispatch(
        &mut self,
        i: usize,
        now: f64,
        full_time_s: f64,
        full_energy_j: f64,
        deadline_abs: f64,
        resolve_at_cutoff: bool,
    ) {
        let full_finish_s = now + full_time_s;
        let d = &mut self.pop.devices[i];
        let class = d.device.name;
        // online at dispatch; the connection survives only to this
        // on-dwell's end
        let first_off_s = d.schedule.on_dwell_end_s(now);
        let (cutoff_s, outcome) = if first_off_s < deadline_abs.min(full_finish_s) {
            (first_off_s, Outcome::DropChurn)
        } else if full_finish_s > deadline_abs {
            (deadline_abs, Outcome::DropDeadline)
        } else {
            (full_finish_s, Outcome::Fold)
        };
        // Two-tier reclassification: a would-be fold whose edge is dead
        // (or will be by the time the upload lands) has nowhere to land —
        // the device does its full work and the result is lost, so it
        // becomes a churn drop at the full finish with full energy. The
        // dispatch event stays honest: the fate is still known at issue
        // time, because the failure schedule is part of the model.
        let (cutoff_s, outcome) = match &self.tier {
            Some(tier) if outcome == Outcome::Fold => {
                let e = tier.edge_of(i);
                let doomed = !tier.alive[e]
                    || matches!(tier.fail, Some((fe, t)) if fe == e && full_finish_s >= t);
                if doomed {
                    (full_finish_s, Outcome::DropChurn)
                } else {
                    (cutoff_s, outcome)
                }
            }
            _ => (cutoff_s, outcome),
        };
        let frac = ((cutoff_s - now) / (full_finish_s - now)).clamp(0.0, 1.0);
        let energy_j = full_energy_j * frac;
        d.last_selected_round = Some(self.version + 1);
        d.times_selected += 1;
        self.in_flight += 1;
        self.bytes_down_acc += self.wire.bytes_down;
        // Edge downlink: the first member dispatch per model version
        // pulls the current model cloud→edge once; the edge fans it out
        // to its shard (the per-device leg is booked above for every
        // dispatch). Dead edges pull nothing — the cloud serves their
        // orphaned devices directly at the device-leg cost.
        if let Some(tier) = &mut self.tier {
            let e = tier.edge_of(i);
            if tier.alive[e] && tier.seen_version[e] != self.version {
                tier.seen_version[e] = self.version;
                self.bytes_down_acc += tier.leg_bytes;
                self.obs.emit(&Event::EdgeDispatch {
                    t_s: now,
                    edge: e as u64,
                    bytes_down: tier.leg_bytes,
                });
            }
        }
        self.heap.push(Reverse(Completion {
            resolve_s: if resolve_at_cutoff { cutoff_s } else { full_finish_s },
            device_idx: i,
            energy_j,
            base_version: self.version,
            outcome,
        }));
        self.obs.emit(&Event::Dispatch {
            t_s: now,
            device: i as u64,
            class,
            fate: match outcome {
                Outcome::Fold => Fate::Fold,
                Outcome::DropDeadline => Fate::DropDeadline,
                Outcome::DropChurn => Fate::DropChurn,
            },
            work_s: cutoff_s - now,
            energy_j,
            bytes_down: self.wire.bytes_down,
        });
    }

    /// Settle one resolution event: account its energy, fold or drop it,
    /// and (streaming) advance the clock and free the device's slot.
    fn settle(&mut self, ev: Completion) {
        let i = ev.device_idx;
        match self.mode {
            ExecMode::Async { .. } => {
                self.now_s = self.now_s.max(ev.resolve_s);
                self.index
                    .as_mut()
                    .expect("streaming mode has an index")
                    .mark_idle(i as u32);
            }
            ExecMode::Sync => {
                self.slowest_all_s = self.slowest_all_s.max(ev.resolve_s);
            }
        }
        // Streaming: a pending edge failure applies at the first settle
        // at or past its time, *before* this event is processed — its
        // parked folds drop and the run degrades instead of dying. (The
        // barrier mode applies failures at the round merge instead; see
        // `sync_edge_merge`.)
        if let ExecMode::Async { .. } = self.mode {
            self.apply_edge_fail_async();
        }
        self.in_flight -= 1;
        self.energy_j += ev.energy_j;
        let class = self.pop.devices[i].device.name;
        match ev.outcome {
            Outcome::Fold => {
                let staleness = self.version - ev.base_version;
                // Streaming two-tier: the fold parks at its edge and
                // only reaches the cloud buffer when the edge's ship
                // quorum fills. Everywhere else (flat, or the barrier
                // mode where the merge groups by edge at the flush) it
                // lands in the cloud buffer directly.
                let parked_at = match (&mut self.tier, self.mode) {
                    (Some(tier), ExecMode::Async { .. }) => {
                        let e = tier.edge_of(i);
                        debug_assert!(tier.alive[e], "fold settled for a dead edge");
                        tier.buffers[e].push(EdgeBuffered {
                            device_idx: i,
                            base_version: ev.base_version,
                            resolve_s: ev.resolve_s,
                        });
                        Some(e)
                    }
                    _ => {
                        self.buffer.push(BufferedFold {
                            device_idx: i,
                            staleness,
                            resolve_s: ev.resolve_s,
                        });
                        None
                    }
                };
                self.bytes_up_acc += self.wire.bytes_up;
                self.obs.emit(&Event::Fold {
                    t_s: ev.resolve_s,
                    device: i as u64,
                    class,
                    staleness,
                    energy_j: ev.energy_j,
                    bytes_up: self.wire.bytes_up,
                });
                if let Some(e) = parked_at {
                    self.ship_edge_if_quorum(e);
                }
            }
            Outcome::DropChurn => {
                self.dropped_churn += 1;
                self.wasted_j += ev.energy_j;
                self.obs.emit(&Event::DropChurn {
                    t_s: ev.resolve_s,
                    device: i as u64,
                    class,
                    energy_j: ev.energy_j,
                });
            }
            Outcome::DropDeadline => {
                self.dropped_deadline += 1;
                self.wasted_j += ev.energy_j;
                self.obs.emit(&Event::DropDeadline {
                    t_s: ev.resolve_s,
                    device: i as u64,
                    class,
                    energy_j: ev.energy_j,
                });
            }
        }
    }

    /// The full modeled round energy for one device — bit-identical to
    /// the `SelectionContext::modeled_round_energy_j` a fold was charged
    /// at settle (a fold's proration factor is exactly 1.0), so an edge
    /// failure can move already-charged energy into the wasted book
    /// without storing per-fold energy in the buffers.
    fn full_fold_energy_j(&self, device_idx: usize) -> f64 {
        let d = self.pop.devices[device_idx].device;
        let link = self
            .cfg
            .cost
            .comm(d, (self.wire.bytes_down + self.wire.bytes_up) as usize);
        self.cfg.cost.compute(d, self.steps).energy_j + link.energy_j
    }

    /// Streaming-mode edge failure: once virtual time reaches the
    /// injected `--edge-fail` instant, the edge's parked folds are lost
    /// (counted as churn drops, their settle energy moved to the wasted
    /// book) and the edge stays dead for the rest of the run — its
    /// devices keep being dispatched, but their uploads have nowhere to
    /// land (reclassified at issue time; see `push_dispatch`).
    fn apply_edge_fail_async(&mut self) {
        let Some(tier) = &mut self.tier else { return };
        let Some((e, t_fail)) = tier.fail else { return };
        if self.now_s < t_fail {
            return;
        }
        tier.fail = None;
        tier.alive[e] = false;
        let entries = std::mem::take(&mut tier.buffers[e]);
        let dropped = entries.len() as u64;
        let mut wasted = 0.0f64;
        for b in &entries {
            wasted += self.full_fold_energy_j(b.device_idx);
        }
        self.dropped_churn += dropped as usize;
        self.wasted_j += wasted;
        self.obs.emit(&Event::EdgeFail {
            t_s: self.now_s,
            edge: e as u64,
            dropped,
            wasted_j: wasted,
        });
    }

    /// Streaming-mode edge ship: when edge `e`'s parked folds reach the
    /// ship quorum, the edge folds them locally and ships one dense
    /// model upstream — the parked entries enter the cloud buffer in
    /// arrival order with their staleness computed *now* (they age
    /// across cloud flushes), and the edge→cloud leg books its bytes.
    fn ship_edge_if_quorum(&mut self, e: usize) {
        let tier = self.tier.as_mut().expect("tier ship without a tier");
        if tier.buffers[e].len() < tier.quorum {
            return;
        }
        let entries = std::mem::take(&mut tier.buffers[e]);
        let shipped = entries.len() as u64;
        let mut staleness_sum = 0u64;
        for b in entries {
            let staleness = self.version - b.base_version;
            staleness_sum += staleness;
            self.buffer.push(BufferedFold {
                device_idx: b.device_idx,
                staleness,
                resolve_s: b.resolve_s,
            });
        }
        self.bytes_up_acc += tier.leg_bytes;
        self.obs.emit(&Event::EdgeFlush {
            t_s: self.now_s,
            edge: e as u64,
            folded: shipped,
            staleness_sum,
            bytes_up: tier.leg_bytes,
        });
    }

    /// Barrier-mode edge merge, run at the top of a flush when the tier
    /// is active. Returns the precomputed round end so the flush's clock
    /// arithmetic matches the flat engine exactly.
    ///
    /// Order of operations (normative — `TOPOLOGY.md`):
    /// 1. The barrier close is computed from the *pre-failure* books —
    ///    an edge dying mid-round never moves the barrier; the cloud
    ///    discovers the missing shard at the merge.
    /// 2. A pending `--edge-fail` with `t ≤ round_end` applies: the dead
    ///    edge's buffered folds drop (churn; their settle energy moves
    ///    to the wasted book in arrival order) and the edge stays dead.
    /// 3. The surviving buffer is stably regrouped by edge id — the
    ///    deterministic merge order: edges fold in ascending id order,
    ///    arrival order within an edge.
    /// 4. Each contributing edge ships one dense model upstream
    ///    (edge→cloud bytes + an `EdgeFlush` event at the barrier
    ///    close).
    fn sync_edge_merge(&mut self) -> f64 {
        let drops = self.dropped_deadline + self.dropped_churn;
        let slowest_ok = self
            .buffer
            .iter()
            .map(|f| f.resolve_s)
            .fold(self.round_now_s, f64::max);
        let round_end = match self.cfg.deadline_s {
            Some(tau) if drops > 0 => self.round_now_s + tau,
            Some(_) => slowest_ok,
            None => self.slowest_all_s,
        };
        {
            let tier = self.tier.as_mut().expect("sync merge without a tier");
            if let Some((e, t_fail)) = tier.fail {
                if t_fail <= round_end {
                    tier.fail = None;
                    tier.alive[e] = false;
                    let mut dropped = 0u64;
                    let mut wasted = 0.0f64;
                    let mut survivors = Vec::with_capacity(self.buffer.len());
                    for f in std::mem::take(&mut self.buffer) {
                        if tier.edge_of(f.device_idx) == e {
                            dropped += 1;
                            let d = self.pop.devices[f.device_idx].device;
                            let link = self
                                .cfg
                                .cost
                                .comm(d, (self.wire.bytes_down + self.wire.bytes_up) as usize);
                            wasted += self.cfg.cost.compute(d, self.steps).energy_j + link.energy_j;
                        } else {
                            survivors.push(f);
                        }
                    }
                    self.buffer = survivors;
                    self.dropped_churn += dropped as usize;
                    self.wasted_j += wasted;
                    self.obs.emit(&Event::EdgeFail {
                        t_s: round_end,
                        edge: e as u64,
                        dropped,
                        wasted_j: wasted,
                    });
                }
            }
        }
        let tier = self.tier.as_ref().expect("sync merge without a tier");
        self.buffer.sort_by_key(|f| tier.edge_of(f.device_idx));
        let mut i = 0;
        while i < self.buffer.len() {
            let e = tier.edge_of(self.buffer[i].device_idx);
            let mut folded = 0u64;
            let mut staleness_sum = 0u64;
            let mut j = i;
            while j < self.buffer.len() && tier.edge_of(self.buffer[j].device_idx) == e {
                folded += 1;
                staleness_sum += self.buffer[j].staleness;
                j += 1;
            }
            self.bytes_up_acc += tier.leg_bytes;
            self.obs.emit(&Event::EdgeFlush {
                t_s: round_end,
                edge: e as u64,
                folded,
                staleness_sum,
                bytes_up: tier.leg_bytes,
            });
            i = j;
        }
        round_end
    }

    /// Per-fold aggregation weights for the buffered results, by
    /// strategy (the engine-side mirror of the live `AsyncStrategy`
    /// adapters — see `strategy/README.md` for the composition rules):
    ///
    /// - **FedAvg / Compressed** — the staleness discount `(1+s)^-α`
    ///   (f16 changes bytes, never weights; dequantized folds average
    ///   exactly like FedAvg's).
    /// - **FedProx{μ}** — `discount / (1+μ)`: the proximal term damps
    ///   each client's drift toward its local optimum, so the surrogate
    ///   fold advances by the same factor. μ = 0 divides by exactly 1.0
    ///   and is bit-identical to FedAvg.
    /// - **QFedAvg{q}** — `discount · h_i · (n/Σh)` with
    ///   `h_i = (loss_i + ε)^q` from the device's last reported loss
    ///   (1.0 before the first report): q-fair emphasis, renormalized
    ///   so total fold mass matches FedAvg's. q = 0 makes every
    ///   `h_i = 1.0` exactly (IEEE `powf(x, 0) = 1` for finite x > 0)
    ///   and `n/Σh = 1.0` exactly, hence bit-identity with FedAvg.
    /// - **SecAgg** — exactly 1.0: the server only ever sees the masked
    ///   *sum*, so per-client reweighting after masking is impossible —
    ///   the composition rule is "secagg disables the staleness
    ///   discount", not an approximation of it.
    fn fold_weights(&self) -> Vec<(usize, f64)> {
        use crate::config::SchedStrategyConfig as S;
        let alpha = self.cfg.staleness_alpha;
        let discount =
            |f: &BufferedFold| crate::strategy::fedbuff::staleness_discount(f.staleness, alpha);
        match &self.cfg.strategy {
            S::FedAvg | S::Compressed => {
                self.buffer.iter().map(|f| (f.device_idx, discount(f))).collect()
            }
            S::SecAgg => self.buffer.iter().map(|f| (f.device_idx, 1.0)).collect(),
            S::FedProx { mu } => self
                .buffer
                .iter()
                .map(|f| (f.device_idx, discount(f) / (1.0 + mu)))
                .collect(),
            S::QFedAvg { q } => {
                let h: Vec<f64> = self
                    .buffer
                    .iter()
                    .map(|f| {
                        let loss = self.pop.devices[f.device_idx].last_loss.unwrap_or(1.0);
                        (loss.max(0.0) + crate::strategy::qfedavg::EPS).powf(*q)
                    })
                    .collect();
                let sum: f64 = h.iter().sum();
                let n = self.buffer.len() as f64;
                self.buffer
                    .iter()
                    .zip(&h)
                    .map(|(f, &hi)| (f.device_idx, discount(f) * hi * (n / sum)))
                    .collect()
            }
        }
    }

    /// Flush the buffer into a new model version: train the folds
    /// (strategy-weighted; see [`Engine::fold_weights`]), close the
    /// books, and emit the round record. Shared by both modes — only the
    /// clock arithmetic differs (barrier close vs. flush-to-flush).
    fn flush(&mut self) -> Result<PopulationRound> {
        // Barrier-mode two-tier merge: apply any pending edge failure,
        // regroup the buffer by edge id, and book the edge→cloud ships —
        // all before the fold weights are computed, so the trainer sees
        // the deterministic merge order. The round end is precomputed
        // from the pre-merge books (the flat formula below would see
        // post-failure drop counts and move the barrier).
        let merged_round_end = match (&self.tier, self.mode) {
            (Some(_), ExecMode::Sync) => Some(self.sync_edge_merge()),
            _ => None,
        };
        self.version += 1;
        let version = self.version;
        let folds = self.fold_weights();
        let (losses, eval_loss, accuracy) =
            self.trainer
                .train_flush(version, &self.pop, &folds, self.steps)?;
        debug_assert_eq!(losses.len(), self.buffer.len());
        for (f, &l) in self.buffer.iter().zip(&losses) {
            self.pop.devices[f.device_idx].last_loss = Some(l);
        }
        let completed = self.buffer.len();
        let staleness_sum: u64 = self.buffer.iter().map(|f| f.staleness).sum();
        let max_staleness = self.buffer.iter().map(|f| f.staleness).max().unwrap_or(0);
        let train_loss = if losses.is_empty() {
            f64::NAN
        } else {
            // Fold-weighted mean: report the blend the model actually
            // ingested, so q-fair / proximal reweighting shows up in the
            // round record. For unit weights every product is exact
            // (`l * 1.0 == l`) and the divisor sums to exactly `n`, so
            // this is bit-identical to the plain mean FedAvg reports.
            let num: f64 = folds.iter().zip(&losses).map(|((_, w), &l)| w * l).sum();
            let den: f64 = folds.iter().map(|(_, w)| w).sum();
            num / den
        };
        let overhead = self.cfg.cost.server_overhead_s;

        let round_time_s = match self.mode {
            ExecMode::Sync => {
                // The round closes at τ if anyone is missing, else at
                // the slowest reporter (no deadline: the server waits
                // out every straggler, folded or doomed).
                let round_end = match merged_round_end {
                    Some(end) => end,
                    None => {
                        let drops = self.dropped_deadline + self.dropped_churn;
                        let slowest_ok = self
                            .buffer
                            .iter()
                            .map(|f| f.resolve_s)
                            .fold(self.round_now_s, f64::max);
                        match self.cfg.deadline_s {
                            Some(tau) if drops > 0 => self.round_now_s + tau,
                            Some(_) => slowest_ok,
                            None => self.slowest_all_s,
                        }
                    }
                };
                // idle-while-waiting energy for clients that reported
                // early (a zero wait charges exactly 0 J — adding it is
                // an exact identity, so the ledger skips the event)
                for f in &self.buffer {
                    let wait = (round_end - f.resolve_s).max(0.0);
                    let idle_j = self
                        .cfg
                        .cost
                        .idle(self.pop.devices[f.device_idx].device, wait)
                        .energy_j;
                    self.energy_j += idle_j;
                    if wait > 0.0 {
                        self.obs.emit(&Event::Idle {
                            t_s: round_end,
                            device: f.device_idx as u64,
                            class: self.pop.devices[f.device_idx].device.name,
                            wait_s: wait,
                            energy_j: idle_j,
                        });
                    }
                }
                // measured from round entry so availability dead air is
                // charged
                let round_time_s = (round_end - self.entry_s) + overhead;
                self.clock_s = self.entry_s + round_time_s;
                self.now_s = self.clock_s;
                self.round_open = false;
                round_time_s
            }
            ExecMode::Async { .. } => {
                let round_time_s = (self.now_s - self.last_flush_s) + overhead;
                self.now_s += overhead;
                self.last_flush_s = self.now_s;
                self.clock_s = self.now_s;
                round_time_s
            }
        };

        let rec = PopulationRound {
            round: version,
            available: self.avail_count,
            // resolution-based accounting in both modes: dispatches
            // *settled* this window (folds + drops), so selected -
            // completed = drops and hit_rate keeps its meaning;
            // outstanding streaming work is `in_flight`
            selected: completed + self.dropped_deadline + self.dropped_churn,
            completed,
            dropped_deadline: self.dropped_deadline,
            dropped_churn: self.dropped_churn,
            train_loss,
            eval_loss,
            accuracy,
            steps: completed as u64 * self.steps,
            round_time_s,
            cum_time_s: self.clock_s,
            round_energy_j: self.energy_j,
            wasted_energy_j: self.wasted_j,
            mean_staleness: if completed == 0 {
                0.0
            } else {
                staleness_sum as f64 / completed as f64
            },
            max_staleness,
            in_flight: self.in_flight,
            bytes_down: self.bytes_down_acc,
            bytes_up: self.bytes_up_acc,
        };
        self.obs.emit(&Event::Flush {
            t_s: self.clock_s,
            version,
            folded: completed as u64,
            mean_staleness: rec.mean_staleness,
            max_staleness,
        });
        self.obs.emit(&Event::RoundEnd {
            t_s: self.clock_s,
            round: version,
            round_time_s,
            energy_j: rec.round_energy_j,
            wasted_j: rec.wasted_energy_j,
            completed: completed as u64,
            dropped_deadline: rec.dropped_deadline as u64,
            dropped_churn: rec.dropped_churn as u64,
            eval_loss,
            accuracy,
            bytes_down: rec.bytes_down,
            bytes_up: rec.bytes_up,
        });
        self.buffer.clear();
        self.dropped_deadline = 0;
        self.dropped_churn = 0;
        self.wasted_j = 0.0;
        self.energy_j = 0.0;
        self.bytes_down_acc = 0;
        self.bytes_up_acc = 0;
        self.events_since_flush = 0;
        Ok(rec)
    }

    /// Streaming dead air: nothing in flight and nothing dispatchable.
    /// Every *built-in* policy dispatches at least one online candidate,
    /// so an empty heap with devices online means a custom policy
    /// declined — diagnose that accurately instead of blaming
    /// availability. Otherwise fast-forward the clock to the next device
    /// arrival (the dead air is charged to the flush in progress).
    fn fast_forward(&mut self) -> Result<()> {
        let index = self
            .index
            .as_mut()
            .expect("a barrier dispatch always queues events");
        index.advance(self.now_s);
        if index.idle_online_len() > 0 {
            return Err(Error::Protocol(format!(
                "async version {}: policy selected no clients ({} available)",
                self.version + 1,
                index.idle_online_len()
            )));
        }
        self.rescans += 1;
        if self.rescans > 1_000 {
            return Err(Error::Protocol(format!(
                "async version {}: no devices ever available (t={:.0}s)",
                self.version + 1,
                self.now_s
            )));
        }
        let Some(t_next) = index.next_transition_s() else {
            return Err(Error::Protocol(format!(
                "async version {}: no devices ever available (t={:.0}s)",
                self.version + 1,
                self.now_s
            )));
        };
        // epsilon guards float-boundary stalls
        self.now_s += (t_next - self.now_s).max(1e-6);
        Ok(())
    }

    // -----------------------------------------------------------------
    // Checkpoint / resume
    // -----------------------------------------------------------------

    /// Capture a complete engine snapshot at the current flush boundary
    /// (`rounds` is the trace produced so far; it rides along so the
    /// resumed report can splice onto the uninterrupted one). Errors if
    /// called mid-round — a barrier round is open or folds are
    /// buffered — or if the trainer does not support checkpointing.
    ///
    /// What makes the snapshot *sufficient* for bit-identical resume:
    /// population synthesis is a pure function of the config (only the
    /// mutable per-device tails are captured), the policy contributes
    /// its RNG position, the trainer its numeric state, and the
    /// streaming mode additionally contributes the in-flight dispatch
    /// manifest (re-queued verbatim on resume, so outstanding work is
    /// re-settled, not lost) and the availability index's exact
    /// internal state (free-list order included — uniform sampling
    /// consumes it).
    pub fn checkpoint(&self, rounds: &[PopulationRound]) -> Result<EngineCheckpoint> {
        if self.round_open || !self.buffer.is_empty() {
            return Err(Error::Persist(
                "engine checkpoints are only valid at a flush boundary".into(),
            ));
        }
        let trainer = self.trainer.checkpoint_state().ok_or_else(|| {
            Error::Persist(
                "this CohortTrainer does not support checkpointing \
                 (checkpoint_state returned None)"
                    .into(),
            )
        })?;
        let mut in_flight: Vec<InFlightDispatch> = self
            .heap
            .iter()
            .map(|rev| {
                let c = &rev.0;
                InFlightDispatch {
                    resolve_s: c.resolve_s,
                    device: c.device_idx as u64,
                    energy_j: c.energy_j,
                    base_version: c.base_version,
                    outcome: match c.outcome {
                        Outcome::Fold => 0,
                        Outcome::DropDeadline => 1,
                        Outcome::DropChurn => 2,
                    },
                }
            })
            .collect();
        // (resolve_s, device) is unique — a device is never in flight
        // twice — so this order is canonical and the restored heap pops
        // in exactly the original sequence.
        in_flight.sort_by(|a, b| a.resolve_s.total_cmp(&b.resolve_s).then(a.device.cmp(&b.device)));
        Ok(EngineCheckpoint {
            fingerprint: self.cfg.fingerprint(),
            version: self.version,
            clock_s: self.clock_s,
            now_s: self.now_s,
            last_flush_s: self.last_flush_s,
            avail_count: self.avail_count as u64,
            devices: self
                .pop
                .devices
                .iter()
                .map(|d| DeviceState {
                    last_loss: d.last_loss,
                    last_selected_round: d.last_selected_round,
                    times_selected: d.times_selected,
                })
                .collect(),
            policy_rng: self.policy.rng_state(),
            trainer,
            in_flight,
            index: self.index.as_ref().map(|ix| ix.export_state()),
            rounds: rounds.to_vec(),
            shards: Some(synthesis_shard_seeds(&self.cfg, self.cfg.workers)),
            edge: self.tier.as_ref().map(|t| EdgeTierState {
                edges: t.edges as u64,
                alive: t.alive.clone(),
                buffers: t
                    .buffers
                    .iter()
                    .map(|buf| {
                        buf.iter()
                            .map(|f| EdgeParkedFold {
                                device: f.device_idx as u64,
                                base_version: f.base_version,
                                resolve_s: f.resolve_s,
                            })
                            .collect()
                    })
                    .collect(),
            }),
        })
    }

    /// Rebuild an engine from a checkpoint and continue where it left
    /// off: [`Engine::run`] then produces rounds `version+1..` and
    /// prepends the checkpointed trace, bit-identical to the
    /// uninterrupted run (locked by the kill-at-round-k e2e tests).
    /// The config must fingerprint-match the checkpointed one
    /// ([`crate::config::ScheduleConfig::fingerprint`]); `rounds`,
    /// `target_accuracy`, `name` and the checkpoint knobs may differ.
    pub fn resume(cfg: &ScheduleConfig, trainer: T, ckpt: &EngineCheckpoint) -> Result<Self> {
        let mut e = Engine::new(cfg, trainer)?;
        let fp = cfg.fingerprint();
        if fp != ckpt.fingerprint {
            return Err(Error::Persist(format!(
                "checkpoint config mismatch: the checkpoint was written under\n  {}\nbut this run is configured as\n  {fp}",
                ckpt.fingerprint
            )));
        }
        if ckpt.devices.len() != e.pop.devices.len() {
            return Err(Error::Persist(format!(
                "checkpoint has {} devices, population synthesized {}",
                ckpt.devices.len(),
                e.pop.devices.len()
            )));
        }
        // Parallel-synthesis audit (absent in pre-SHRD checkpoints):
        // recompute the shard-start states for the checkpoint's recorded
        // worker count and require bit-equality — shard streams must be
        // fast-forward positions in the canonical stream, never
        // independent seeds, or resuming under a different --workers
        // would silently synthesize a different population.
        if let Some(sh) = &ckpt.shards {
            let expect = synthesis_shard_seeds(cfg, sh.workers as usize);
            if expect.starts != sh.starts {
                return Err(Error::Persist(format!(
                    "checkpoint shard RNG states (workers={}) do not match this \
                     config's synthesis stream — population would diverge on resume",
                    sh.workers
                )));
            }
        }
        for (d, s) in e.pop.devices.iter_mut().zip(&ckpt.devices) {
            d.last_loss = s.last_loss;
            d.last_selected_round = s.last_selected_round;
            d.times_selected = s.times_selected;
        }
        if let Some(state) = &ckpt.policy_rng {
            e.policy.restore_rng(state);
        }
        e.trainer.restore_state(&ckpt.trainer)?;
        e.version = ckpt.version;
        e.clock_s = ckpt.clock_s;
        e.now_s = ckpt.now_s;
        e.last_flush_s = ckpt.last_flush_s;
        e.avail_count = ckpt.avail_count as usize;
        match (e.mode, &ckpt.index) {
            (ExecMode::Async { .. }, Some(state)) => {
                let schedules: Vec<DeviceSchedule> =
                    e.pop.devices.iter().map(|d| d.schedule.clone()).collect();
                e.index = Some(AvailabilityIndex::from_state(schedules, state.clone())?);
            }
            (ExecMode::Sync, None) => {}
            _ => {
                return Err(Error::Persist(
                    "checkpoint execution mode (sync/async) does not match the config".into(),
                ))
            }
        }
        if e.mode == ExecMode::Sync && !ckpt.in_flight.is_empty() {
            return Err(Error::Persist(
                "sync checkpoint carries in-flight dispatches".into(),
            ));
        }
        for f in &ckpt.in_flight {
            if f.device as usize >= e.pop.devices.len() {
                return Err(Error::Persist(format!(
                    "in-flight dispatch for device {} out of range",
                    f.device
                )));
            }
            e.heap.push(Reverse(Completion {
                resolve_s: f.resolve_s,
                device_idx: f.device as usize,
                energy_j: f.energy_j,
                base_version: f.base_version,
                outcome: match f.outcome {
                    0 => Outcome::Fold,
                    1 => Outcome::DropDeadline,
                    2 => Outcome::DropChurn,
                    other => {
                        return Err(Error::Persist(format!(
                            "unknown in-flight outcome tag {other}"
                        )))
                    }
                },
            }));
        }
        e.in_flight = e.heap.len();
        match (&mut e.tier, &ckpt.edge) {
            (Some(tier), Some(state)) => {
                if state.edges != tier.edges as u64
                    || state.alive.len() != tier.edges
                    || state.buffers.len() != tier.edges
                {
                    return Err(Error::Persist(format!(
                        "checkpoint edge tier has {} edges, the config says {}",
                        state.edges, tier.edges
                    )));
                }
                tier.alive = state.alive.clone();
                for (buf, parked) in tier.buffers.iter_mut().zip(&state.buffers) {
                    buf.clear();
                    for f in parked {
                        if f.device as usize >= e.pop.devices.len() {
                            return Err(Error::Persist(format!(
                                "edge-parked fold for device {} out of range",
                                f.device
                            )));
                        }
                        buf.push(EdgeBuffered {
                            device_idx: f.device as usize,
                            base_version: f.base_version,
                            resolve_s: f.resolve_s,
                        });
                    }
                }
                // A dead edge means the configured failure already
                // applied; don't re-apply it on resume.
                if let Some((fe, _)) = tier.fail {
                    if !tier.alive[fe] {
                        tier.fail = None;
                    }
                }
            }
            (None, None) => {}
            (Some(_), None) => {
                return Err(Error::Persist(
                    "config has an edge tier but the checkpoint carries no EDGE section".into(),
                ))
            }
            (None, Some(_)) => {
                return Err(Error::Persist(
                    "checkpoint carries edge-tier state but the config is flat (--edges 1)".into(),
                ))
            }
        }
        e.prior_rounds = ckpt.rounds.clone();
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PolicyConfig, ScheduleConfig};
    use crate::sched::availability::ChurnSpec;

    fn cfg() -> ScheduleConfig {
        ScheduleConfig::default()
            .named("engine-test")
            .population(2_000)
            .cohort(50)
            .rounds(5)
            .seed(7)
    }

    #[test]
    fn rounds_advance_virtual_time_and_accuracy() {
        let report = Engine::new(&cfg(), SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 5);
        assert!(report.rounds.windows(2).all(|w| w[1].cum_time_s > w[0].cum_time_s));
        assert!(report.rounds.windows(2).all(|w| w[1].accuracy >= w[0].accuracy));
        assert!(report.final_accuracy() > 0.0);
        // no deadline, no churn: everyone selected completes
        assert!(report.rounds.iter().all(|r| r.completed == r.selected));
        assert_eq!(report.dropped_total(), 0);
        assert!(report.wasted_energy_j() == 0.0);
        assert!(report.total_energy_j() > 0.0);
    }

    #[test]
    fn deadline_drops_stragglers_and_wastes_energy() {
        // 8 steps ≈ 11.8 s on TX2 GPU, ≈ 71 s on the RPi; τ = 30 s drops
        // every RPi a uniform policy happens to pick.
        let c = cfg()
            .policy(PolicyConfig::Uniform)
            .deadline(Some(30.0))
            .rounds(6);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert!(report.dropped_total() > 0, "no drops under a tight τ");
        assert!(report.wasted_energy_j() > 0.0);
        assert!(report.hit_rate() < 1.0);
        // the round can never run past τ + server overhead (1 s default)
        assert!(report.rounds.iter().all(|r| r.round_time_s <= 31.0 + 1e-9));
        // accounting invariant
        for r in &report.rounds {
            assert_eq!(r.completed + r.dropped_deadline + r.dropped_churn, r.selected);
        }
    }

    #[test]
    fn churn_rotates_availability() {
        let c = cfg()
            .population(5_000)
            .churn(Some(ChurnSpec { mean_on_s: 500.0, mean_off_s: 500.0 }))
            .rounds(8);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        for r in &report.rounds {
            assert!(
                r.available > 1_000 && r.available < 4_000,
                "round {}: available={} of 5000",
                r.round,
                r.available
            );
        }
    }

    #[test]
    fn dead_air_fast_forwards_instead_of_failing() {
        // duty ≈ 0.1%: most scan instants have zero devices online, so
        // the engine must jump the clock to the next arrival, not error.
        let c = cfg()
            .population(50)
            .cohort(5)
            .rounds(8)
            .seed(11)
            .churn(Some(ChurnSpec { mean_on_s: 10.0, mean_off_s: 10_000.0 }));
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 8);
        assert!(report.rounds.iter().all(|r| r.available >= 1));
        assert!(report
            .rounds
            .windows(2)
            .all(|w| w[1].cum_time_s > w[0].cum_time_s));
    }

    #[test]
    fn engine_runs_are_deterministic() {
        let c = cfg().policy(PolicyConfig::UtilityBased { alpha: 2.0, explore_frac: 0.2 });
        let a = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let b = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn synthesized_population_identical_for_every_worker_count() {
        // ragged population so shard boundaries never align with worker
        // counts; churn so schedules carry per-device randomness too
        let c = cfg()
            .population(1_003)
            .churn(Some(ChurnSpec { mean_on_s: 300.0, mean_off_s: 150.0 }));
        let saved = par::workers();
        par::set_workers(1);
        let base = Population::synthesize(&c).unwrap();
        for w in [2usize, 3, 8, 64] {
            par::set_workers(w);
            let p = Population::synthesize(&c).unwrap();
            assert_eq!(p.len(), base.len());
            for (i, (a, b)) in base.devices.iter().zip(&p.devices).enumerate() {
                assert!(std::ptr::eq(a.device, b.device), "device {i}: profile differs at workers={w}");
                assert_eq!(a.num_examples, b.num_examples, "device {i} workers={w}");
                assert_eq!(a.skew.to_bits(), b.skew.to_bits(), "device {i} workers={w}");
                assert_eq!(
                    format!("{:?}", a.schedule),
                    format!("{:?}", b.schedule),
                    "device {i} workers={w}"
                );
            }
        }
        par::set_workers(saved);
    }

    #[test]
    fn sharded_engine_matches_single_worker_byte_for_byte() {
        // sync with churn + deadline (drops, availability re-scans) and
        // async streaming — the full event surface, per worker count
        let sync = cfg()
            .population(600)
            .cohort(24)
            .rounds(4)
            .deadline(Some(60.0))
            .churn(Some(ChurnSpec { mean_on_s: 400.0, mean_off_s: 200.0 }));
        let streaming = cfg().population(600).cohort(24).buffered(6).rounds(6);
        for base_cfg in [sync, streaming] {
            let baseline = Engine::new(&base_cfg.clone().workers(1), SurrogateTrainer::default())
                .unwrap()
                .run()
                .unwrap()
                .to_csv();
            for w in [2usize, 4, 8] {
                let got = Engine::new(&base_cfg.clone().workers(w), SurrogateTrainer::default())
                    .unwrap()
                    .run()
                    .unwrap()
                    .to_csv();
                assert_eq!(got, baseline, "{} diverged at workers={w}", base_cfg.name);
            }
        }
    }

    #[test]
    fn target_accuracy_stops_early() {
        let mut c = cfg().rounds(50);
        c.target_accuracy = Some(0.3);
        let report = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert!(report.rounds.len() < 50);
        assert!(report.final_accuracy() >= 0.3);
    }

    #[test]
    fn async_mode_flushes_versions_and_tracks_staleness() {
        let c = cfg().buffered(8).rounds(10);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 10);
        for r in &report.rounds {
            assert_eq!(r.completed, 8, "every flush folds exactly K results");
            assert!(r.round_time_s > 0.0);
            assert!(r.in_flight <= c.effective_concurrency());
        }
        assert!(report
            .rounds
            .windows(2)
            .all(|w| w[1].cum_time_s > w[0].cum_time_s));
        assert!(report
            .rounds
            .windows(2)
            .all(|w| w[1].accuracy >= w[0].accuracy));
        // the default mix is heterogeneous (RPi 6× slower than TX2 GPU):
        // versions flush while stragglers are still in flight, so some
        // folds must land stale
        assert!(
            report.rounds.iter().any(|r| r.max_staleness > 0),
            "no stale folds despite a heterogeneous mix"
        );
        assert!(report.mean_staleness() > 0.0);
        // no deadline, no churn: nothing is dropped in async mode either
        assert_eq!(report.dropped_total(), 0);
        assert_eq!(report.wasted_energy_j(), 0.0);
    }

    #[test]
    fn async_runs_are_deterministic() {
        let c = cfg().buffered(8).rounds(8).seed(23);
        let a = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let b = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn async_deadline_drops_per_dispatch_but_still_flushes() {
        // τ = 30 s drops every RPi/Pixel-2 dispatch (modeled 33–71 s)
        // while the fast classes keep the buffer filling. 20 versions so
        // the run outlasts the slow events (first drop pops at ≈ 31 s).
        let c = cfg().buffered(4).deadline(Some(30.0)).rounds(20);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 20);
        assert!(report.dropped_total() > 0, "no drops under a tight τ");
        assert!(report.wasted_energy_j() > 0.0);
        // accounting invariant, same shape as the sync mode: every
        // settled dispatch either folded or was dropped
        for r in &report.rounds {
            assert_eq!(r.completed, 4);
            assert_eq!(r.completed + r.dropped_deadline + r.dropped_churn, r.selected);
        }
        assert!(report.hit_rate() < 1.0);
    }

    #[test]
    fn async_mode_survives_churn() {
        let c = cfg()
            .population(2_000)
            .buffered(8)
            .churn(Some(ChurnSpec { mean_on_s: 500.0, mean_off_s: 500.0 }))
            .rounds(6);
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 6);
        assert!(report.rounds.iter().all(|r| r.completed == 8));
    }

    #[test]
    fn async_target_accuracy_stops_early() {
        let mut c = cfg().buffered(8).rounds(500);
        c.target_accuracy = Some(0.3);
        let report = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert!(report.rounds.len() < 500);
        assert!(report.final_accuracy() >= 0.3);
    }

    #[test]
    fn fairness_cap_spreads_selection_load() {
        // With a hard cap of 2 selections per device, 10 rounds × 50
        // clients = 500 drafts must spread over ≥ 250 distinct devices;
        // uniform with the same seed concentrates more repeats.
        let c = cfg()
            .population(2_000)
            .policy(PolicyConfig::FairnessCap { max_selections: 2 })
            .rounds(10);
        let engine = Engine::new(&c, SurrogateTrainer::default()).unwrap();
        let report = {
            let mut e = engine;
            let mut rounds = Vec::new();
            for round in 1..=10 {
                rounds.push(e.run_round(round).unwrap());
            }
            let over_cap = e
                .population()
                .devices
                .iter()
                .filter(|d| d.times_selected > 2)
                .count();
            assert_eq!(over_cap, 0, "fairness cap exceeded");
            let distinct = e
                .population()
                .devices
                .iter()
                .filter(|d| d.times_selected > 0)
                .count();
            assert!(distinct >= 250, "selection load not spread: {distinct} devices");
            rounds
        };
        assert_eq!(report.len(), 10);
        assert!(report.iter().all(|r| r.selected == 50));
    }

    #[test]
    fn run_round_and_run_version_enforce_modes() {
        let mut sync = Engine::new(&cfg(), SurrogateTrainer::default()).unwrap();
        assert!(sync.run_version().is_err());
        assert!(sync.run_round(1).is_ok());
        let mut streaming =
            Engine::new(&cfg().buffered(8), SurrogateTrainer::default()).unwrap();
        assert!(streaming.run_round(1).is_err());
        assert!(streaming.run_version().is_ok());
    }

    #[test]
    fn sync_checkpoint_resume_replays_rounds_bit_identically() {
        let c = cfg().rounds(6);
        let full = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        // "kill" at round 3: checkpoint, then resume into a fresh engine
        let mut e = Engine::new(&c, SurrogateTrainer::default()).unwrap();
        let mut rounds = Vec::new();
        for r in 1..=3 {
            rounds.push(e.run_round(r).unwrap());
        }
        let ck = e.checkpoint(&rounds).unwrap();
        assert!(ck.in_flight.is_empty(), "sync boundary has nothing in flight");
        assert!(ck.index.is_none(), "sync engines carry no index");
        let resumed = Engine::resume(&c, SurrogateTrainer::default(), &ck)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(resumed.to_csv(), full.to_csv());
    }

    #[test]
    fn async_checkpoint_resume_replays_versions_bit_identically() {
        let c = cfg().buffered(8).rounds(8).seed(23);
        let full = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let mut e = Engine::new(&c, SurrogateTrainer::default()).unwrap();
        let mut rounds = Vec::new();
        for _ in 0..4 {
            rounds.push(e.run_version().unwrap());
        }
        let ck = e.checkpoint(&rounds).unwrap();
        assert!(
            !ck.in_flight.is_empty(),
            "a streaming flush boundary should carry in-flight dispatches"
        );
        assert!(ck.index.is_some(), "streaming engines persist their index");
        let resumed = Engine::resume(&c, SurrogateTrainer::default(), &ck)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(resumed.to_csv(), full.to_csv());
    }

    #[test]
    fn resume_rejects_mismatched_or_mode_flipped_config() {
        let c = cfg();
        let mut e = Engine::new(&c, SurrogateTrainer::default()).unwrap();
        let rec = e.run_round(1).unwrap();
        let ck = e.checkpoint(&[rec]).unwrap();
        // different seed → different trajectory → refused
        assert!(Engine::resume(&cfg().seed(999), SurrogateTrainer::default(), &ck).is_err());
        // sync checkpoint into an async config → refused
        assert!(Engine::resume(&cfg().buffered(8), SurrogateTrainer::default(), &ck).is_err());
        // rounds / name / target may differ freely
        let mut extended = cfg().rounds(50).named("extended");
        extended.target_accuracy = Some(0.9);
        assert!(Engine::resume(&extended, SurrogateTrainer::default(), &ck).is_ok());
    }

    #[test]
    fn population_synthesis_honors_mix_and_seed() {
        let mut c = cfg().population(10_000);
        c.device_mix = vec![("pixel4".into(), 3.0), ("raspberry_pi4".into(), 1.0)];
        let pop = Population::synthesize(&c).unwrap();
        assert_eq!(pop.len(), 10_000);
        let pixels = pop.devices.iter().filter(|d| d.device.name == "pixel4").count();
        assert!(
            (7_000..8_000).contains(&pixels),
            "pixel share {pixels} off the 3:1 mix"
        );
        let again = Population::synthesize(&c).unwrap();
        assert_eq!(pop.devices.len(), again.devices.len());
        assert!(pop
            .devices
            .iter()
            .zip(&again.devices)
            .all(|(a, b)| a.device.name == b.device.name && a.num_examples == b.num_examples));
    }

    #[test]
    fn unknown_device_in_mix_rejected() {
        let mut c = cfg();
        c.device_mix = vec![("nokia3310".into(), 1.0)];
        assert!(Population::synthesize(&c).is_err());
    }

    // -- trace- and scenario-driven populations ---------------------------

    fn write_trace(tag: &str, text: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "flowrs-engine-trace-{tag}-{}.csv",
            std::process::id()
        ));
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn trace_file_drives_availability_and_classes() {
        // 2 always-on jetsons, 1 rpi that disconnects at t=30 s, 1 phone
        // that only comes online at t=50 s
        let text = "device,init,class,toggles_s\n\
                    0,1,jetson,\n\
                    1,1,jetson,\n\
                    2,1,rpi,30\n\
                    3,0,phone,50\n";
        let p = write_trace("classes", text);
        let c = ScheduleConfig::default()
            .named("trace-test")
            .population(4)
            .cohort(4)
            .rounds(2)
            .seed(3)
            .trace_file(p.to_str().unwrap());
        let pop = Population::synthesize(&c).unwrap();
        assert_eq!(pop.devices[0].device.name, "jetson_tx2_gpu");
        assert_eq!(pop.devices[2].device.name, "raspberry_pi4");
        assert_eq!(pop.devices[3].device.name, "pixel4");
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 2);
        // round 1 at t=0: devices 0, 1, 2 online; the RPi's recorded
        // disconnect at 30 s kills its ≈71 s dispatch mid-flight
        assert_eq!(report.rounds[0].available, 3);
        assert_eq!(report.rounds[0].dropped_churn, 1);
        // the class tag must drive the cost model: the doomed RPi burns
        // real (wasted) energy at RPi power draw
        assert!(report.rounds[0].wasted_energy_j > 0.0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn trace_population_mismatch_is_rejected() {
        let p = write_trace(
            "mismatch",
            "device,init,class,toggles_s\n0,1,,\n1,1,,\n",
        );
        let c = cfg().population(5).trace_file(p.to_str().unwrap());
        let err = Population::synthesize(&c).unwrap_err();
        assert!(
            err.to_string().contains("describes 2 devices"),
            "unexpected error: {err}"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn scenario_population_runs_and_pins_classes() {
        let c = cfg().population(300).cohort(20).rounds(3).scenario("diurnal");
        let pop = Population::synthesize(&c).unwrap();
        assert!(pop.devices.iter().all(|d| {
            !d.device.name.starts_with("jetson") && d.device.name != "raspberry_pi4"
        }));
        let report = Engine::new(&c, SurrogateTrainer::default())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(report.rounds.len(), 3);
        assert!(report.rounds.iter().all(|r| r.available > 0));
    }

    #[test]
    fn scenario_async_runs_are_deterministic() {
        let c = cfg()
            .population(200)
            .cohort(16)
            .buffered(8)
            .rounds(5)
            .scenario("flash-crowd");
        let a = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let b = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        assert_eq!(a.to_csv(), b.to_csv());
        assert!(a.rounds.iter().all(|r| r.completed == 8));
    }

    #[test]
    fn trace_driven_async_checkpoint_resume_is_bit_identical() {
        let c = cfg()
            .population(150)
            .cohort(12)
            .buffered(6)
            .rounds(6)
            .seed(29)
            .scenario("flash-crowd");
        let full = Engine::new(&c, SurrogateTrainer::default()).unwrap().run().unwrap();
        let mut e = Engine::new(&c, SurrogateTrainer::default()).unwrap();
        let mut rounds = Vec::new();
        for _ in 0..3 {
            rounds.push(e.run_version().unwrap());
        }
        let ck = e.checkpoint(&rounds).unwrap();
        assert!(ck.index.is_some(), "streaming trace engines persist their index");
        let resumed = Engine::resume(&c, SurrogateTrainer::default(), &ck)
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(resumed.to_csv(), full.to_csv());
    }
}
