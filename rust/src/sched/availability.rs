//! Device availability: per-device on/off churn.
//!
//! Real federated populations are never fully online — phones charge at
//! night, lose signal, leave Wi-Fi. The engine draws cohorts from
//! *available* devices only, using a deterministic per-device on/off
//! cycle synthesized from a seeded RNG: each device gets its own dwell
//! times (around the configured means) and phase, so at any virtual time
//! roughly `mean_on / (mean_on + mean_off)` of the population is online,
//! with membership constantly rotating.
//!
//! The cycle form keeps availability queries O(1) at million-device
//! scale; [`ChurnModel::trace`] materializes the same schedule as an
//! explicit toggle-time trace when a test or an export needs one.

use crate::util::rng::Rng;

/// Churn parameters: mean online / offline dwell times in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnSpec {
    pub mean_on_s: f64,
    pub mean_off_s: f64,
}

/// One device's deterministic on/off cycle: online during the first
/// `on_s` seconds of every `on_s + off_s` period, shifted by `phase_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cycle {
    pub on_s: f64,
    pub off_s: f64,
    pub phase_s: f64,
}

impl Cycle {
    /// A device that never goes offline.
    pub fn always_on() -> Self {
        Cycle { on_s: 1.0, off_s: 0.0, phase_s: 0.0 }
    }

    /// Is the device online at virtual time `t_s` (t ≥ 0)?
    pub fn is_on(&self, t_s: f64) -> bool {
        (t_s + self.phase_s) % (self.on_s + self.off_s) < self.on_s
    }

    /// End of the on-dwell containing `t_s` — the instant a connection
    /// opened at `t_s` dies. Call only when `is_on(t_s)`; infinite for an
    /// always-on cycle.
    pub fn on_dwell_end_s(&self, t_s: f64) -> f64 {
        if self.off_s <= 0.0 {
            return f64::INFINITY;
        }
        let period = self.on_s + self.off_s;
        let pos = (t_s + self.phase_s) % period;
        debug_assert!(pos < self.on_s, "on_dwell_end_s called while offline");
        t_s + (self.on_s - pos)
    }

    /// Seconds from `t_s` until this device is next online (0 if online
    /// now).
    pub fn next_on_delay_s(&self, t_s: f64) -> f64 {
        let period = self.on_s + self.off_s;
        let pos = (t_s + self.phase_s) % period;
        if pos < self.on_s {
            0.0
        } else {
            period - pos
        }
    }
}

/// Population-wide churn: every device's cycle derives deterministically
/// from (seed, device index).
#[derive(Debug, Clone)]
pub struct ChurnModel {
    seed: u64,
    spec: ChurnSpec,
}

impl ChurnModel {
    pub fn new(spec: ChurnSpec, seed: u64) -> Self {
        ChurnModel { seed, spec }
    }

    /// The device's on/off cycle. Dwell times are drawn uniformly in
    /// `[0.5, 1.5) ×` the configured mean; the phase is uniform over the
    /// period so devices don't toggle in lockstep.
    pub fn cycle(&self, device: u64) -> Cycle {
        let mut rng = Rng::seed_from(self.seed).derive(device);
        let on_s = self.spec.mean_on_s * (0.5 + rng.f64());
        let off_s = self.spec.mean_off_s * (0.5 + rng.f64());
        let phase_s = rng.f64() * (on_s + off_s);
        Cycle { on_s, off_s, phase_s }
    }

    pub fn is_available(&self, device: u64, t_s: f64) -> bool {
        self.cycle(device).is_on(t_s)
    }

    /// Materialize the device's schedule over `[0, horizon_s)` as an
    /// explicit trace (state at t=0 plus sorted toggle times).
    pub fn trace(&self, device: u64, horizon_s: f64) -> AvailabilityTrace {
        let c = self.cycle(device);
        if c.off_s <= 0.0 {
            // mean_off_s = 0 is valid config: the device never drops, and
            // emitting zero-length off dwells would break the trace's
            // strictly-increasing toggle contract.
            return AvailabilityTrace { initially_on: true, toggles_s: Vec::new() };
        }
        let period = c.on_s + c.off_s;
        let pos = c.phase_s % period; // position inside the cycle at t=0
        let initially_on = pos < c.on_s;
        let mut toggles_s = Vec::new();
        // time of the first toggle after t=0, then alternate dwell times
        let mut t = if initially_on { c.on_s - pos } else { period - pos };
        let mut on = initially_on;
        while t < horizon_s {
            toggles_s.push(t);
            on = !on;
            t += if on { c.on_s } else { c.off_s };
        }
        AvailabilityTrace { initially_on, toggles_s }
    }
}

/// Explicit per-device availability trace: initial state + toggle times.
#[derive(Debug, Clone, Default)]
pub struct AvailabilityTrace {
    pub initially_on: bool,
    /// Strictly increasing times (s) at which the device flips state.
    pub toggles_s: Vec<f64>,
}

impl AvailabilityTrace {
    pub fn is_on(&self, t_s: f64) -> bool {
        let flips = self.toggles_s.partition_point(|&x| x <= t_s);
        self.initially_on ^ (flips % 2 == 1)
    }
}

/// The population's availability model.
#[derive(Debug, Clone)]
pub enum Availability {
    /// Everyone always online (the paper's testbed setting).
    AlwaysOn,
    Churn(ChurnModel),
}

impl Availability {
    pub fn from_spec(spec: Option<&ChurnSpec>, seed: u64) -> Self {
        match spec {
            Some(s) => Availability::Churn(ChurnModel::new(s.clone(), seed)),
            None => Availability::AlwaysOn,
        }
    }

    pub fn cycle(&self, device: u64) -> Cycle {
        match self {
            Availability::AlwaysOn => Cycle::always_on(),
            Availability::Churn(m) => m.cycle(device),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ChurnModel {
        ChurnModel::new(ChurnSpec { mean_on_s: 600.0, mean_off_s: 300.0 }, 42)
    }

    #[test]
    fn always_on_is_always_on() {
        let c = Cycle::always_on();
        for t in [0.0, 1.0, 1e6, 12345.678] {
            assert!(c.is_on(t));
        }
    }

    #[test]
    fn cycle_alternates_with_expected_duty() {
        let m = model();
        // duty ≈ 600/900 on average; count over many devices at one instant
        let online = (0..10_000).filter(|&d| m.is_available(d, 5_000.0)).count();
        assert!(
            (5_500..7_800).contains(&online),
            "online={online}, expected ≈ 2/3 of 10k"
        );
        // every device both appears and disappears over a long horizon
        for d in 0..32 {
            let c = m.cycle(d);
            let states: Vec<bool> = (0..200).map(|i| c.is_on(i as f64 * 17.0)).collect();
            assert!(states.iter().any(|&s| s), "device {d} never on");
            assert!(states.iter().any(|&s| !s), "device {d} never off");
        }
    }

    #[test]
    fn dwell_helpers_agree_with_is_on() {
        let m = model();
        for d in 0..16 {
            let c = m.cycle(d);
            for i in 0..200 {
                let t = i as f64 * 23.7;
                if c.is_on(t) {
                    assert_eq!(c.next_on_delay_s(t), 0.0, "device {d} t={t}");
                    let end = c.on_dwell_end_s(t);
                    assert!(end > t, "device {d} t={t}");
                    // just before the dwell end: still on; just past: off
                    assert!(c.is_on(end - 1e-6), "device {d} t={t} end={end}");
                    assert!(!c.is_on(end + 1e-6), "device {d} t={t} end={end}");
                } else {
                    let dt = c.next_on_delay_s(t);
                    assert!(dt > 0.0, "device {d} t={t}");
                    assert!(c.is_on(t + dt + 1e-6), "device {d} t={t} dt={dt}");
                }
            }
        }
        // always-on cycles never disconnect and are never waited on
        let c = Cycle::always_on();
        assert_eq!(c.on_dwell_end_s(123.0), f64::INFINITY);
        assert_eq!(c.next_on_delay_s(123.0), 0.0);
    }

    #[test]
    fn trace_agrees_with_cycle_queries() {
        let m = model();
        for d in 0..16 {
            let trace = m.trace(d, 10_000.0);
            for i in 0..500 {
                let t = i as f64 * 19.97;
                assert_eq!(
                    trace.is_on(t),
                    m.is_available(d, t),
                    "device {d} diverges at t={t}"
                );
            }
        }
    }

    #[test]
    fn traces_are_deterministic_per_seed() {
        let a = model().trace(3, 5_000.0);
        let b = model().trace(3, 5_000.0);
        assert_eq!(a.initially_on, b.initially_on);
        assert_eq!(a.toggles_s, b.toggles_s);
        let other = ChurnModel::new(ChurnSpec { mean_on_s: 600.0, mean_off_s: 300.0 }, 43)
            .trace(3, 5_000.0);
        assert_ne!(a.toggles_s, other.toggles_s);
    }

    #[test]
    fn trace_toggles_are_increasing() {
        let trace = model().trace(9, 50_000.0);
        assert!(trace.toggles_s.windows(2).all(|w| w[0] < w[1]));
        assert!(!trace.toggles_s.is_empty());
    }

    #[test]
    fn zero_off_dwell_means_always_on() {
        // mean_off_s = 0 is valid config; the trace must not emit
        // zero-length off dwells (duplicate toggle times).
        let m = ChurnModel::new(ChurnSpec { mean_on_s: 600.0, mean_off_s: 0.0 }, 42);
        for d in 0..8 {
            let trace = m.trace(d, 50_000.0);
            assert!(trace.initially_on);
            assert!(trace.toggles_s.is_empty());
            assert!(m.is_available(d, 12_345.6));
        }
    }
}
