//! Device availability: per-device on/off churn.
//!
//! Real federated populations are never fully online — phones charge at
//! night, lose signal, leave Wi-Fi. The engine draws cohorts from
//! *available* devices only, using a deterministic per-device on/off
//! cycle synthesized from a seeded RNG: each device gets its own dwell
//! times (around the configured means) and phase, so at any virtual time
//! roughly `mean_on / (mean_on + mean_off)` of the population is online,
//! with membership constantly rotating.
//!
//! The cycle form keeps *point* availability queries O(1) at
//! million-device scale; [`ChurnModel::trace`] materializes the same
//! schedule as an explicit toggle-time trace when a test or an export
//! needs one. Recorded traces are first-class, not just an export
//! format: [`DeviceSchedule`] abstracts over a periodic [`Cycle`] and
//! an explicit [`AvailabilityTrace`], so populations replayed from
//! telemetry files or scenario generators ([`crate::sched::trace`])
//! drive the engine through exactly the machinery the synthetic model
//! uses. For the streaming execution core, which needs the *set*
//! of available devices after every event, [`AvailabilityIndex`]
//! maintains that set incrementally: a time wheel bucketed by next
//! state-transition time plus a swap-remove free-list of idle online
//! devices, so advancing virtual time costs O(transitions elapsed) —
//! amortized O(1) per event — instead of an O(population) rescan.
//!
//! The index's complete internal state is exportable
//! ([`AvailabilityIndex::export_state`]) and restorable
//! ([`AvailabilityIndex::from_state`]) — byte-exactly, free-list order
//! and wheel contents included — because the checkpoint subsystem
//! ([`crate::persist`]) guarantees that a killed-and-resumed streaming
//! run samples exactly the devices the uninterrupted run would have.
#![deny(missing_docs)]

use crate::error::{Error, Result};
use crate::util::par;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Sharded availability scans
// ---------------------------------------------------------------------------
//
// The barrier engine's per-round O(population) passes — "who is online
// now", "when does the next device come online", "build a candidate per
// online device" — shard across `util::par` worker threads. Each shard
// owns a contiguous id-ordered slice, and the merge concatenates shard
// results in shard order, so the output is exactly the sequential scan's:
// parallelism here is invisible to traces, goldens and checkpoints.

/// Indices (as `u32`) of the items satisfying `pred`, in ascending index
/// order — the sharded form of the sequential filter-scan. Per-shard
/// slices are contiguous and merged in shard order, so the result is
/// identical for every `workers` value.
pub fn shard_scan_indices<T, F>(items: &[T], workers: usize, pred: F) -> Vec<u32>
where
    T: Sync,
    F: Fn(&T) -> bool + Sync,
{
    let ranges = par::shard_ranges(items.len(), workers.min(items.len().max(1)));
    let shards = par::run_sharded(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        let mut found = Vec::new();
        for (off, item) in items[lo..hi].iter().enumerate() {
            if pred(item) {
                found.push((lo + off) as u32);
            }
        }
        found
    });
    let mut out = Vec::with_capacity(shards.iter().map(Vec::len).sum());
    for shard in shards {
        out.extend(shard);
    }
    out
}

/// Element-wise map merged in shard order (== input order); the sharded
/// form of `items.iter().map(f).collect()` for a pure `f`.
pub fn shard_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let ranges = par::shard_ranges(items.len(), workers.min(items.len().max(1)));
    let shards = par::run_sharded(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        items[lo..hi].iter().map(&f).collect::<Vec<U>>()
    });
    let mut out = Vec::with_capacity(items.len());
    for shard in shards {
        out.extend(shard);
    }
    out
}

/// Minimum of `f(item)` over all items (infinite when empty). The min of
/// per-shard minima is *exactly* the global minimum — `f64::min` over
/// values that must not be NaN — so the fold order is immaterial and the
/// result is bit-identical for every `workers` value.
pub fn shard_min_by<T, F>(items: &[T], workers: usize, f: F) -> f64
where
    T: Sync,
    F: Fn(&T) -> f64 + Sync,
{
    let ranges = par::shard_ranges(items.len(), workers.min(items.len().max(1)));
    let mins = par::run_sharded(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        items[lo..hi]
            .iter()
            .map(&f)
            .fold(f64::INFINITY, f64::min)
    });
    mins.into_iter().fold(f64::INFINITY, f64::min)
}

/// Churn parameters: mean online / offline dwell times in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnSpec {
    /// Mean online dwell (seconds).
    pub mean_on_s: f64,
    /// Mean offline dwell (seconds).
    pub mean_off_s: f64,
}

/// One device's deterministic on/off cycle: online during the first
/// `on_s` seconds of every `on_s + off_s` period, shifted by `phase_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cycle {
    /// Online dwell length (seconds).
    pub on_s: f64,
    /// Offline dwell length (seconds); 0 = never offline.
    pub off_s: f64,
    /// Phase shift of the cycle at t = 0 (seconds).
    pub phase_s: f64,
}

impl Cycle {
    /// A device that never goes offline.
    pub fn always_on() -> Self {
        Cycle { on_s: 1.0, off_s: 0.0, phase_s: 0.0 }
    }

    /// Is the device online at virtual time `t_s` (t ≥ 0)?
    pub fn is_on(&self, t_s: f64) -> bool {
        (t_s + self.phase_s) % (self.on_s + self.off_s) < self.on_s
    }

    /// End of the on-dwell containing `t_s` — the instant a connection
    /// opened at `t_s` dies. Call only when `is_on(t_s)`; infinite for an
    /// always-on cycle.
    pub fn on_dwell_end_s(&self, t_s: f64) -> f64 {
        if self.off_s <= 0.0 {
            return f64::INFINITY;
        }
        let period = self.on_s + self.off_s;
        let pos = (t_s + self.phase_s) % period;
        debug_assert!(pos < self.on_s, "on_dwell_end_s called while offline");
        t_s + (self.on_s - pos)
    }

    /// Seconds from `t_s` until this device is next online (0 if online
    /// now).
    pub fn next_on_delay_s(&self, t_s: f64) -> f64 {
        let period = self.on_s + self.off_s;
        let pos = (t_s + self.phase_s) % period;
        if pos < self.on_s {
            0.0
        } else {
            period - pos
        }
    }

    /// Distance from `t_s` to this cycle's nearest on/off toggle
    /// (infinite for an always-on cycle). Instants closer than float
    /// noise to a toggle are legitimately ambiguous — on/off answers a
    /// rounding error apart are both defensible — so equivalence checks
    /// (index vs. brute-force rescan) use this to skip them.
    pub fn boundary_distance_s(&self, t_s: f64) -> f64 {
        if self.off_s <= 0.0 {
            return f64::INFINITY;
        }
        let period = self.on_s + self.off_s;
        let pos = (t_s + self.phase_s) % period;
        // nearest of: period start, on->off edge, period end
        pos.min((pos - self.on_s).abs()).min(period - pos)
    }
}

/// Population-wide churn: every device's cycle derives deterministically
/// from (seed, device index).
#[derive(Debug, Clone)]
pub struct ChurnModel {
    seed: u64,
    spec: ChurnSpec,
}

impl ChurnModel {
    /// Build the population-wide churn model for `spec`, seeded so the
    /// whole schedule is reproducible.
    pub fn new(spec: ChurnSpec, seed: u64) -> Self {
        ChurnModel { seed, spec }
    }

    /// The device's on/off cycle. Dwell times are drawn uniformly in
    /// `[0.5, 1.5) ×` the configured mean; the phase is uniform over the
    /// period so devices don't toggle in lockstep.
    pub fn cycle(&self, device: u64) -> Cycle {
        let mut rng = Rng::seed_from(self.seed).derive(device);
        let on_s = self.spec.mean_on_s * (0.5 + rng.f64());
        let off_s = self.spec.mean_off_s * (0.5 + rng.f64());
        let phase_s = rng.f64() * (on_s + off_s);
        Cycle { on_s, off_s, phase_s }
    }

    /// Is `device` online at virtual time `t_s`?
    pub fn is_available(&self, device: u64, t_s: f64) -> bool {
        self.cycle(device).is_on(t_s)
    }

    /// Materialize the device's schedule over `[0, horizon_s)` as an
    /// explicit trace (state at t=0 plus sorted toggle times).
    pub fn trace(&self, device: u64, horizon_s: f64) -> AvailabilityTrace {
        self.cycle(device).materialize(horizon_s)
    }
}

impl Cycle {
    /// Materialize this cycle over `[0, horizon_s)` as an explicit
    /// trace (state at t=0 plus sorted toggle times). Shared by
    /// [`ChurnModel::trace`] and the scenario generators in
    /// [`crate::sched::trace`].
    pub fn materialize(&self, horizon_s: f64) -> AvailabilityTrace {
        if self.off_s <= 0.0 {
            // off_s = 0 is valid config: the device never drops, and
            // emitting zero-length off dwells would break the trace's
            // strictly-increasing toggle contract.
            return AvailabilityTrace { initially_on: true, toggles_s: Vec::new() };
        }
        let period = self.on_s + self.off_s;
        let pos = self.phase_s % period; // position inside the cycle at t=0
        let initially_on = pos < self.on_s;
        let mut toggles_s = Vec::new();
        // time of the first toggle after t=0, then alternate dwell times
        let mut t = if initially_on { self.on_s - pos } else { period - pos };
        let mut on = initially_on;
        while t < horizon_s {
            toggles_s.push(t);
            on = !on;
            t += if on { self.on_s } else { self.off_s };
        }
        AvailabilityTrace { initially_on, toggles_s }
    }
}

/// Explicit per-device availability trace: initial state + toggle times.
#[derive(Debug, Clone, Default)]
pub struct AvailabilityTrace {
    /// State at t = 0.
    pub initially_on: bool,
    /// Strictly increasing times (s) at which the device flips state.
    pub toggles_s: Vec<f64>,
}

impl AvailabilityTrace {
    /// Number of toggles at or before `t_s`.
    fn flips_through(&self, t_s: f64) -> usize {
        self.toggles_s.partition_point(|&x| x <= t_s)
    }

    /// Is the device online at `t_s` according to this trace?
    pub fn is_on(&self, t_s: f64) -> bool {
        self.initially_on ^ (self.flips_through(t_s) % 2 == 1)
    }

    /// The first toggle instant strictly after `t_s`, if any remains —
    /// past its last toggle the device freezes in its final state.
    pub fn next_toggle_after(&self, t_s: f64) -> Option<f64> {
        self.toggles_s.get(self.flips_through(t_s)).copied()
    }

    /// Distance from `t_s` to the nearest toggle (infinite for a
    /// toggle-free trace) — same ambiguity-skip contract as
    /// [`Cycle::boundary_distance_s`].
    pub fn boundary_distance_s(&self, t_s: f64) -> f64 {
        let i = self.flips_through(t_s);
        let after = self
            .toggles_s
            .get(i)
            .map(|&x| x - t_s)
            .unwrap_or(f64::INFINITY);
        let before = if i > 0 { t_s - self.toggles_s[i - 1] } else { f64::INFINITY };
        after.min(before)
    }
}

/// One device's availability schedule: either a synthetic periodic
/// [`Cycle`] or an explicit recorded [`AvailabilityTrace`]. Traces are
/// shared via `Arc` so a million-device population does not duplicate
/// toggle lists between the population and the availability index.
///
/// A trace device freezes in whatever state its last toggle leaves it
/// in; a schedule that never comes back online reports an infinite
/// [`DeviceSchedule::next_on_delay_s`], which the engine's dead-air
/// paths already treat as "this device is gone".
#[derive(Debug, Clone)]
pub enum DeviceSchedule {
    /// Deterministic periodic on/off cycle (always-on or churn model).
    Cycle(Cycle),
    /// Explicit toggle-time trace (recorded file or generated scenario).
    Trace(std::sync::Arc<AvailabilityTrace>),
}

impl From<Cycle> for DeviceSchedule {
    fn from(c: Cycle) -> Self {
        DeviceSchedule::Cycle(c)
    }
}

impl From<AvailabilityTrace> for DeviceSchedule {
    fn from(t: AvailabilityTrace) -> Self {
        DeviceSchedule::Trace(std::sync::Arc::new(t))
    }
}

impl DeviceSchedule {
    /// A device that never goes offline.
    pub fn always_on() -> Self {
        DeviceSchedule::Cycle(Cycle::always_on())
    }

    /// Is the device online at virtual time `t_s`?
    pub fn is_on(&self, t_s: f64) -> bool {
        match self {
            DeviceSchedule::Cycle(c) => c.is_on(t_s),
            DeviceSchedule::Trace(t) => t.is_on(t_s),
        }
    }

    /// End of the on-dwell containing `t_s` — the instant a connection
    /// opened at `t_s` dies. Call only while online; infinite when the
    /// schedule never goes offline again.
    pub fn on_dwell_end_s(&self, t_s: f64) -> f64 {
        match self {
            DeviceSchedule::Cycle(c) => c.on_dwell_end_s(t_s),
            DeviceSchedule::Trace(t) => {
                t.next_toggle_after(t_s).unwrap_or(f64::INFINITY)
            }
        }
    }

    /// Seconds from `t_s` until this device is next online (0 if online
    /// now; infinite when an offline trace never toggles again).
    pub fn next_on_delay_s(&self, t_s: f64) -> f64 {
        match self {
            DeviceSchedule::Cycle(c) => c.next_on_delay_s(t_s),
            DeviceSchedule::Trace(t) => {
                if t.is_on(t_s) {
                    0.0
                } else {
                    t.next_toggle_after(t_s)
                        .map(|x| x - t_s)
                        .unwrap_or(f64::INFINITY)
                }
            }
        }
    }

    /// Distance from `t_s` to this schedule's nearest toggle (infinite
    /// when it never toggles) — see [`Cycle::boundary_distance_s`].
    pub fn boundary_distance_s(&self, t_s: f64) -> f64 {
        match self {
            DeviceSchedule::Cycle(c) => c.boundary_distance_s(t_s),
            DeviceSchedule::Trace(t) => t.boundary_distance_s(t_s),
        }
    }

    /// Absolute next-toggle instant used when (re)building the index's
    /// wheel at `t_s` (`online` = the device's state at `t_s`); `None`
    /// when the schedule never toggles again. For cycles this is the
    /// exact arithmetic the pre-trace index used in its build path, so
    /// cycle-driven runs stay bit-identical.
    fn next_transition_from(&self, t_s: f64, online: bool) -> Option<f64> {
        match self {
            DeviceSchedule::Cycle(c) => {
                if c.off_s <= 0.0 {
                    return None;
                }
                Some(if online {
                    c.on_dwell_end_s(t_s)
                } else {
                    t_s + c.next_on_delay_s(t_s)
                })
            }
            DeviceSchedule::Trace(t) => t.next_toggle_after(t_s),
        }
    }

    /// Relative delay to the next toggle when *processing* a transition
    /// at `t_s`. A separate method because the index's reschedule path
    /// historically computed a relative dwell where its build path
    /// computed an absolute instant; both float shapes are preserved
    /// exactly so cycle-driven runs replay bit-identically across this
    /// refactor.
    fn next_transition_delay(&self, t_s: f64, online: bool) -> Option<f64> {
        match self {
            DeviceSchedule::Cycle(c) => {
                if c.off_s <= 0.0 {
                    return None;
                }
                Some(if online {
                    c.on_dwell_end_s(t_s) - t_s
                } else {
                    c.next_on_delay_s(t_s)
                })
            }
            DeviceSchedule::Trace(t) => t.next_toggle_after(t_s).map(|x| x - t_s),
        }
    }

    /// Rough period estimate for sizing the index's wheel buckets
    /// (`None` when the schedule never toggles). Any value is correct —
    /// this only tunes bucket occupancy.
    fn period_hint_s(&self) -> Option<f64> {
        match self {
            DeviceSchedule::Cycle(c) => {
                if c.off_s > 0.0 {
                    Some(c.on_s + c.off_s)
                } else {
                    None
                }
            }
            DeviceSchedule::Trace(t) => {
                let n = t.toggles_s.len();
                if n >= 2 {
                    Some((t.toggles_s[n - 1] - t.toggles_s[0]) / (n - 1) as f64 * 2.0)
                } else {
                    None
                }
            }
        }
    }
}

/// The population's availability model.
#[derive(Debug, Clone)]
pub enum Availability {
    /// Everyone always online (the paper's testbed setting).
    AlwaysOn,
    /// Per-device deterministic on/off churn.
    Churn(ChurnModel),
}

impl Availability {
    /// Build the model: churn when a spec is configured, always-on
    /// otherwise.
    pub fn from_spec(spec: Option<&ChurnSpec>, seed: u64) -> Self {
        match spec {
            Some(s) => Availability::Churn(ChurnModel::new(s.clone(), seed)),
            None => Availability::AlwaysOn,
        }
    }

    /// The device's on/off cycle under this model.
    pub fn cycle(&self, device: u64) -> Cycle {
        match self {
            Availability::AlwaysOn => Cycle::always_on(),
            Availability::Churn(m) => m.cycle(device),
        }
    }
}

// ---------------------------------------------------------------------------
// AvailabilityIndex: O(1)-amortized incremental membership
// ---------------------------------------------------------------------------

/// Sentinel for "device is not in the idle-online list".
const NOT_LISTED: u32 = u32::MAX;

/// Guard against floating-point stalls when a computed transition does
/// not advance time (a dwell boundary hit within rounding error).
const MIN_TRANSITION_STEP_S: f64 = 1e-9;

/// Smallest schedule step guaranteed to actually advance a float of
/// magnitude `t_s`: the absolute floor alone is absorbed by f64
/// rounding once `t_s` exceeds ~2^24 s, so a relative component (1e-12
/// relative ≫ the 2^-52 machine epsilon) keeps `t + step > t` at any
/// virtual time.
fn min_step_s(t_s: f64) -> f64 {
    MIN_TRANSITION_STEP_S.max(t_s.abs() * 1e-12)
}

/// A calendar-queue of per-device next-transition times: buckets of
/// fixed width over absolute virtual time, entries kept unsorted inside
/// a bucket (processing order within a bucket is deterministic but not
/// time-sorted — membership toggles commute, so only determinism
/// matters). An entry whose time lands a full lap ahead stays in its
/// bucket until the cursor comes around again. The cursor is an integer
/// window index so repeated advancement cannot drift in floating point.
#[derive(Debug, Clone)]
struct TransitionWheel {
    width_s: f64,
    buckets: Vec<Vec<(f64, u32)>>,
    /// Index of the window the cursor is in (`floor(t / width)`).
    cursor_window: u64,
    len: usize,
}

impl TransitionWheel {
    fn new(width_s: f64, num_buckets: usize, t0_s: f64) -> Self {
        let mut wheel = TransitionWheel {
            width_s,
            buckets: vec![Vec::new(); num_buckets.max(1)],
            cursor_window: 0,
            len: 0,
        };
        wheel.cursor_window = wheel.window_of(t0_s);
        wheel
    }

    fn window_of(&self, t_s: f64) -> u64 {
        (t_s / self.width_s) as u64
    }

    fn schedule(&mut self, t_s: f64, device: u32) {
        let b = (self.window_of(t_s) % self.buckets.len() as u64) as usize;
        self.buckets[b].push((t_s, device));
        self.len += 1;
    }

    /// Move entries of the cursor's bucket that are due (`t <= now`)
    /// into `out`. Does not move the cursor. Processing order across
    /// windows is irrelevant for correctness: transitions of distinct
    /// devices commute, and each device has exactly one pending entry.
    fn take_due(&mut self, now_s: f64, out: &mut Vec<(f64, u32)>) {
        if self.len == 0 {
            return;
        }
        let b = (self.cursor_window % self.buckets.len() as u64) as usize;
        let bucket = &mut self.buckets[b];
        let mut i = 0;
        while i < bucket.len() {
            if bucket[i].0 <= now_s {
                out.push(bucket.swap_remove(i));
                self.len -= 1;
            } else {
                i += 1;
            }
        }
    }

    /// Step the cursor to the next window if the current one is entirely
    /// behind `now`; returns false once the cursor window contains `now`.
    fn advance_window(&mut self, now_s: f64) -> bool {
        if self.cursor_window < self.window_of(now_s) {
            self.cursor_window += 1;
            true
        } else {
            false
        }
    }

    /// Earliest scheduled transition, scanning every bucket — O(entries).
    /// Only the dead-air path (nobody online, nothing in flight) needs
    /// this, which is exactly when a full scan was already the status quo.
    fn earliest(&self) -> Option<f64> {
        let mut min: Option<f64> = None;
        for bucket in &self.buckets {
            for &(t, _) in bucket {
                min = Some(match min {
                    Some(m) if m <= t => m,
                    _ => t,
                });
            }
        }
        min
    }
}

/// Incrementally maintained availability membership over a population
/// of on/off [`Cycle`]s — the O(1)-amortized replacement for per-event
/// O(population) rescans in the streaming execution core.
///
/// The index tracks, per device, (a) whether it is online at the
/// index's current time and (b) whether the caller has checked it out
/// (`busy`, e.g. a fit dispatch in flight). Devices that are online and
/// not busy sit in an unordered free-list supporting O(1) insert /
/// swap-remove and O(k) uniform sampling without replacement.
/// [`AvailabilityIndex::advance`] processes exactly the state
/// transitions that elapsed, so total maintenance cost over a run is
/// O(total transitions), independent of how many events interleave.
///
/// Determinism: every operation (transition processing order, list
/// swaps, sampling) is a pure function of the construction input and
/// the call sequence, so identical runs produce identical membership
/// *and* identical list order.
#[derive(Debug, Clone)]
pub struct AvailabilityIndex {
    schedules: Vec<DeviceSchedule>,
    online: Vec<bool>,
    busy: Vec<bool>,
    idle_online: Vec<u32>,
    pos: Vec<u32>,
    wheel: TransitionWheel,
    now_s: f64,
    /// scratch for `advance` (kept to avoid per-call allocation)
    due: Vec<(f64, u32)>,
}

impl AvailabilityIndex {
    /// Build the index over pure cycles at virtual time `t0_s` — the
    /// convenience form of [`AvailabilityIndex::from_schedules`] for
    /// model-synthesized populations. Always-on cycles never schedule
    /// transitions, so a churn-free population costs nothing to advance.
    pub fn new(cycles: Vec<Cycle>, t0_s: f64) -> Self {
        Self::from_schedules(
            cycles.into_iter().map(DeviceSchedule::Cycle).collect(),
            t0_s,
        )
    }

    /// Build the index over arbitrary [`DeviceSchedule`]s (cycles,
    /// recorded traces, or a mix) at virtual time `t0_s`. Schedules
    /// that never toggle again never enter the transition wheel.
    pub fn from_schedules(schedules: Vec<DeviceSchedule>, t0_s: f64) -> Self {
        let n = schedules.len();
        // Bucket width tuned to the mean toggle period; any value is
        // correct, this one keeps buckets small under the default specs.
        let mut period_sum = 0.0f64;
        let mut churny = 0usize;
        for s in &schedules {
            if let Some(p) = s.period_hint_s() {
                period_sum += p;
                churny += 1;
            }
        }
        let width_s = if churny == 0 {
            1.0
        } else {
            (period_sum / churny as f64 / 8.0).clamp(1e-3, 1e7)
        };
        let mut idx = AvailabilityIndex {
            schedules,
            online: vec![false; n],
            busy: vec![false; n],
            idle_online: Vec::with_capacity(n),
            pos: vec![NOT_LISTED; n],
            wheel: TransitionWheel::new(width_s, 512, t0_s),
            now_s: t0_s,
            due: Vec::new(),
        };
        for i in 0..n {
            let online = idx.schedules[i].is_on(t0_s);
            let t_next = idx.schedules[i].next_transition_from(t0_s, online);
            if online {
                idx.online[i] = true;
                idx.list_push(i as u32);
            }
            if let Some(t) = t_next {
                idx.wheel
                    .schedule(t.max(t0_s + min_step_s(t0_s)), i as u32);
            }
        }
        idx
    }

    /// The index's current virtual time.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Devices currently online and not checked out.
    pub fn idle_online_len(&self) -> usize {
        self.idle_online.len()
    }

    /// Is `device` online at the index's current time?
    pub fn is_online(&self, device: u32) -> bool {
        self.online[device as usize]
    }

    /// Advance to `now_s` (monotone; earlier times are a no-op),
    /// processing every state transition in between. Amortized O(1) per
    /// call: each device transition is handled exactly once, whenever it
    /// falls due. A jump longer than a full wheel lap (only possible
    /// after extreme dead air) falls back to an O(population) rebuild —
    /// exactly what a from-scratch rescan would have cost.
    pub fn advance(&mut self, now_s: f64) {
        if now_s <= self.now_s {
            return;
        }
        if self.wheel.len == 0 {
            self.now_s = now_s;
            return;
        }
        if self.wheel.window_of(now_s) - self.wheel.cursor_window
            >= self.wheel.buckets.len() as u64
        {
            self.rebuild(now_s);
            return;
        }
        self.now_s = now_s;
        loop {
            let mut due = std::mem::take(&mut self.due);
            self.wheel.take_due(now_s, &mut due);
            if due.is_empty() {
                self.due = due;
                // window clean: step to the next one or stop at `now`
                if !self.wheel.advance_window(now_s) {
                    break;
                }
                continue;
            }
            for &(t, device) in &due {
                self.apply_transition(t, device);
            }
            due.clear();
            self.due = due;
            // re-scan the same window: a follow-up transition may have
            // landed inside it and already be due
        }
    }

    /// From-scratch reconstruction at `now_s`: recompute every device's
    /// state and next transition directly from its schedule. Busy marks
    /// are preserved.
    fn rebuild(&mut self, now_s: f64) {
        self.now_s = now_s;
        self.idle_online.clear();
        self.pos.iter_mut().for_each(|p| *p = NOT_LISTED);
        self.wheel = TransitionWheel::new(
            self.wheel.width_s,
            self.wheel.buckets.len(),
            now_s,
        );
        for i in 0..self.schedules.len() {
            let online = self.schedules[i].is_on(now_s);
            let t_next = self.schedules[i].next_transition_from(now_s, online);
            self.online[i] = online;
            if online && !self.busy[i] {
                self.list_push(i as u32);
            }
            if let Some(t) = t_next {
                self.wheel
                    .schedule(t.max(now_s + min_step_s(now_s)), i as u32);
            }
        }
    }

    /// Process one scheduled transition: recompute the device's state
    /// from its schedule at the scheduled instant (robust to the
    /// boundary landing a rounding error away) and schedule the next
    /// one, if the schedule ever toggles again (an exhausted trace
    /// simply leaves the wheel).
    fn apply_transition(&mut self, t_s: f64, device: u32) {
        let i = device as usize;
        let on = self.schedules[i].is_on(t_s);
        if on != self.online[i] {
            self.online[i] = on;
            if !self.busy[i] {
                if on {
                    self.list_push(device);
                } else {
                    self.list_remove(device);
                }
            }
        }
        let next = self.schedules[i].next_transition_delay(t_s, on);
        if let Some(dt) = next {
            self.wheel.schedule(t_s + dt.max(min_step_s(t_s)), device);
        }
    }

    /// Check a device out (e.g. a dispatch in flight): it leaves the
    /// idle pool until [`AvailabilityIndex::mark_idle`].
    pub fn mark_busy(&mut self, device: u32) {
        let i = device as usize;
        debug_assert!(!self.busy[i], "device {device} already busy");
        self.busy[i] = true;
        if self.pos[i] != NOT_LISTED {
            self.list_remove(device);
        }
    }

    /// Return a device to the pool; it re-enters the idle-online list
    /// only if its cycle says it is online at the index's current time.
    pub fn mark_idle(&mut self, device: u32) {
        let i = device as usize;
        self.busy[i] = false;
        if self.online[i] && self.pos[i] == NOT_LISTED {
            self.list_push(device);
        }
    }

    /// Uniform sample of `k` distinct idle online devices — O(k) partial
    /// Fisher–Yates over the free-list (the list order this leaves
    /// behind is deterministic).
    pub fn sample_idle(&mut self, rng: &mut Rng, k: usize) -> Vec<u32> {
        let n = self.idle_online.len();
        let k = k.min(n);
        let mut out = Vec::with_capacity(k);
        for j in 0..k {
            let r = j + rng.below(n - j);
            self.idle_online.swap(j, r);
            self.pos[self.idle_online[j] as usize] = j as u32;
            self.pos[self.idle_online[r] as usize] = r as u32;
            out.push(self.idle_online[j]);
        }
        out
    }

    /// Re-derive one device's online state straight from its schedule
    /// at `t_s`, fixing the free-list to match. Callers use this to
    /// reconcile float-boundary disagreements between the wheel's
    /// scheduled transitions and a point `is_on` query (the device's
    /// pending wheel entry stays scheduled; processing it later is
    /// idempotent, since transitions recompute state from the schedule).
    pub fn resync_device(&mut self, device: u32, t_s: f64) {
        let i = device as usize;
        let on = self.schedules[i].is_on(t_s);
        if on != self.online[i] {
            self.online[i] = on;
            if !self.busy[i] {
                if on {
                    self.list_push(device);
                } else {
                    self.list_remove(device);
                }
            }
        }
    }

    /// The idle online devices in ascending id order — the O(available)
    /// materialization for policies that score the whole candidate pool.
    pub fn idle_online_sorted(&self) -> Vec<u32> {
        let mut v = self.idle_online.clone();
        v.sort_unstable();
        v
    }

    /// Earliest pending state transition (absolute virtual time), if any
    /// cycle ever toggles. O(scheduled entries) — dead-air path only.
    pub fn next_transition_s(&self) -> Option<f64> {
        self.wheel.earliest()
    }

    /// Export the index's complete internal state — free-list order and
    /// raw wheel contents included — for checkpointing. Restoring the
    /// result with [`AvailabilityIndex::from_state`] (over the same
    /// schedules) yields an index whose every future observable —
    /// membership, sampling order, transition processing — is
    /// bit-identical to this one's. A canonical rebuild at the same
    /// time would *not* be: the free-list order (which uniform sampling
    /// consumes) and sub-epsilon wheel timestamps are functions of the
    /// whole operation history.
    pub fn export_state(&self) -> IndexState {
        IndexState {
            now_s: self.now_s,
            online: self.online.clone(),
            busy: self.busy.clone(),
            idle_online: self.idle_online.clone(),
            wheel_width_s: self.wheel.width_s,
            wheel_cursor_window: self.wheel.cursor_window,
            wheel_buckets: self.wheel.buckets.clone(),
        }
    }

    /// Rebuild an index from [`AvailabilityIndex::export_state`] output
    /// and the same schedules it was built over. Validates internal
    /// consistency (vector lengths, free-list entries in range and
    /// duplicate-free) so a corrupt checkpoint fails cleanly instead of
    /// resuming into undefined behavior.
    pub fn from_state(schedules: Vec<DeviceSchedule>, state: IndexState) -> Result<Self> {
        let n = schedules.len();
        if state.online.len() != n || state.busy.len() != n {
            return Err(Error::Persist(format!(
                "availability-index state is for {} devices, population has {n}",
                state.online.len()
            )));
        }
        if !(state.wheel_width_s > 0.0) || !state.wheel_width_s.is_finite() {
            return Err(Error::Persist(format!(
                "invalid wheel width {}",
                state.wheel_width_s
            )));
        }
        let mut pos = vec![NOT_LISTED; n];
        for (j, &d) in state.idle_online.iter().enumerate() {
            let i = d as usize;
            if i >= n {
                return Err(Error::Persist(format!(
                    "free-list entry {d} out of range (population {n})"
                )));
            }
            if pos[i] != NOT_LISTED {
                return Err(Error::Persist(format!(
                    "device {d} appears twice in the idle free-list"
                )));
            }
            if !state.online[i] || state.busy[i] {
                return Err(Error::Persist(format!(
                    "free-list entry {d} is not idle-online (online={}, busy={})",
                    state.online[i], state.busy[i]
                )));
            }
            pos[i] = j as u32;
        }
        for bucket in &state.wheel_buckets {
            for &(_, d) in bucket {
                if d as usize >= n {
                    return Err(Error::Persist(format!(
                        "wheel entry for device {d} out of range (population {n})"
                    )));
                }
            }
        }
        let buckets = if state.wheel_buckets.is_empty() {
            vec![Vec::new()]
        } else {
            state.wheel_buckets
        };
        let len = buckets.iter().map(Vec::len).sum();
        let wheel = TransitionWheel {
            width_s: state.wheel_width_s,
            buckets,
            cursor_window: state.wheel_cursor_window,
            len,
        };
        Ok(AvailabilityIndex {
            schedules,
            online: state.online,
            busy: state.busy,
            idle_online: state.idle_online,
            pos,
            wheel,
            now_s: state.now_s,
            due: Vec::new(),
        })
    }

    fn list_push(&mut self, device: u32) {
        debug_assert_eq!(self.pos[device as usize], NOT_LISTED);
        self.pos[device as usize] = self.idle_online.len() as u32;
        self.idle_online.push(device);
    }

    fn list_remove(&mut self, device: u32) {
        let p = self.pos[device as usize] as usize;
        debug_assert!(p < self.idle_online.len());
        self.idle_online.swap_remove(p);
        if p < self.idle_online.len() {
            self.pos[self.idle_online[p] as usize] = p as u32;
        }
        self.pos[device as usize] = NOT_LISTED;
    }
}

/// The complete serializable state of an [`AvailabilityIndex`]
/// ([`AvailabilityIndex::export_state`] /
/// [`AvailabilityIndex::from_state`]). Field order and contents mirror
/// the index's internals verbatim — including the *unsorted* free-list
/// and per-bucket wheel entries — because bit-identical resume depends
/// on exactly that history-dependent state.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexState {
    /// The index's virtual time.
    pub now_s: f64,
    /// Per-device online flag at `now_s`.
    pub online: Vec<bool>,
    /// Per-device checked-out (in-flight) flag.
    pub busy: Vec<bool>,
    /// The idle-online free-list, in its live (history-dependent) order.
    pub idle_online: Vec<u32>,
    /// Transition-wheel bucket width (seconds).
    pub wheel_width_s: f64,
    /// The wheel cursor's integer window index.
    pub wheel_cursor_window: u64,
    /// Raw wheel buckets: `(transition time, device)` entries, bucket
    /// and in-bucket order preserved.
    pub wheel_buckets: Vec<Vec<(f64, u32)>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ChurnModel {
        ChurnModel::new(ChurnSpec { mean_on_s: 600.0, mean_off_s: 300.0 }, 42)
    }

    #[test]
    fn sharded_scans_match_sequential_for_every_worker_count() {
        let m = model();
        let cycles: Vec<Cycle> = (0..1_001).map(|d| m.cycle(d)).collect();
        let t = 5_000.0;
        let seq_idx: Vec<u32> = cycles
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_on(t))
            .map(|(i, _)| i as u32)
            .collect();
        let seq_map: Vec<f64> = cycles.iter().map(|c| c.next_on_delay_s(t)).collect();
        let seq_min = seq_map.iter().copied().fold(f64::INFINITY, f64::min);
        for workers in [1usize, 2, 3, 8, 64, 5_000] {
            let idx = shard_scan_indices(&cycles, workers, |c| c.is_on(t));
            assert_eq!(idx, seq_idx, "scan diverged at workers={workers}");
            let mapped = shard_map(&cycles, workers, |c| c.next_on_delay_s(t));
            assert_eq!(mapped, seq_map, "map diverged at workers={workers}");
            let min = shard_min_by(&cycles, workers, |c| c.next_on_delay_s(t));
            assert_eq!(
                min.to_bits(),
                seq_min.to_bits(),
                "min diverged at workers={workers}"
            );
        }
        // empty-slice edges
        let empty: Vec<Cycle> = Vec::new();
        assert!(shard_scan_indices(&empty, 4, |_| true).is_empty());
        assert!(shard_map(&empty, 4, |_| 0.0).is_empty());
        assert_eq!(shard_min_by(&empty, 4, |_| 0.0), f64::INFINITY);
    }

    #[test]
    fn always_on_is_always_on() {
        let c = Cycle::always_on();
        for t in [0.0, 1.0, 1e6, 12345.678] {
            assert!(c.is_on(t));
        }
    }

    #[test]
    fn cycle_alternates_with_expected_duty() {
        let m = model();
        // duty ≈ 600/900 on average; count over many devices at one instant
        let online = (0..10_000).filter(|&d| m.is_available(d, 5_000.0)).count();
        assert!(
            (5_500..7_800).contains(&online),
            "online={online}, expected ≈ 2/3 of 10k"
        );
        // every device both appears and disappears over a long horizon
        for d in 0..32 {
            let c = m.cycle(d);
            let states: Vec<bool> = (0..200).map(|i| c.is_on(i as f64 * 17.0)).collect();
            assert!(states.iter().any(|&s| s), "device {d} never on");
            assert!(states.iter().any(|&s| !s), "device {d} never off");
        }
    }

    #[test]
    fn dwell_helpers_agree_with_is_on() {
        let m = model();
        for d in 0..16 {
            let c = m.cycle(d);
            for i in 0..200 {
                let t = i as f64 * 23.7;
                if c.is_on(t) {
                    assert_eq!(c.next_on_delay_s(t), 0.0, "device {d} t={t}");
                    let end = c.on_dwell_end_s(t);
                    assert!(end > t, "device {d} t={t}");
                    // just before the dwell end: still on; just past: off
                    assert!(c.is_on(end - 1e-6), "device {d} t={t} end={end}");
                    assert!(!c.is_on(end + 1e-6), "device {d} t={t} end={end}");
                } else {
                    let dt = c.next_on_delay_s(t);
                    assert!(dt > 0.0, "device {d} t={t}");
                    assert!(c.is_on(t + dt + 1e-6), "device {d} t={t} dt={dt}");
                }
            }
        }
        // always-on cycles never disconnect and are never waited on
        let c = Cycle::always_on();
        assert_eq!(c.on_dwell_end_s(123.0), f64::INFINITY);
        assert_eq!(c.next_on_delay_s(123.0), 0.0);
    }

    #[test]
    fn trace_agrees_with_cycle_queries() {
        let m = model();
        for d in 0..16 {
            let trace = m.trace(d, 10_000.0);
            for i in 0..500 {
                let t = i as f64 * 19.97;
                assert_eq!(
                    trace.is_on(t),
                    m.is_available(d, t),
                    "device {d} diverges at t={t}"
                );
            }
        }
    }

    #[test]
    fn traces_are_deterministic_per_seed() {
        let a = model().trace(3, 5_000.0);
        let b = model().trace(3, 5_000.0);
        assert_eq!(a.initially_on, b.initially_on);
        assert_eq!(a.toggles_s, b.toggles_s);
        let other = ChurnModel::new(ChurnSpec { mean_on_s: 600.0, mean_off_s: 300.0 }, 43)
            .trace(3, 5_000.0);
        assert_ne!(a.toggles_s, other.toggles_s);
    }

    #[test]
    fn trace_toggles_are_increasing() {
        let trace = model().trace(9, 50_000.0);
        assert!(trace.toggles_s.windows(2).all(|w| w[0] < w[1]));
        assert!(!trace.toggles_s.is_empty());
    }

    // -- AvailabilityIndex ------------------------------------------------

    fn cycles_for(m: &ChurnModel, n: u64) -> Vec<Cycle> {
        (0..n).map(|d| m.cycle(d)).collect()
    }

    fn scheds(cycles: &[Cycle]) -> Vec<DeviceSchedule> {
        cycles.iter().map(|&c| DeviceSchedule::Cycle(c)).collect()
    }

    /// Brute-force membership at `t`: online and not busy.
    fn brute_idle(cycles: &[Cycle], busy: &[bool], t: f64) -> Vec<u32> {
        (0..cycles.len())
            .filter(|&i| !busy[i] && cycles[i].is_on(t))
            .map(|i| i as u32)
            .collect()
    }

    /// Distance from `t` to the nearest toggle of any cycle — queries
    /// this close to a boundary are legitimately ambiguous in floats.
    fn boundary_distance(cycles: &[Cycle], t: f64) -> f64 {
        cycles
            .iter()
            .map(|c| c.boundary_distance_s(t))
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn index_matches_brute_force_over_monotone_times() {
        let m = model();
        let cycles = cycles_for(&m, 300);
        let mut idx = AvailabilityIndex::new(cycles.clone(), 0.0);
        let busy = vec![false; cycles.len()];
        let mut t = 0.0;
        for step in 0..400 {
            t += 7.3 + (step % 11) as f64 * 13.1;
            if boundary_distance(&cycles, t) < 1e-6 {
                continue; // ambiguous within float noise of a toggle
            }
            idx.advance(t);
            let mut got = idx.idle_online_sorted();
            got.sort_unstable();
            assert_eq!(got, brute_idle(&cycles, &busy, t), "diverged at t={t}");
        }
    }

    #[test]
    fn index_busy_marks_remove_and_restore() {
        let m = ChurnModel::new(ChurnSpec { mean_on_s: 100.0, mean_off_s: 0.0 }, 7);
        let cycles = cycles_for(&m, 10);
        let mut idx = AvailabilityIndex::new(cycles, 0.0);
        assert_eq!(idx.idle_online_len(), 10);
        idx.mark_busy(3);
        idx.mark_busy(7);
        assert_eq!(idx.idle_online_len(), 8);
        assert!(!idx.idle_online_sorted().contains(&3));
        idx.mark_idle(3);
        assert_eq!(idx.idle_online_len(), 9);
        assert!(idx.idle_online_sorted().contains(&3));
        idx.mark_idle(7);
        assert_eq!(idx.idle_online_sorted(), (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn index_busy_device_rejoins_only_when_online() {
        let m = model();
        let cycles = cycles_for(&m, 50);
        let mut idx = AvailabilityIndex::new(cycles.clone(), 0.0);
        // find a device online at t=0 that is offline at some later probe
        let dev = (0..50u32)
            .find(|&d| cycles[d as usize].is_on(0.0))
            .expect("someone online at t=0");
        idx.mark_busy(dev);
        let c = cycles[dev as usize];
        let t_off = c.on_dwell_end_s(0.0) + 1.0; // firmly inside the off dwell
        idx.advance(t_off);
        idx.mark_idle(dev);
        assert!(
            !idx.idle_online_sorted().contains(&dev),
            "offline device re-entered the idle pool"
        );
        assert!(!idx.is_online(dev));
    }

    #[test]
    fn index_sampling_is_uniform_without_replacement_and_deterministic() {
        let m = ChurnModel::new(ChurnSpec { mean_on_s: 1.0, mean_off_s: 0.0 }, 1);
        let cycles = cycles_for(&m, 100);
        let mut a = AvailabilityIndex::new(cycles.clone(), 0.0);
        let mut b = AvailabilityIndex::new(cycles, 0.0);
        let sa = a.sample_idle(&mut Rng::seed_from(9), 20);
        let sb = b.sample_idle(&mut Rng::seed_from(9), 20);
        assert_eq!(sa, sb, "same seed must sample the same devices");
        assert_eq!(sa.len(), 20);
        let mut sorted = sa.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20, "sample repeated a device: {sa:?}");
        // oversampling clamps to the pool
        assert_eq!(a.sample_idle(&mut Rng::seed_from(1), 500).len(), 100);
        // the list stays internally consistent after sampling
        assert_eq!(a.idle_online_sorted(), (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn index_survives_long_jumps_via_rebuild() {
        let m = model();
        let cycles = cycles_for(&m, 100);
        let mut idx = AvailabilityIndex::new(cycles.clone(), 0.0);
        let busy = vec![false; cycles.len()];
        // jump far past a full wheel lap, then resume small steps
        for &t in &[1.0e7, 1.0e7 + 5.0, 1.0e7 + 901.0] {
            if boundary_distance(&cycles, t) < 1e-6 {
                continue;
            }
            idx.advance(t);
            assert_eq!(
                idx.idle_online_sorted(),
                brute_idle(&cycles, &busy, t),
                "diverged after jump to t={t}"
            );
        }
    }

    #[test]
    fn index_next_transition_matches_min_next_on_delay_when_all_offline() {
        // all-offline instant: the next transition must be the earliest
        // device arrival, which is what the dead-air fast-forward needs
        let m = ChurnModel::new(ChurnSpec { mean_on_s: 10.0, mean_off_s: 10_000.0 }, 3);
        let cycles = cycles_for(&m, 40);
        let mut t = 0.0;
        let mut idx = AvailabilityIndex::new(cycles.clone(), 0.0);
        // walk to some instant where nobody is online
        for _ in 0..200 {
            t += 137.0;
            idx.advance(t);
            if idx.idle_online_len() == 0 {
                break;
            }
        }
        assert_eq!(idx.idle_online_len(), 0, "never found an all-offline instant");
        let expected = cycles
            .iter()
            .map(|c| t + c.next_on_delay_s(t))
            .fold(f64::INFINITY, f64::min);
        let got = idx.next_transition_s().expect("churny cycles always schedule");
        assert!(
            (got - expected).abs() < 1e-6,
            "next transition {got} vs expected arrival {expected}"
        );
    }

    #[test]
    fn index_state_roundtrip_is_bit_identical_going_forward() {
        let m = model();
        let cycles = cycles_for(&m, 250);
        let mut a = AvailabilityIndex::new(cycles.clone(), 0.0);
        // build up history-dependent internal order: advance, check
        // devices out and back in, sample
        let mut rng = Rng::seed_from(77);
        let mut t = 0.0;
        for step in 0..60 {
            t += 31.0 + (step % 7) as f64 * 11.0;
            a.advance(t);
            let picked = a.sample_idle(&mut rng, 4);
            for &d in &picked {
                a.mark_busy(d);
            }
            if step % 2 == 0 {
                for &d in &picked {
                    a.mark_idle(d);
                }
            }
        }
        let state = a.export_state();
        let mut b = AvailabilityIndex::from_state(scheds(&cycles), state.clone()).unwrap();
        assert_eq!(b.export_state(), state, "restore must be lossless");
        // identical sampling stream (free-list order restored exactly)
        let mut ra = Rng::seed_from(5);
        let mut rb = Rng::seed_from(5);
        assert_eq!(a.sample_idle(&mut ra, 10), b.sample_idle(&mut rb, 10));
        // identical future transitions
        for dt in [13.0, 250.0, 777.0] {
            t += dt;
            a.advance(t);
            b.advance(t);
            assert_eq!(a.idle_online_sorted(), b.idle_online_sorted(), "diverged at t={t}");
            assert_eq!(a.export_state(), b.export_state(), "internal state diverged at t={t}");
        }
    }

    #[test]
    fn index_state_validation_rejects_corruption() {
        let m = model();
        let cycles = cycles_for(&m, 20);
        let idx = AvailabilityIndex::new(cycles.clone(), 0.0);
        let good = idx.export_state();
        // wrong population size
        assert!(AvailabilityIndex::from_state(scheds(&cycles[..10]), good.clone()).is_err());
        // duplicate free-list entry
        let mut dup = good.clone();
        if dup.idle_online.len() >= 2 {
            dup.idle_online[1] = dup.idle_online[0];
            assert!(AvailabilityIndex::from_state(scheds(&cycles), dup).is_err());
        }
        // out-of-range free-list entry
        let mut oob = good.clone();
        oob.idle_online[0] = 999;
        assert!(AvailabilityIndex::from_state(scheds(&cycles), oob).is_err());
        // free-list entry contradicting the busy flag (would corrupt
        // the swap-remove invariant silently in release builds)
        let mut busy_listed = good.clone();
        busy_listed.busy[busy_listed.idle_online[0] as usize] = true;
        assert!(AvailabilityIndex::from_state(scheds(&cycles), busy_listed).is_err());
        // wheel entry for a device outside the population (would panic
        // in apply_transition on the first advance past its time)
        let mut bad_wheel = good.clone();
        bad_wheel.wheel_buckets[0].push((1.0, 999));
        assert!(AvailabilityIndex::from_state(scheds(&cycles), bad_wheel).is_err());
        // broken wheel width
        let mut bad_w = good;
        bad_w.wheel_width_s = -1.0;
        assert!(AvailabilityIndex::from_state(scheds(&cycles), bad_w).is_err());
    }

    // -- DeviceSchedule: explicit traces ----------------------------------

    #[test]
    fn trace_schedule_helpers_agree_with_cycle_schedule() {
        // A materialized trace must answer every schedule query the way
        // its generating cycle does, away from float-ambiguous toggles.
        let m = model();
        for d in 0..12 {
            let c = m.cycle(d);
            let cyc = DeviceSchedule::Cycle(c);
            let tr: DeviceSchedule = c.materialize(20_000.0).into();
            for i in 0..400 {
                let t = i as f64 * 29.3;
                if cyc.boundary_distance_s(t) < 1e-6 {
                    continue;
                }
                assert_eq!(tr.is_on(t), cyc.is_on(t), "device {d} t={t}");
                let dc = cyc.next_on_delay_s(t);
                let dt = tr.next_on_delay_s(t);
                assert!(
                    (dc - dt).abs() < 1e-6 || (dc == 0.0 && dt == 0.0),
                    "device {d} t={t}: next-on {dt} vs cycle {dc}"
                );
                if tr.is_on(t) {
                    let ec = cyc.on_dwell_end_s(t);
                    let et = tr.on_dwell_end_s(t);
                    if ec < 20_000.0 - 1.0 {
                        assert!((ec - et).abs() < 1e-6, "device {d} t={t}: {et} vs {ec}");
                    }
                }
            }
        }
    }

    #[test]
    fn trace_schedule_freezes_after_last_toggle() {
        let t: DeviceSchedule = AvailabilityTrace {
            initially_on: true,
            toggles_s: vec![10.0, 20.0, 30.0],
        }
        .into();
        assert!(t.is_on(5.0));
        assert!(!t.is_on(15.0));
        assert!(t.is_on(25.0));
        // past the last toggle: frozen offline, never online again
        assert!(!t.is_on(35.0));
        assert!(!t.is_on(1e9));
        assert_eq!(t.next_on_delay_s(35.0), f64::INFINITY);
        assert_eq!(t.on_dwell_end_s(25.0), 30.0);
        // a trace ending online reports an infinite on-dwell
        let open: DeviceSchedule =
            AvailabilityTrace { initially_on: false, toggles_s: vec![10.0] }.into();
        assert!(open.is_on(11.0));
        assert_eq!(open.on_dwell_end_s(11.0), f64::INFINITY);
        assert_eq!(open.next_on_delay_s(5.0), 5.0);
    }

    #[test]
    fn index_over_traces_matches_index_over_cycles() {
        // The tentpole claim: the index ingests explicit toggle
        // schedules natively and maintains the same membership the
        // cycle-driven index does.
        let m = model();
        let cycles = cycles_for(&m, 150);
        let traces: Vec<DeviceSchedule> = cycles
            .iter()
            .map(|c| DeviceSchedule::from(c.materialize(50_000.0)))
            .collect();
        let mut a = AvailabilityIndex::new(cycles.clone(), 0.0);
        let mut b = AvailabilityIndex::from_schedules(traces, 0.0);
        let mut t = 0.0;
        for step in 0..300 {
            t += 11.7 + (step % 13) as f64 * 9.1;
            if t > 45_000.0 {
                break; // stay well inside the materialization horizon
            }
            if boundary_distance(&cycles, t) < 1e-6 {
                continue;
            }
            a.advance(t);
            b.advance(t);
            assert_eq!(
                a.idle_online_sorted(),
                b.idle_online_sorted(),
                "trace-driven index diverged at t={t}"
            );
        }
    }

    #[test]
    fn index_handles_mixed_and_exhausted_schedules() {
        // one cycle, one finite trace, one always-on, one never-on
        let schedules = vec![
            DeviceSchedule::Cycle(Cycle { on_s: 50.0, off_s: 50.0, phase_s: 0.0 }),
            DeviceSchedule::from(AvailabilityTrace {
                initially_on: true,
                toggles_s: vec![30.0],
            }),
            DeviceSchedule::always_on(),
            DeviceSchedule::from(AvailabilityTrace {
                initially_on: false,
                toggles_s: Vec::new(),
            }),
        ];
        let mut idx = AvailabilityIndex::from_schedules(schedules, 0.0);
        assert_eq!(idx.idle_online_sorted(), vec![0, 1, 2]);
        idx.advance(40.0); // device 1's trace is exhausted (off forever)
        assert_eq!(idx.idle_online_sorted(), vec![0, 2]);
        idx.advance(60.0); // cycle device 0 toggles off at 50
        assert_eq!(idx.idle_online_sorted(), vec![2]);
        idx.advance(120.0); // device 0 back on at 100; 1 and 3 stay gone
        assert_eq!(idx.idle_online_sorted(), vec![0, 2]);
        idx.advance(1.0e6);
        assert_eq!(idx.idle_online_sorted(), vec![0, 2]);
    }

    #[test]
    fn zero_off_dwell_means_always_on() {
        // mean_off_s = 0 is valid config; the trace must not emit
        // zero-length off dwells (duplicate toggle times).
        let m = ChurnModel::new(ChurnSpec { mean_on_s: 600.0, mean_off_s: 0.0 }, 42);
        for d in 0..8 {
            let trace = m.trace(d, 50_000.0);
            assert!(trace.initially_on);
            assert!(trace.toggles_s.is_empty());
            assert!(m.is_available(d, 12_345.6));
        }
    }
}
