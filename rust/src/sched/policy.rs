//! Pluggable cohort-selection policies.
//!
//! The paper's closing argument is that quantified system costs "could be
//! used to design more efficient FL algorithms"; this module is that step.
//! A [`SelectionPolicy`] decides *which* clients train each round, given
//! the calibrated [`CostModel`] and what the server has observed so far:
//!
//! * [`UniformRandom`] — the FedAvg baseline (extracted from the strategy
//!   so server, simulator and population engine share one sampler).
//! * [`DeadlineAware`] — the natural generalization of the paper's
//!   τ-cutoff: instead of truncating stragglers after τ, don't pick
//!   clients whose *modeled* round time exceeds τ in the first place.
//! * [`UtilityBased`] — Oort-style: blend statistical utility (recent
//!   training loss, data size) with modeled system cost, plus an
//!   exploration share for never-sampled clients.
//! * [`FairnessCap`] — uniform sampling under a per-device
//!   selection-count cap, so no device is drafted (and drained) far more
//!   often than its peers.
//!
//! All policies are deterministic per seed: same seed + same candidates
//! → same cohort, which the property tests pin down. The pure
//! per-candidate classification pass each policy runs before touching
//! its RNG (feasible/late, scored/fresh, eligible/capped) is sharded
//! across [`crate::util::par::workers`] threads via
//! [`partition_candidates`]; shard outputs merge in shard order, so the
//! cohort is identical for every worker count.
//!
//! Policies that can sample straight off the incremental
//! [`AvailabilityIndex`] additionally implement
//! [`SelectionPolicy::select_streaming`], the O(1)-amortized fast path
//! the streaming execution core uses between events; everyone else gets
//! the materialized candidate view via [`SelectionPolicy::select`].

use crate::device::DeviceProfile;
use crate::sched::availability::AvailabilityIndex;
use crate::sim::cost::CostModel;
use crate::util::par;
use crate::util::rng::{Rng, RngState};

/// Shard a pure per-candidate classification across
/// [`par::workers`] threads. `classify` sorts candidate `i` into the
/// first bucket (`Ok`) or the second (`Err`); each shard walks a
/// contiguous index range in order and the per-shard buckets are
/// concatenated in shard order, so both output vectors are identical to
/// the sequential loop for every worker count. `classify` must be pure
/// — every RNG draw a policy makes happens strictly after this pass.
fn partition_candidates<A, B, F>(candidates: &[Candidate], classify: F) -> (Vec<A>, Vec<B>)
where
    A: Send,
    B: Send,
    F: Fn(usize, &Candidate) -> Result<A, B> + Sync,
{
    let workers = par::workers().min(candidates.len().max(1));
    let ranges = par::shard_ranges(candidates.len(), workers);
    let shards = par::run_sharded(ranges.len(), |s| {
        let (lo, hi) = ranges[s];
        let mut a = Vec::new();
        let mut b = Vec::new();
        for (off, c) in candidates[lo..hi].iter().enumerate() {
            match classify(lo + off, c) {
                Ok(x) => a.push(x),
                Err(y) => b.push(y),
            }
        }
        (a, b)
    });
    let mut a = Vec::new();
    let mut b = Vec::new();
    for (sa, sb) in shards {
        a.extend(sa);
        b.extend(sb);
    }
    (a, b)
}

/// Everything a policy may consult about the round being scheduled.
#[derive(Debug, Clone, Copy)]
pub struct SelectionContext<'a> {
    pub round: u64,
    pub cost: &'a CostModel,
    /// Modeled local train steps a selected client will run this round.
    pub steps_per_round: u64,
    /// Downlink payload bytes per dispatch (server → client), from the
    /// strategy's wire model ([`crate::strategy::wire::WireModel`]).
    pub bytes_down: u64,
    /// Uplink payload bytes per fold (client → server).
    pub bytes_up: u64,
    /// How many clients the round wants.
    pub target_cohort: usize,
    /// Round deadline τ in seconds (modeled download + compute + upload).
    pub deadline_s: Option<f64>,
}

impl SelectionContext<'_> {
    /// Modeled end-to-end round time for one client on `device`.
    ///
    /// Charges one link transfer of `bytes_down + bytes_up`. When the
    /// two directions are equal (every full-precision strategy) this is
    /// bit-identical to the historical `2·comm(model_bytes)`: the comm
    /// model is linear-in-bytes with a single rounding step, and
    /// doubling an IEEE numerator commutes with that rounding.
    pub fn modeled_round_time_s(&self, device: &DeviceProfile) -> f64 {
        let link = self.cost.comm(device, (self.bytes_down + self.bytes_up) as usize);
        self.cost.compute(device, self.steps_per_round).time_s + link.time_s
    }

    /// Modeled end-to-end round energy for one client on `device`.
    pub fn modeled_round_energy_j(&self, device: &DeviceProfile) -> f64 {
        let link = self.cost.comm(device, (self.bytes_down + self.bytes_up) as usize);
        self.cost.compute(device, self.steps_per_round).energy_j + link.energy_j
    }
}

/// What the scheduler knows about one selectable client.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub device: &'static DeviceProfile,
    pub num_examples: u64,
    /// Most recent train loss this client reported (None = never sampled).
    pub last_loss: Option<f64>,
    /// Rounds since this client was last selected (None = never).
    pub rounds_since_selected: Option<u64>,
    /// How many times this client has been selected so far (fairness
    /// policies cap this).
    pub times_selected: u64,
}

/// A cohort-selection policy. `select` returns distinct indices into
/// `candidates`, at most `ctx.target_cohort` of them (exactly
/// `min(target_cohort, candidates.len())` for every policy in this
/// module). Implementations must be deterministic given their seed.
pub trait SelectionPolicy: Send {
    fn name(&self) -> &'static str;

    fn select(&mut self, ctx: &SelectionContext, candidates: &[Candidate]) -> Vec<usize>;

    /// Streaming fast path: draw up to `want` devices straight off the
    /// availability index, without materializing the candidate pool.
    /// Returns *device ids* (not candidate indices). The default `None`
    /// tells the caller this policy needs the full candidate view (it
    /// then builds candidates and calls [`SelectionPolicy::select`]);
    /// policies that only need uniform access — [`UniformRandom`] —
    /// override it, making per-event top-up O(want) amortized instead of
    /// O(population).
    fn select_streaming(
        &mut self,
        _ctx: &SelectionContext,
        _index: &mut AvailabilityIndex,
        _want: usize,
    ) -> Option<Vec<u32>> {
        None
    }

    /// Checkpointing hook: the policy's RNG position, if it carries
    /// one. The default `None` marks the policy as stateless — the
    /// checkpoint subsystem ([`crate::persist`]) then persists nothing
    /// for it and assumes its decisions are a pure function of the
    /// candidates. Every built-in policy overrides this.
    fn rng_state(&self) -> Option<RngState> {
        None
    }

    /// Restore the RNG position captured by
    /// [`SelectionPolicy::rng_state`]. A no-op for stateless policies.
    fn restore_rng(&mut self, _state: &RngState) {}
}

// ---------------------------------------------------------------------------
// UniformRandom
// ---------------------------------------------------------------------------

/// Uniform sampling without replacement — FedAvg's original behavior.
pub struct UniformRandom {
    rng: Rng,
}

impl UniformRandom {
    /// Seeds the RNG directly (no mixing): this is FedAvg's original
    /// sampler, and extracted callers must reproduce historical seeded
    /// cohorts exactly.
    pub fn new(seed: u64) -> Self {
        UniformRandom { rng: Rng::seed_from(seed) }
    }

    /// `min(k, n)` distinct indices in `[0, n)`. Shared with
    /// [`crate::strategy::FedAvg`]'s fraction sampling.
    pub fn pick(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_indices(n, k.min(n))
    }
}

impl SelectionPolicy for UniformRandom {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn select(&mut self, ctx: &SelectionContext, candidates: &[Candidate]) -> Vec<usize> {
        self.pick(candidates.len(), ctx.target_cohort)
    }

    /// Uniform sampling needs nothing but the index: O(want) partial
    /// Fisher–Yates over the idle-online free-list. (This draws from the
    /// same seeded stream as `select`, so a policy instance stays
    /// deterministic whichever path the caller takes — but the streams
    /// are not interchangeable: the fast path consumes O(want) draws
    /// where the materialized path consumes O(available).)
    fn select_streaming(
        &mut self,
        _ctx: &SelectionContext,
        index: &mut AvailabilityIndex,
        want: usize,
    ) -> Option<Vec<u32>> {
        Some(index.sample_idle(&mut self.rng, want))
    }

    fn rng_state(&self) -> Option<RngState> {
        Some(self.rng.state())
    }

    fn restore_rng(&mut self, state: &RngState) {
        self.rng = Rng::restore(state);
    }
}

// ---------------------------------------------------------------------------
// DeadlineAware
// ---------------------------------------------------------------------------

/// Pick uniformly among clients whose modeled round time fits the τ
/// deadline; if the feasible pool is too small, top up with the fastest
/// infeasible clients (they will be the least-late stragglers).
pub struct DeadlineAware {
    rng: Rng,
}

impl DeadlineAware {
    pub fn new(seed: u64) -> Self {
        DeadlineAware { rng: Rng::seed_from(seed ^ 0x00D1) }
    }
}

impl SelectionPolicy for DeadlineAware {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn select(&mut self, ctx: &SelectionContext, candidates: &[Candidate]) -> Vec<usize> {
        let k = ctx.target_cohort.min(candidates.len());
        let (mut feasible, mut late): (Vec<usize>, Vec<(f64, usize)>) =
            partition_candidates(candidates, |i, c| {
                let t = ctx.modeled_round_time_s(c.device);
                match ctx.deadline_s {
                    Some(tau) if t > tau => Err((t, i)),
                    _ => Ok(i),
                }
            });
        self.rng.shuffle(&mut feasible);
        feasible.truncate(k);
        if feasible.len() < k {
            late.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let need = k - feasible.len();
            feasible.extend(late.iter().take(need).map(|&(_, i)| i));
        }
        feasible
    }

    fn rng_state(&self) -> Option<RngState> {
        Some(self.rng.state())
    }

    fn restore_rng(&mut self, state: &RngState) {
        self.rng = Rng::restore(state);
    }
}

// ---------------------------------------------------------------------------
// UtilityBased
// ---------------------------------------------------------------------------

/// Default `(τ/t)^alpha` over-deadline penalty exponent (shared with
/// `config::PolicyConfig::parse` so `"utility"` means the same policy
/// however it's constructed).
pub const DEFAULT_UTILITY_ALPHA: f64 = 2.0;
/// Default share of each cohort reserved for never-sampled clients.
pub const DEFAULT_EXPLORE_FRAC: f64 = 0.1;

/// Oort-flavored utility selection: statistical utility from the client's
/// recent loss and data size, discounted by `(τ/t)^alpha` when the
/// modeled round time `t` overshoots the deadline, with a slight
/// staleness bonus and an `explore_frac` share of each cohort reserved
/// for never-sampled clients.
pub struct UtilityBased {
    rng: Rng,
    pub alpha: f64,
    pub explore_frac: f64,
}

impl UtilityBased {
    pub fn new(seed: u64) -> Self {
        UtilityBased {
            rng: Rng::seed_from(seed ^ 0x007C),
            alpha: DEFAULT_UTILITY_ALPHA,
            explore_frac: DEFAULT_EXPLORE_FRAC,
        }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn with_exploration(mut self, frac: f64) -> Self {
        self.explore_frac = frac.clamp(0.0, 1.0);
        self
    }

    fn score(&self, ctx: &SelectionContext, c: &Candidate, loss: f64) -> f64 {
        let stat = (c.num_examples as f64).sqrt() * loss.max(0.0);
        let sys = match ctx.deadline_s {
            Some(tau) => {
                let t = ctx.modeled_round_time_s(c.device);
                if t > tau {
                    (tau / t).powf(self.alpha)
                } else {
                    1.0
                }
            }
            None => 1.0,
        };
        let staleness = 1.0 + 0.05 * (c.rounds_since_selected.unwrap_or(0) as f64).sqrt();
        stat * sys * staleness
    }
}

impl SelectionPolicy for UtilityBased {
    fn name(&self) -> &'static str {
        "utility"
    }

    fn select(&mut self, ctx: &SelectionContext, candidates: &[Candidate]) -> Vec<usize> {
        let k = ctx.target_cohort.min(candidates.len());
        let this: &Self = self;
        let (mut scored, mut fresh): (Vec<(f64, usize)>, Vec<usize>) =
            partition_candidates(candidates, |i, c| match c.last_loss {
                Some(loss) => Ok((this.score(ctx, c, loss), i)),
                None => Err(i),
            });
        // Highest utility first; index breaks ties deterministically.
        scored.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let explore_n = (k as f64 * self.explore_frac).round() as usize;
        let exploit_n = k.saturating_sub(explore_n).min(scored.len());
        let mut picked: Vec<usize> = scored.iter().take(exploit_n).map(|&(_, i)| i).collect();
        self.rng.shuffle(&mut fresh);
        let need = k - picked.len();
        picked.extend(fresh.into_iter().take(need));
        if picked.len() < k {
            // No fresh clients left: top up from the remaining scored pool.
            let need = k - picked.len();
            picked.extend(scored.iter().skip(exploit_n).take(need).map(|&(_, i)| i));
        }
        picked
    }

    fn rng_state(&self) -> Option<RngState> {
        Some(self.rng.state())
    }

    fn restore_rng(&mut self, state: &RngState) {
        self.rng = Rng::restore(state);
    }
}

// ---------------------------------------------------------------------------
// FairnessCap
// ---------------------------------------------------------------------------

/// Default per-device selection cap for the `fair` policy.
pub const DEFAULT_FAIRNESS_CAP: u64 = 10;

/// Fairness-aware selection: uniform sampling restricted to devices
/// selected fewer than `max_selections` times so far. If the uncapped
/// pool cannot fill the cohort, it tops up with the least-selected
/// capped devices (ties broken by candidate index), so cohorts stay full
/// while selection load spreads as evenly as availability allows.
pub struct FairnessCap {
    rng: Rng,
    /// Hard cap on how often one device is drafted over a run.
    pub max_selections: u64,
}

impl FairnessCap {
    pub fn new(seed: u64) -> Self {
        FairnessCap {
            rng: Rng::seed_from(seed ^ 0xFA1C),
            max_selections: DEFAULT_FAIRNESS_CAP,
        }
    }

    pub fn with_cap(mut self, max_selections: u64) -> Self {
        self.max_selections = max_selections.max(1);
        self
    }
}

impl SelectionPolicy for FairnessCap {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn select(&mut self, ctx: &SelectionContext, candidates: &[Candidate]) -> Vec<usize> {
        let k = ctx.target_cohort.min(candidates.len());
        let cap = self.max_selections;
        let (mut eligible, mut capped): (Vec<usize>, Vec<(u64, usize)>) =
            partition_candidates(candidates, |i, c| {
                if c.times_selected < cap {
                    Ok(i)
                } else {
                    Err((c.times_selected, i))
                }
            });
        self.rng.shuffle(&mut eligible);
        eligible.truncate(k);
        if eligible.len() < k {
            // Not enough uncapped devices: fill with the least-hammered
            // capped ones rather than starving the round.
            capped.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
            let need = k - eligible.len();
            eligible.extend(capped.iter().take(need).map(|&(_, i)| i));
        }
        eligible
    }

    fn rng_state(&self) -> Option<RngState> {
        Some(self.rng.state())
    }

    fn restore_rng(&mut self, state: &RngState) {
        self.rng = Rng::restore(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;

    fn candidate(device: &'static DeviceProfile, last_loss: Option<f64>) -> Candidate {
        Candidate {
            device,
            num_examples: 256,
            last_loss,
            rounds_since_selected: None,
            times_selected: 0,
        }
    }

    fn mixed_candidates() -> Vec<Candidate> {
        // 4 fast (TX2 GPU, factor 1.0) + 4 slow (RPi, factor 6.0)
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let rpi = profiles::by_name("raspberry_pi4").unwrap();
        (0..8)
            .map(|i| candidate(if i < 4 { gpu } else { rpi }, Some(1.0)))
            .collect()
    }

    fn ctx(cost: &CostModel, k: usize, deadline_s: Option<f64>) -> SelectionContext<'_> {
        SelectionContext {
            round: 1,
            cost,
            steps_per_round: 80,
            bytes_down: 547_496,
            bytes_up: 547_496,
            target_cohort: k,
            deadline_s,
        }
    }

    #[test]
    fn uniform_selects_distinct_k() {
        let m = CostModel::default();
        let cands = mixed_candidates();
        let picked = UniformRandom::new(7).select(&ctx(&m, 5, None), &cands);
        assert_eq!(picked.len(), 5);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 5);
        // asking for more than exist clamps
        assert_eq!(UniformRandom::new(7).select(&ctx(&m, 99, None), &cands).len(), 8);
    }

    #[test]
    fn deadline_aware_picks_only_feasible_when_enough() {
        let m = CostModel::default();
        let cands = mixed_candidates();
        // 80 steps × 1.48 s ≈ 118 s on the GPU, ≈ 710 s on the RPi.
        let c = ctx(&m, 4, Some(200.0));
        let picked = DeadlineAware::new(3).select(&c, &cands);
        assert_eq!(picked.len(), 4);
        assert!(picked.iter().all(|&i| i < 4), "picked a straggler: {picked:?}");
    }

    #[test]
    fn deadline_aware_tops_up_with_fastest_stragglers() {
        let m = CostModel::default();
        let cands = mixed_candidates();
        let c = ctx(&m, 6, Some(200.0));
        let picked = DeadlineAware::new(3).select(&c, &cands);
        assert_eq!(picked.len(), 6);
        // all 4 feasible GPUs plus 2 (equally slow) RPis
        assert_eq!(picked.iter().filter(|&&i| i < 4).count(), 4);
    }

    #[test]
    fn deadline_aware_without_deadline_is_uniform() {
        let m = CostModel::default();
        let cands = mixed_candidates();
        let picked = DeadlineAware::new(3).select(&ctx(&m, 8, None), &cands);
        let mut sorted = picked;
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn utility_prefers_high_loss_clients() {
        let m = CostModel::default();
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let cands: Vec<Candidate> = (0..6)
            .map(|i| candidate(gpu, Some(if i < 3 { 0.1 } else { 5.0 })))
            .collect();
        let mut policy = UtilityBased::new(1).with_exploration(0.0);
        let picked = policy.select(&ctx(&m, 3, None), &cands);
        let mut sorted = picked;
        sorted.sort_unstable();
        assert_eq!(sorted, vec![3, 4, 5]);
    }

    #[test]
    fn utility_reserves_exploration_share_for_fresh_clients() {
        let m = CostModel::default();
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let mut cands: Vec<Candidate> = (0..8).map(|_| candidate(gpu, Some(2.0))).collect();
        cands.push(candidate(gpu, None));
        cands.push(candidate(gpu, None));
        let mut policy = UtilityBased::new(1).with_exploration(0.5);
        let picked = policy.select(&ctx(&m, 4, None), &cands);
        assert_eq!(picked.len(), 4);
        let fresh = picked.iter().filter(|&&i| i >= 8).count();
        assert_eq!(fresh, 2, "explore share not honored: {picked:?}");
    }

    #[test]
    fn utility_penalizes_over_deadline_devices() {
        let m = CostModel::default();
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let rpi = profiles::by_name("raspberry_pi4").unwrap();
        // same loss; the RPi blows τ by ~3.5× and must score lower
        let cands = vec![candidate(gpu, Some(1.0)), candidate(rpi, Some(1.0))];
        let mut policy = UtilityBased::new(1).with_exploration(0.0);
        let picked = policy.select(&ctx(&m, 1, Some(200.0)), &cands);
        assert_eq!(picked, vec![0]);
    }

    #[test]
    fn policies_are_deterministic_per_seed() {
        let m = CostModel::default();
        let cands = mixed_candidates();
        let c = ctx(&m, 4, Some(200.0));
        for seed in [0u64, 1, 42, 0xDEAD] {
            assert_eq!(
                UniformRandom::new(seed).select(&c, &cands),
                UniformRandom::new(seed).select(&c, &cands),
            );
            assert_eq!(
                DeadlineAware::new(seed).select(&c, &cands),
                DeadlineAware::new(seed).select(&c, &cands),
            );
            assert_eq!(
                UtilityBased::new(seed).select(&c, &cands),
                UtilityBased::new(seed).select(&c, &cands),
            );
            assert_eq!(
                FairnessCap::new(seed).select(&c, &cands),
                FairnessCap::new(seed).select(&c, &cands),
            );
        }
    }

    #[test]
    fn fairness_cap_excludes_over_selected_devices() {
        let m = CostModel::default();
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let mut cands: Vec<Candidate> = (0..8).map(|_| candidate(gpu, Some(1.0))).collect();
        for c in cands.iter_mut().take(4) {
            c.times_selected = 5; // at the cap
        }
        let mut policy = FairnessCap::new(3).with_cap(5);
        let picked = policy.select(&ctx(&m, 4, None), &cands);
        assert_eq!(picked.len(), 4);
        assert!(
            picked.iter().all(|&i| i >= 4),
            "picked a capped device: {picked:?}"
        );
    }

    #[test]
    fn fairness_cap_tops_up_with_least_selected_when_pool_exhausted() {
        let m = CostModel::default();
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let mut cands: Vec<Candidate> = (0..6).map(|_| candidate(gpu, Some(1.0))).collect();
        // everyone capped, at different counts; 2 under-cap devices
        for (i, c) in cands.iter_mut().enumerate() {
            c.times_selected = match i {
                0 | 1 => 0,
                2 => 7,
                3 => 9,
                _ => 20,
            };
        }
        let mut policy = FairnessCap::new(3).with_cap(5);
        let picked = policy.select(&ctx(&m, 4, None), &cands);
        assert_eq!(picked.len(), 4);
        // both uncapped devices plus the two least-selected capped ones
        assert!(picked.contains(&0) && picked.contains(&1), "{picked:?}");
        assert!(picked.contains(&2) && picked.contains(&3), "{picked:?}");
    }

    #[test]
    fn rng_state_roundtrip_replays_selection() {
        let m = CostModel::default();
        let cands = mixed_candidates();
        let c = ctx(&m, 4, Some(200.0));
        let policies: Vec<Box<dyn SelectionPolicy>> = vec![
            Box::new(UniformRandom::new(3)),
            Box::new(DeadlineAware::new(3)),
            Box::new(UtilityBased::new(3)),
            Box::new(FairnessCap::new(3)),
        ];
        for mut p in policies {
            // burn a draw so the captured state is mid-stream
            let _ = p.select(&c, &cands);
            let state = p.rng_state().expect("built-in policies expose their RNG");
            let first = p.select(&c, &cands);
            p.restore_rng(&state);
            let replay = p.select(&c, &cands);
            assert_eq!(first, replay, "{} did not replay after restore", p.name());
        }
    }

    #[test]
    fn selection_identical_for_every_worker_count() {
        let m = CostModel::default();
        // ragged pool (11 candidates) so shard boundaries land mid-class
        let gpu = profiles::by_name("jetson_tx2_gpu").unwrap();
        let rpi = profiles::by_name("raspberry_pi4").unwrap();
        let mut cands: Vec<Candidate> = (0..11)
            .map(|i| candidate(if i % 3 == 0 { rpi } else { gpu }, Some(0.5 + i as f64)))
            .collect();
        cands[4].last_loss = None;
        cands[7].last_loss = None;
        cands[2].times_selected = 99;
        cands[9].times_selected = 99;
        let c = ctx(&m, 5, Some(200.0));
        let saved = par::workers();
        par::set_workers(1);
        let base = (
            DeadlineAware::new(9).select(&c, &cands),
            UtilityBased::new(9).select(&c, &cands),
            FairnessCap::new(9).select(&c, &cands),
        );
        for w in [2usize, 3, 8, 64] {
            par::set_workers(w);
            assert_eq!(base.0, DeadlineAware::new(9).select(&c, &cands), "workers={w}");
            assert_eq!(base.1, UtilityBased::new(9).select(&c, &cands), "workers={w}");
            assert_eq!(base.2, FairnessCap::new(9).select(&c, &cands), "workers={w}");
        }
        par::set_workers(saved);
    }

    #[test]
    fn uniform_streaming_fast_path_samples_from_index() {
        use crate::sched::availability::{AvailabilityIndex, Cycle};
        let m = CostModel::default();
        let cands = mixed_candidates();
        let c = ctx(&m, 3, None);
        let mut index = AvailabilityIndex::new(vec![Cycle::always_on(); 8], 0.0);
        let mut policy = UniformRandom::new(5);
        let picked = policy
            .select_streaming(&c, &mut index, 3)
            .expect("uniform supports the streaming fast path");
        assert_eq!(picked.len(), 3);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "repeated device: {picked:?}");
        assert!(picked.iter().all(|&d| d < 8));
        // non-uniform policies decline the fast path
        assert!(DeadlineAware::new(5)
            .select_streaming(&c, &mut index, 3)
            .is_none());
        assert!(UtilityBased::new(5)
            .select_streaming(&c, &mut index, 3)
            .is_none());
        assert!(FairnessCap::new(5)
            .select_streaming(&c, &mut index, 3)
            .is_none());
    }
}
