//! Cost-aware client scheduling and the event-driven population engine.
//!
//! The paper quantifies per-device system costs (compute time, comm time,
//! energy) and closes by arguing that those numbers "could be used to
//! design more efficient FL algorithms". This subsystem does exactly
//! that, in three layers:
//!
//! * [`policy`] — pluggable [`policy::SelectionPolicy`] implementations
//!   ([`policy::UniformRandom`], [`policy::DeadlineAware`],
//!   [`policy::UtilityBased`]) that choose each round's cohort from the
//!   calibrated [`crate::sim::cost::CostModel`] and observed client
//!   state.
//! * [`availability`] — per-device on/off churn so cohorts are drawn
//!   from *available* devices only (deterministic cycles + explicit
//!   trace synthesis from a seeded RNG), the
//!   [`availability::DeviceSchedule`] abstraction over cycles and
//!   recorded toggle traces, plus the incremental
//!   [`availability::AvailabilityIndex`]: a time wheel over next
//!   state-transitions + an idle-online free-list, so the streaming
//!   core's per-event top-up is O(1)-amortized instead of an
//!   O(population) rescan — over cycles and explicit traces alike.
//! * [`trace`] — trace-driven availability and device-class scenarios:
//!   [`trace::TraceSet`] files (CSV/JSON, spec in
//!   `rust/src/sched/TRACES.md`), the named scenario generators
//!   (`diurnal`, `charging-gated`, `flash-crowd`), and the
//!   [`trace::AvailabilitySource`] abstraction the engine consumes.
//! * [`engine`] — **one** event-driven virtual-time core
//!   ([`engine::ExecMode`]) that scales to 100k–1M virtual devices by
//!   advancing a binary-heap event queue over modeled costs, training
//!   numerics only for the selected cohort. Synchronous FedAvg rounds
//!   are the degenerate case (buffer = cohort, barrier flush, zero
//!   staleness); with [`crate::config::ScheduleConfig::async_buffer`]
//!   set the same loop streams FedBuff-style: device-finish events fold
//!   into a buffer (staleness-discounted) and every K folds flush a
//!   model version.
//!
//! Wiring: [`crate::config::ScheduleConfig`] describes an experiment
//! (JSON or builder), [`crate::server::Server`] accepts a selection hook
//! so live deployments use the same policies, and
//! [`crate::sim::population`] runs population-scale experiments with
//! real PJRT numerics when artifacts are present (the closed-form
//! surrogate otherwise). See `rust/src/sched/README.md`.

pub mod availability;
pub mod engine;
pub mod policy;
pub mod trace;

pub use availability::{
    Availability, AvailabilityIndex, AvailabilityTrace, ChurnModel, ChurnSpec, Cycle,
    DeviceSchedule, IndexState,
};
pub use engine::{
    CohortTrainer, Engine, ExecMode, Population, PopulationReport, PopulationRound,
    SurrogateTrainer, VirtualDevice,
};
pub use policy::{
    Candidate, DeadlineAware, FairnessCap, SelectionContext, SelectionPolicy, UniformRandom,
    UtilityBased,
};
pub use trace::{AvailabilitySource, TraceEntry, TraceSet};
