//! Minimal leveled logger (offline build — no `tracing`).
//!
//! Level is read once from `FLOWRS_LOG` (`error`, `warn`, `info`, `debug`,
//! `trace`; default `info` — an unrecognized value warns once on stderr and
//! falls back to `info`). Output goes to stderr so experiment tables on
//! stdout stay machine-readable.

pub mod log {
    use std::sync::atomic::{AtomicU8, Ordering};
    use std::sync::OnceLock;
    use std::time::Instant;

    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    #[repr(u8)]
    pub enum Level {
        Error = 0,
        Warn = 1,
        Info = 2,
        Debug = 3,
        Trace = 4,
    }

    static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
    static START: OnceLock<Instant> = OnceLock::new();

    fn level() -> u8 {
        let l = LEVEL.load(Ordering::Relaxed);
        if l != u8::MAX {
            return l;
        }
        let parsed = match std::env::var("FLOWRS_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("warn") => Level::Warn,
            Ok("debug") => Level::Debug,
            Ok("trace") => Level::Trace,
            Ok("info") | Err(_) => Level::Info,
            Ok(other) => {
                // A typo like FLOWRS_LOG=inof silently running at the
                // default level is a debugging trap — warn once.
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "[flowrs] unrecognized FLOWRS_LOG value {other:?} \
                         (expected error|warn|info|debug|trace); using info"
                    );
                });
                Level::Info
            }
        } as u8;
        LEVEL.store(parsed, Ordering::Relaxed);
        parsed
    }

    /// Override the level programmatically (tests, CLI flags).
    pub fn set_level(l: Level) {
        LEVEL.store(l as u8, Ordering::Relaxed);
    }

    fn emit(tag: &str, msg: &str) {
        let t = START.get_or_init(Instant::now).elapsed();
        eprintln!("[{:>9.3}s {tag}] {msg}", t.as_secs_f64());
    }

    pub fn error(msg: &str) {
        if level() >= Level::Error as u8 {
            emit("ERROR", msg);
        }
    }

    pub fn warn(msg: &str) {
        if level() >= Level::Warn as u8 {
            emit("WARN ", msg);
        }
    }

    pub fn info(msg: &str) {
        if level() >= Level::Info as u8 {
            emit("INFO ", msg);
        }
    }

    pub fn debug(msg: &str) {
        if level() >= Level::Debug as u8 {
            emit("DEBUG", msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::log::{set_level, Level};

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Error);
        // nothing to assert on output; just exercise the paths
        super::log::error("e");
        super::log::warn("w");
        super::log::info("i");
        super::log::debug("d");
        set_level(Level::Info);
    }
}
