//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from the Rust hot path.
//!
//! The `xla` crate's wrappers hold raw pointers and are `!Send`, so all
//! PJRT state lives on one dedicated **executor thread**; the [`Runtime`]
//! handle is a cheap, cloneable channel front-end that any thread or async
//! task can call. Executables are compiled once (lazily, on first use) and
//! cached for the life of the process — after that, a train step is a
//! channel round-trip plus the XLA execution itself.
//!
//! Interchange with Python is HLO *text* (`HloModuleProto::from_text_file`),
//! not serialized protos — see `python/compile/aot.py` for why.

pub mod manifest;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

pub use manifest::{default_artifact_dir, ArtifactEntry, IoSpec, Manifest, ModelEntry};

use crate::error::{Error, Result};
use crate::proto::Tensor;

use exec::executor_thread;

struct Job {
    artifact: String,
    inputs: Vec<Tensor>,
    resp: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// Counters exposed for benches and the perf pass.
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub executions: AtomicU64,
    pub compilations: AtomicU64,
}

/// Cloneable handle to the executor thread.
#[derive(Clone)]
pub struct Runtime {
    tx: mpsc::Sender<Job>,
    manifest: Arc<Manifest>,
    stats: Arc<RuntimeStats>,
}

impl Runtime {
    /// Load the manifest from `dir` and spin up the executor thread.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Arc::new(Manifest::load(dir)?);
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(RuntimeStats::default());
        let thread_manifest = Arc::clone(&manifest);
        let thread_stats = Arc::clone(&stats);
        std::thread::Builder::new()
            .name("flowrs-pjrt".into())
            .spawn(move || executor_thread(thread_manifest, rx, ready_tx, thread_stats))
            .map_err(|e| Error::Runtime(format!("spawn executor thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("executor thread died during startup".into()))??;
        Ok(Runtime { tx, manifest, stats })
    }

    /// Load from the default artifact directory (`$FLOWRS_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn executions(&self) -> u64 {
        self.stats.executions.load(Ordering::Relaxed)
    }

    /// Execute an artifact by name. Blocking; validated against the
    /// manifest signature before crossing the channel.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(artifact)?;
        if spec.inputs.len() != inputs.len() {
            return Err(Error::Runtime(format!(
                "{artifact}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (want, got)) in spec.inputs.iter().zip(&inputs).enumerate() {
            if want.shape != got.shape {
                return Err(Error::Runtime(format!(
                    "{artifact}: input {i} shape mismatch: manifest {:?}, got {:?}",
                    want.shape, got.shape
                )));
            }
            let dtype = got.data.dtype_name();
            if want.dtype != dtype {
                return Err(Error::Runtime(format!(
                    "{artifact}: input {i} dtype mismatch: manifest {}, got {dtype}",
                    want.dtype
                )));
            }
        }
        let (resp_tx, resp_rx) = mpsc::channel();
        self.tx
            .send(Job { artifact: artifact.to_string(), inputs, resp: resp_tx })
            .map_err(|_| Error::Runtime("executor thread gone".into()))?;
        resp_rx
            .recv()
            .map_err(|_| Error::Runtime("executor thread dropped response".into()))?
    }

    // -- Typed helpers over the model artifacts --------------------------

    /// Initial (flat) global parameters for a model.
    pub fn initial_parameters(&self, model: &str) -> Result<Vec<f32>> {
        self.manifest.initial_parameters(model)
    }

    /// One local SGD step: returns (new_params, loss).
    pub fn train_step(
        &self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let entry = self.manifest.model(model)?;
        let b = entry.train_batch;
        let mut x_shape = vec![b];
        x_shape.extend_from_slice(&entry.input_shape);
        let artifact = entry.train.trim_end_matches(".hlo.txt").to_string();
        let outputs = self.execute(
            &artifact,
            vec![
                Tensor::f32(vec![entry.param_count], params.to_vec())?,
                Tensor::f32(x_shape, x.to_vec())?,
                Tensor::i32(vec![b], y.to_vec())?,
                Tensor::scalar_f32(lr),
            ],
        )?;
        decode_train_outputs(outputs)
    }

    /// One FedProx local step (adds the μ/2‖w−w_global‖² proximal term).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step_prox(
        &self,
        model: &str,
        params: &[f32],
        global: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let entry = self.manifest.model(model)?;
        let b = entry.train_batch;
        let mut x_shape = vec![b];
        x_shape.extend_from_slice(&entry.input_shape);
        let artifact = entry.train_prox.trim_end_matches(".hlo.txt").to_string();
        let outputs = self.execute(
            &artifact,
            vec![
                Tensor::f32(vec![entry.param_count], params.to_vec())?,
                Tensor::f32(vec![entry.param_count], global.to_vec())?,
                Tensor::f32(x_shape, x.to_vec())?,
                Tensor::i32(vec![b], y.to_vec())?,
                Tensor::scalar_f32(lr),
                Tensor::scalar_f32(mu),
            ],
        )?;
        decode_train_outputs(outputs)
    }

    /// Evaluate one batch: returns (mean_loss, correct_count).
    pub fn eval_step(
        &self,
        model: &str,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let entry = self.manifest.model(model)?;
        let b = entry.eval_batch;
        let mut x_shape = vec![b];
        x_shape.extend_from_slice(&entry.input_shape);
        let artifact = entry.eval.trim_end_matches(".hlo.txt").to_string();
        let outputs = self.execute(
            &artifact,
            vec![
                Tensor::f32(vec![entry.param_count], params.to_vec())?,
                Tensor::f32(x_shape, x.to_vec())?,
                Tensor::i32(vec![b], y.to_vec())?,
            ],
        )?;
        let mut it = outputs.into_iter();
        let loss = scalar_out(it.next(), "loss")?;
        let correct = scalar_out(it.next(), "correct")?;
        Ok((loss, correct))
    }

    /// Frozen base model: raw inputs [B, base_input] -> features [B, dim].
    /// `train_path` selects the train-batch (true) or eval-batch artifact.
    pub fn base_features(
        &self,
        model: &str,
        x: &[f32],
        base_w: &[f32],
        base_b: &[f32],
        train_path: bool,
    ) -> Result<Vec<f32>> {
        let entry = self.manifest.model(model)?;
        let (file, b) = if train_path {
            (entry.features_train.as_ref(), entry.train_batch)
        } else {
            (entry.features_eval.as_ref(), entry.eval_batch)
        };
        let file = file.ok_or_else(|| {
            Error::Runtime(format!("model {model} has no frozen-base artifacts"))
        })?;
        let base_in = entry
            .base_input
            .ok_or_else(|| Error::Runtime(format!("model {model} has no base_input")))?;
        let dim = entry
            .feature_dim
            .ok_or_else(|| Error::Runtime(format!("model {model} has no feature_dim")))?;
        let artifact = file.trim_end_matches(".hlo.txt").to_string();
        let outputs = self.execute(
            &artifact,
            vec![
                Tensor::f32(vec![b, base_in], x.to_vec())?,
                Tensor::f32(vec![base_in, dim], base_w.to_vec())?,
                Tensor::f32(vec![dim], base_b.to_vec())?,
            ],
        )?;
        outputs
            .into_iter()
            .next()
            .ok_or_else(|| Error::Runtime("features artifact returned nothing".into()))?
            .into_f32()
    }

    /// FedAvg aggregation on the PJRT path: weighted sum of client vectors.
    ///
    /// `weights` are pre-normalized by the caller; unused slots (up to the
    /// artifact's fixed `agg_slots`) are zero-padded and contribute nothing.
    pub fn aggregate(&self, model: &str, vectors: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let entry = self.manifest.model(model)?;
        let k = entry.agg_slots;
        let p = entry.param_count;
        if vectors.len() != weights.len() {
            return Err(Error::Aggregation(format!(
                "{} vectors but {} weights",
                vectors.len(),
                weights.len()
            )));
        }
        if vectors.len() > k {
            return Err(Error::Aggregation(format!(
                "cohort of {} exceeds the aggregation artifact's {k} slots",
                vectors.len()
            )));
        }
        let mut stacked = vec![0f32; k * p];
        for (i, v) in vectors.iter().enumerate() {
            if v.len() != p {
                return Err(Error::Aggregation(format!(
                    "client vector {i} has {} params, expected {p}",
                    v.len()
                )));
            }
            stacked[i * p..(i + 1) * p].copy_from_slice(v);
        }
        let mut w = vec![0f32; k];
        w[..weights.len()].copy_from_slice(weights);
        let artifact = entry.agg.trim_end_matches(".hlo.txt").to_string();
        let outputs = self.execute(
            &artifact,
            vec![Tensor::f32(vec![k, p], stacked)?, Tensor::f32(vec![k], w)?],
        )?;
        outputs
            .into_iter()
            .next()
            .ok_or_else(|| Error::Runtime("agg artifact returned nothing".into()))?
            .into_f32()
    }
}

fn decode_train_outputs(outputs: Vec<Tensor>) -> Result<(Vec<f32>, f32)> {
    let mut it = outputs.into_iter();
    let params = it
        .next()
        .ok_or_else(|| Error::Runtime("train step returned nothing".into()))?
        .into_f32()?;
    let loss = scalar_out(it.next(), "loss")?;
    Ok((params, loss))
}

fn scalar_out(t: Option<Tensor>, what: &str) -> Result<f32> {
    let t = t.ok_or_else(|| Error::Runtime(format!("missing {what} output")))?;
    let v = t.as_f32()?;
    v.first()
        .copied()
        .ok_or_else(|| Error::Runtime(format!("empty {what} output")))
}

// ---------------------------------------------------------------------------
// Executor thread — real PJRT behind the `xla` feature, a stub otherwise
// (manifest loading and the typed helpers above work either way; without
// the feature every execution request fails with a clear message, and
// the artifact-gated tests/benches skip at runtime as before)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod exec {
    use super::*;
    use crate::proto::TensorData;
    use std::collections::HashMap;

    pub(super) fn executor_thread(
        manifest: Arc<Manifest>,
        rx: mpsc::Receiver<Job>,
        ready: mpsc::Sender<Result<()>>,
        stats: Arc<RuntimeStats>,
    ) {
        let client = match xla::PjRtClient::cpu() {
            Ok(c) => {
                let _ = ready.send(Ok(()));
                c
            }
            Err(e) => {
                let _ = ready.send(Err(Error::Runtime(format!("PjRtClient::cpu: {e}"))));
                return;
            }
        };
        let mut executables: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

        while let Ok(job) = rx.recv() {
            let result = run_job(&manifest, &client, &mut executables, &stats, &job);
            let _ = job.resp.send(result);
        }
    }

    fn run_job(
        manifest: &Manifest,
        client: &xla::PjRtClient,
        executables: &mut HashMap<String, xla::PjRtLoadedExecutable>,
        stats: &RuntimeStats,
        job: &Job,
    ) -> Result<Vec<Tensor>> {
        if !executables.contains_key(&job.artifact) {
            let path = manifest.artifact_path(&job.artifact)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            stats.compilations.fetch_add(1, Ordering::Relaxed);
            executables.insert(job.artifact.clone(), exe);
        }
        let exe = executables.get(&job.artifact).expect("just inserted");

        // Perf/leak note (EXPERIMENTS.md §Perf): `execute::<Literal>` goes
        // through the C shim's `execute()`, which `.release()`s every
        // host-transferred input buffer and never frees it (~0.5 MB leaked per
        // train step — the original table run OOMed at 36 GB). Building the
        // input buffers ourselves and calling `execute_b` keeps ownership on
        // the Rust side, so inputs are freed on drop.
        let buffers: Vec<xla::PjRtBuffer> = job
            .inputs
            .iter()
            .map(|t| tensor_to_buffer(client, t))
            .collect::<Result<_>>()?;
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let tuple = result[0][0].to_literal_sync()?;
        stats.executions.fetch_add(1, Ordering::Relaxed);
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = tuple
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("expected tuple output: {e}")))?;
        parts.into_iter().map(literal_to_tensor).collect()
    }

    fn tensor_to_buffer(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
        // Host-to-device transfer with Rust-side ownership (freed on drop).
        match &t.data {
            TensorData::F32(v) => Ok(client.buffer_from_host_buffer(v, &t.shape, None)?),
            TensorData::F32Shared(v) => {
                Ok(client.buffer_from_host_buffer(v.as_slice(), &t.shape, None)?)
            }
            TensorData::I32(v) => Ok(client.buffer_from_host_buffer(v, &t.shape, None)?),
            TensorData::F16(_) => {
                // f16 is a wire-compression format only; artifacts take f32.
                Err(Error::Runtime(
                    "f16 tensors must be dequantized before execution".into(),
                ))
            }
        }
    }

    fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            other => {
                return Err(Error::Runtime(format!(
                    "unsupported output element type {other:?}"
                )))
            }
        };
        Ok(Tensor { shape: dims, data })
    }
}

#[cfg(not(feature = "xla"))]
mod exec {
    use super::*;

    pub(super) fn executor_thread(
        _manifest: Arc<Manifest>,
        rx: mpsc::Receiver<Job>,
        ready: mpsc::Sender<Result<()>>,
        _stats: Arc<RuntimeStats>,
    ) {
        // Fail the load handshake (mirroring the real path's
        // PjRtClient::cpu failure) so callers' skip/surrogate fallbacks
        // engage up front instead of discovering a dead runtime
        // mid-experiment.
        let _ = ready.send(Err(Error::Runtime(
            "flowrs was built without the `xla` feature: the PJRT runtime is \
             stubbed and cannot execute artifacts"
                .into(),
        )));
        drop(rx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            // Stubbed-runtime builds (no `xla` feature) skip; with the
            // real binding, a load failure is a genuine regression.
            Err(e) if !cfg!(feature = "xla") => {
                crate::telemetry::log::warn(&format!("skipping: runtime unavailable ({e})"));
                None
            }
            Err(e) => panic!("runtime failed to load with artifacts present: {e}"),
        }
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        let entry = rt.manifest().model("head").unwrap().clone();
        // wrong param length
        let err = rt
            .train_step("head", &vec![0.0; 3], &vec![0.0; 10], &vec![0; 10], 0.1)
            .unwrap_err();
        assert!(err.to_string().contains("shape") || err.to_string().contains("elements"));
        // sanity: entry knows its shapes
        assert_eq!(entry.input_shape, vec![1280]);
    }

    #[test]
    fn head_train_step_decreases_loss() {
        let Some(rt) = runtime() else { return };
        let entry = rt.manifest().model("head").unwrap().clone();
        let b = entry.train_batch;
        let mut params = rt.initial_parameters("head").unwrap();
        // deterministic learnable batch: class spike features
        let mut x = vec![0f32; b * 1280];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let cls = (i % 31) as i32;
            y[i] = cls;
            x[i * 1280 + cls as usize] = 5.0;
        }
        let (_, first_loss) = rt.train_step("head", &params, &x, &y, 0.0).unwrap();
        for _ in 0..15 {
            let (p, _) = rt.train_step("head", &params, &x, &y, 0.1).unwrap();
            params = p;
        }
        let (_, last_loss) = rt.train_step("head", &params, &x, &y, 0.0).unwrap();
        assert!(
            last_loss < first_loss * 0.8,
            "loss did not decrease: {first_loss} -> {last_loss}"
        );
    }

    #[test]
    fn aggregate_matches_cpu_reference() {
        let Some(rt) = runtime() else { return };
        let p = rt.manifest().model("head").unwrap().param_count;
        let a: Vec<f32> = (0..p).map(|i| (i % 7) as f32).collect();
        let b: Vec<f32> = (0..p).map(|i| (i % 5) as f32 * -1.0).collect();
        let out = rt.aggregate("head", &[&a, &b], &[0.25, 0.75]).unwrap();
        for i in (0..p).step_by(9173) {
            let want = 0.25 * a[i] + 0.75 * b[i];
            assert!((out[i] - want).abs() < 1e-5, "i={i}: {} vs {want}", out[i]);
        }
    }

    #[test]
    fn aggregate_rejects_oversized_cohort() {
        let Some(rt) = runtime() else { return };
        let entry = rt.manifest().model("head").unwrap().clone();
        let v = vec![0f32; entry.param_count];
        let refs: Vec<&[f32]> = (0..entry.agg_slots + 1).map(|_| v.as_slice()).collect();
        let w = vec![0.1f32; entry.agg_slots + 1];
        assert!(rt.aggregate("head", &refs, &w).is_err());
    }
}
