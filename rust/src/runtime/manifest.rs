//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime: which HLO files exist, their input/output
//! signatures, each model's flat-parameter layout and batch shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Input/output tensor signature of an artifact entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub sha256: String,
}

/// One trainable model (cifar_cnn, head) and its artifact set.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub param_count: usize,
    pub layout: Vec<(String, Vec<usize>)>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub agg_slots: usize,
    pub init_file: String,
    pub train: String,
    pub train_prox: String,
    pub eval: String,
    pub agg: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    /// Present only for the head model (frozen-base pipeline).
    pub base_input: Option<usize>,
    pub feature_dim: Option<usize>,
    pub features_train: Option<String>,
    pub features_eval: Option<String>,
}

fn io_spec(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        shape: v.get("shape")?.as_usize_vec()?,
        dtype: v.get("dtype")?.as_str()?.to_string(),
    })
}

fn artifact_entry(v: &Json) -> Result<ArtifactEntry> {
    Ok(ArtifactEntry {
        file: v.get("file")?.as_str()?.to_string(),
        inputs: v.get("inputs")?.as_arr()?.iter().map(io_spec).collect::<Result<_>>()?,
        outputs: v.get("outputs")?.as_arr()?.iter().map(io_spec).collect::<Result<_>>()?,
        sha256: v.get("sha256")?.as_str()?.to_string(),
    })
}

fn model_entry(v: &Json) -> Result<ModelEntry> {
    let layout = v
        .get("layout")?
        .as_arr()?
        .iter()
        .map(|pair| {
            let pair = pair.as_arr()?;
            if pair.len() != 2 {
                return Err(Error::Artifact("layout entry must be [name, shape]".into()));
            }
            Ok((pair[0].as_str()?.to_string(), pair[1].as_usize_vec()?))
        })
        .collect::<Result<Vec<_>>>()?;
    let opt_usize = |key: &str| -> Result<Option<usize>> {
        v.opt(key).map(Json::as_usize).transpose()
    };
    let opt_str = |key: &str| -> Result<Option<String>> {
        v.opt(key).map(|j| j.as_str().map(str::to_string)).transpose()
    };
    Ok(ModelEntry {
        param_count: v.get("param_count")?.as_usize()?,
        layout,
        train_batch: v.get("train_batch")?.as_usize()?,
        eval_batch: v.get("eval_batch")?.as_usize()?,
        agg_slots: v.get("agg_slots")?.as_usize()?,
        init_file: v.get("init_file")?.as_str()?.to_string(),
        train: v.get("train")?.as_str()?.to_string(),
        train_prox: v.get("train_prox")?.as_str()?.to_string(),
        eval: v.get("eval")?.as_str()?.to_string(),
        agg: v.get("agg")?.as_str()?.to_string(),
        input_shape: v.get("input_shape")?.as_usize_vec()?,
        num_classes: v.get("num_classes")?.as_usize()?,
        base_input: opt_usize("base_input")?,
        feature_dim: opt_usize("feature_dim")?,
        features_train: opt_str("features_train")?,
        features_eval: opt_str("features_eval")?,
    })
}

impl ModelEntry {
    /// Per-example input element count for the *training* path
    /// (raw pixels for cifar_cnn, extracted features for head).
    pub fn example_elements(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let manifest = Self::parse(&text, dir)?;
        manifest.validate()?;
        Ok(manifest)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let doc =
            Json::parse(text).map_err(|e| Error::Artifact(format!("manifest json: {e}")))?;
        let mut models = BTreeMap::new();
        for (name, v) in doc.get("models")?.as_obj()? {
            models.insert(
                name.clone(),
                model_entry(v)
                    .map_err(|e| Error::Artifact(format!("model {name}: {e}")))?,
            );
        }
        let mut artifacts = BTreeMap::new();
        for (name, v) in doc.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                artifact_entry(v)
                    .map_err(|e| Error::Artifact(format!("artifact {name}: {e}")))?,
            );
        }
        Ok(Manifest {
            version: doc.get("version")?.as_usize()? as u32,
            models,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    fn validate(&self) -> Result<()> {
        if self.version != 1 {
            return Err(Error::Artifact(format!(
                "unsupported manifest version {}",
                self.version
            )));
        }
        for (name, model) in &self.models {
            let declared: usize = model
                .layout
                .iter()
                .map(|(_, shape)| shape.iter().product::<usize>())
                .sum();
            if declared != model.param_count {
                return Err(Error::Artifact(format!(
                    "model {name}: layout sums to {declared}, param_count says {}",
                    model.param_count
                )));
            }
            for file in [&model.train, &model.train_prox, &model.eval, &model.agg] {
                let stem = file.trim_end_matches(".hlo.txt");
                if !self.artifacts.contains_key(stem) {
                    return Err(Error::Artifact(format!(
                        "model {name}: artifact {stem} missing from manifest"
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown model {name:?}")))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Read a model's initial (flat f32 LE) parameters.
    pub fn initial_parameters(&self, model: &str) -> Result<Vec<f32>> {
        let entry = self.model(model)?;
        let path = self.dir.join(&entry.init_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Artifact(format!("cannot read {}: {e}", path.display())))?;
        if bytes.len() != 4 * entry.param_count {
            return Err(Error::Artifact(format!(
                "init blob {} has {} bytes, expected {}",
                path.display(),
                bytes.len(),
                4 * entry.param_count
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Default artifact directory: `$FLOWRS_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("FLOWRS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = repo_artifacts() else { return };
        assert_eq!(m.version, 1);
        assert!(m.models.contains_key("cifar_cnn"));
        assert!(m.models.contains_key("head"));
        let cnn = m.model("cifar_cnn").unwrap();
        assert_eq!(cnn.input_shape, vec![32, 32, 3]);
        assert_eq!(cnn.num_classes, 10);
    }

    #[test]
    fn init_blob_round() {
        let Some(m) = repo_artifacts() else { return };
        let init = m.initial_parameters("head").unwrap();
        assert_eq!(init.len(), m.model("head").unwrap().param_count);
        assert!(init.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn unknown_model_errors() {
        let Some(m) = repo_artifacts() else { return };
        assert!(m.model("resnet152").is_err());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn validate_catches_bad_layout() {
        let json = r#"{
            "version": 1,
            "models": {"m": {
                "param_count": 5,
                "layout": [["w", [2, 2]]],
                "train_batch": 1, "eval_batch": 1, "agg_slots": 1,
                "init_file": "x.bin",
                "train": "t.hlo.txt", "train_prox": "t.hlo.txt",
                "eval": "t.hlo.txt", "agg": "t.hlo.txt",
                "input_shape": [2], "num_classes": 2
            }},
            "artifacts": {"t": {"file": "t.hlo.txt", "inputs": [], "outputs": [], "sha256": ""}}
        }"#;
        let m = Manifest::parse(json, &PathBuf::from(".")).unwrap();
        assert!(m.validate().is_err());
    }
}
