//! The client event loop: register, then serve fit/evaluate requests until
//! the server says goodbye. This is the Rust analogue of the Android
//! client's background `StreamObserver` thread (paper Figure 2): messages
//! arrive, the appropriate on-device method runs, the result streams back.

use crate::client::Client;
use crate::error::{Error, Result};
use crate::proto::{ClientInfo, ClientMessage, ServerMessage, Status, StatusCode};
use crate::transport::Connection;

/// Run a client against an established connection. Returns when the server
/// sends `Reconnect` (clean shutdown) or the connection drops.
///
/// Speaks wire v1 end to end (no `Hello` greeting) — the legacy path
/// every pre-v2 peer takes. [`run_client_negotiated`] upgrades to the
/// zero-copy v2 wire when the server supports it.
pub fn run_client(
    mut conn: Connection,
    client: &mut dyn Client,
    info: ClientInfo,
) -> Result<()> {
    conn.send_client_message(&ClientMessage::Register(info.clone()))?;
    serve(conn, client)
}

/// Like [`run_client`], but greets the server with `Hello` first and
/// serves at the negotiated wire version (see `transport/PROTOCOL.md`):
/// the server answers `HelloAck` with the highest mutually supported
/// version, then registration proceeds as usual.
pub fn run_client_negotiated(
    mut conn: Connection,
    client: &mut dyn Client,
    info: ClientInfo,
) -> Result<()> {
    conn.send_client_message(&ClientMessage::Hello {
        max_version: crate::proto::MAX_WIRE_VERSION,
    })?;
    let wire = match conn.recv_server_message()? {
        // clamp defensively: never speak above what this build knows
        ServerMessage::HelloAck { version } => crate::proto::negotiate_version(version),
        other => {
            return Err(Error::Protocol(format!(
                "expected HelloAck to the version greeting, got {other:?}"
            )))
        }
    };
    conn.send_client_message(&ClientMessage::Register(info.clone()))?;
    serve_wire(conn, client, wire)
}

/// Bounded reconnect policy for [`run_client_with_retry`]: exponential
/// backoff with multiplicative jitter, capped per sleep.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total connection attempts, first dial included (min 1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the second attempt; doubles each further attempt.
    pub backoff_base_s: f64,
    /// Hard cap on any single backoff sleep.
    pub backoff_cap_s: f64,
    /// Jitter stream seed (a deterministic backoff schedule for tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 5, backoff_base_s: 0.2, backoff_cap_s: 5.0, seed: 0 }
    }
}

/// Keep a client serving across transient transport faults: dial,
/// negotiate, register, serve; on a *non-clean* transport/I-O/timeout
/// error, sleep a jittered exponential backoff and re-dial from
/// scratch — registration included, since the server may have dropped
/// all session state. A clean server goodbye (`Reconnect`, or a
/// frame-boundary EOF) returns `Ok`; protocol/client faults and an
/// exhausted retry budget return the real error instead of swallowing
/// it (the silent-death regression this loop exists to prevent).
pub fn run_client_with_retry(
    mut dial: impl FnMut() -> Result<Connection>,
    client: &mut dyn Client,
    info: ClientInfo,
    policy: &RetryPolicy,
) -> Result<()> {
    let mut jitter = crate::util::rng::Rng::seed_from(policy.seed);
    let mut last_err = Error::Transport("retry budget exhausted".into());
    for attempt in 0..policy.max_attempts.max(1) {
        if attempt > 0 {
            let exp = policy.backoff_base_s * f64::powi(2.0, attempt as i32 - 1);
            // Multiplicative jitter in [0.5, 1.5): desynchronizes a
            // cohort that all lost the same server at the same moment.
            let sleep_s = (exp * (0.5 + jitter.f64())).min(policy.backoff_cap_s);
            if sleep_s > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(sleep_s));
            }
        }
        let served = dial().and_then(|conn| run_client_negotiated(conn, client, info.clone()));
        match served {
            Ok(()) => return Ok(()),
            Err(e @ (Error::Transport(_) | Error::Io(_) | Error::Timeout(_))) => last_err = e,
            // Protocol/codec/client faults are not transient: redialing
            // would just replay the same failure against the server.
            Err(e) => return Err(e),
        }
    }
    Err(last_err)
}

/// Serve an already-registered connection (the simulator registers the
/// proxy directly, so no `Register` message is sent here). Wire v1.
pub fn serve(conn: Connection, client: &mut dyn Client) -> Result<()> {
    serve_wire(conn, client, crate::proto::codec::VERSION)
}

/// [`serve`] at an explicit negotiated wire version: responses carrying
/// tensors (`FitRes`, `GetParametersRes`) are encoded v2 on v2
/// connections; incoming frames decode on either version transparently.
pub fn serve_wire(mut conn: Connection, client: &mut dyn Client, wire: u8) -> Result<()> {
    loop {
        let msg = match conn.recv_server_message() {
            Ok(m) => m,
            // Only a frame-boundary EOF is the server cleanly going
            // away. A truncated frame, a mid-exchange reset, or any
            // other transport fault used to land here too and silently
            // ended the loop with Ok — the client died without anyone
            // (caller, operator, retry logic) ever seeing an error.
            Err(e) if e.is_clean_close() => return Ok(()),
            Err(e) => return Err(e),
        };
        match msg {
            ServerMessage::GetParametersIns(ins) => {
                let res = client.get_parameters(ins).unwrap_or_else(|e| {
                    crate::proto::GetParametersRes {
                        status: Status {
                            code: StatusCode::FitError,
                            message: e.to_string(),
                        },
                        parameters: Default::default(),
                    }
                });
                conn.send_client_message_v(&ClientMessage::GetParametersRes(res), wire)?;
            }
            ServerMessage::FitIns(ins) => {
                let res = match client.fit(ins) {
                    Ok(res) => res,
                    Err(e) => crate::proto::FitRes {
                        status: Status {
                            code: StatusCode::FitError,
                            message: e.to_string(),
                        },
                        parameters: Default::default(),
                        num_examples: 0,
                        metrics: Default::default(),
                    },
                };
                conn.send_client_message_v(&ClientMessage::FitRes(res), wire)?;
            }
            ServerMessage::EvaluateIns(ins) => {
                let res = match client.evaluate(ins) {
                    Ok(res) => res,
                    Err(e) => crate::proto::EvaluateRes {
                        status: Status {
                            code: StatusCode::EvaluateError,
                            message: e.to_string(),
                        },
                        loss: f64::NAN,
                        num_examples: 0,
                        metrics: Default::default(),
                    },
                };
                conn.send_client_message(&ClientMessage::EvaluateRes(res))?;
            }
            ServerMessage::Reconnect { .. } => {
                let _ = conn.send_client_message(&ClientMessage::Disconnect {
                    reason: "server requested shutdown".into(),
                });
                return Ok(());
            }
            // negotiation is settled before serving; ignore stray acks
            ServerMessage::HelloAck { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::*;
    use crate::transport::{inproc, Connection};

    /// Minimal in-memory client used to exercise the loop without PJRT.
    struct EchoClient {
        params: Vec<f32>,
    }

    impl Client for EchoClient {
        fn get_parameters(&mut self, _: GetParametersIns) -> crate::Result<GetParametersRes> {
            Ok(GetParametersRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(self.params.clone()),
            })
        }
        fn fit(&mut self, ins: FitIns) -> crate::Result<FitRes> {
            // "training": add 1 to every parameter
            let mut p = ins.parameters.to_flat()?.to_vec();
            for v in &mut p {
                *v += 1.0;
            }
            self.params = p.clone();
            Ok(FitRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(p),
                num_examples: 10,
                metrics: Default::default(),
            })
        }
        fn evaluate(&mut self, _: EvaluateIns) -> crate::Result<EvaluateRes> {
            Err(crate::Error::Client("no test data".into()))
        }
    }

    #[test]
    fn loop_handles_all_message_kinds() {
        let (server_end, client_end) = inproc::pair();
        let mut server = Connection::InProc(server_end);

        let handle = std::thread::spawn(move || {
            let mut client = EchoClient { params: vec![0.0; 4] };
            run_client(
                Connection::InProc(client_end),
                &mut client,
                ClientInfo {
                    client_id: "c0".into(),
                    device: "pixel4".into(),
                    os: "Android 10".into(),
                    num_examples: 10,
                },
            )
        });

        // registration first
        let reg = server.recv_client_message().unwrap();
        assert!(matches!(reg, ClientMessage::Register(_)));

        // fit
        server
            .send_server_message(&ServerMessage::FitIns(FitIns {
                parameters: Parameters::from_flat(vec![1.0, 2.0]),
                config: Default::default(),
            }))
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::FitRes(res) => {
                assert_eq!(res.parameters.to_flat().unwrap(), &[2.0, 3.0]);
            }
            other => panic!("expected FitRes, got {other:?}"),
        }

        // evaluate: client errors internally but must answer with a status
        server
            .send_server_message(&ServerMessage::EvaluateIns(EvaluateIns {
                parameters: Parameters::from_flat(vec![0.0]),
                config: Default::default(),
            }))
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::EvaluateRes(res) => {
                assert_eq!(res.status.code, StatusCode::EvaluateError);
            }
            other => panic!("expected EvaluateRes, got {other:?}"),
        }

        // goodbye
        server
            .send_server_message(&ServerMessage::Reconnect { seconds: 0 })
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::Disconnect { .. } => {}
            other => panic!("expected Disconnect, got {other:?}"),
        }
        handle.join().unwrap().unwrap();
    }

    /// Regression for the silent-death bug: a *non-clean* transport
    /// fault mid-fit used to be swallowed as `Ok(())` by the serve
    /// loop. Now it surfaces as an error, the retry loop re-dials and
    /// re-registers, and the second attempt completes the exchange.
    #[test]
    fn retry_survives_mid_fit_connection_drop() {
        use crate::transport::frame::{read_frame, write_frame};
        use crate::transport::tcp::TcpConnection;
        use crate::util::bytes::FrameBuf;
        use std::io::Write;

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let server = std::thread::spawn(move || {
            let wire = crate::proto::codec::VERSION;
            // Attempt 1: negotiate + register normally, then die
            // mid-frame while "sending" a FitIns — the length prefix
            // promises 64 bytes, 3 arrive, the socket drops.
            {
                let (mut stream, _) = listener.accept().unwrap();
                let hello = read_frame(&mut stream).unwrap();
                assert!(matches!(
                    crate::proto::decode_client_frame(&FrameBuf::new(hello)).unwrap(),
                    ClientMessage::Hello { .. }
                ));
                write_frame(
                    &mut stream,
                    &crate::proto::encode_server_message_v(
                        &ServerMessage::HelloAck { version: wire },
                        wire,
                    ),
                )
                .unwrap();
                let reg = read_frame(&mut stream).unwrap();
                assert!(matches!(
                    crate::proto::decode_client_frame(&FrameBuf::new(reg)).unwrap(),
                    ClientMessage::Register(_)
                ));
                stream.write_all(&64u32.to_le_bytes()).unwrap();
                stream.write_all(&[1, 2, 3]).unwrap();
                stream.flush().unwrap();
            }
            // Attempt 2: the retry loop re-dials; serve the whole
            // session, re-registration first.
            let (stream, _) = listener.accept().unwrap();
            let mut conn = Connection::Tcp(TcpConnection::from_stream(stream).unwrap());
            assert!(matches!(
                conn.recv_client_message().unwrap(),
                ClientMessage::Hello { .. }
            ));
            conn.send_server_message(&ServerMessage::HelloAck { version: wire }).unwrap();
            assert!(matches!(
                conn.recv_client_message().unwrap(),
                ClientMessage::Register(_)
            ));
            conn.send_server_message(&ServerMessage::FitIns(FitIns {
                parameters: Parameters::from_flat(vec![1.0, 2.0]),
                config: Default::default(),
            }))
            .unwrap();
            let fit = match conn.recv_client_message().unwrap() {
                ClientMessage::FitRes(res) => res.parameters.to_flat().unwrap().to_vec(),
                other => panic!("expected FitRes, got {other:?}"),
            };
            conn.send_server_message(&ServerMessage::Reconnect { seconds: 0 }).unwrap();
            let _ = conn.recv_client_message(); // Disconnect (best effort)
            fit
        });

        let mut client = EchoClient { params: vec![0.0; 2] };
        let policy = RetryPolicy {
            max_attempts: 3,
            backoff_base_s: 0.01,
            backoff_cap_s: 0.05,
            seed: 42,
        };
        let mut dials = 0u32;
        run_client_with_retry(
            || {
                dials += 1;
                crate::transport::tcp::TcpConnection::connect(addr).map(Connection::Tcp)
            },
            &mut client,
            ClientInfo {
                client_id: "c0".into(),
                device: "pixel4".into(),
                os: "Android 10".into(),
                num_examples: 10,
            },
            &policy,
        )
        .unwrap();
        assert_eq!(dials, 2, "first dial died mid-fit, second completed");
        assert_eq!(server.join().unwrap(), vec![2.0, 3.0]);
    }

    /// An exhausted retry budget surfaces the last real error instead
    /// of pretending the client exited cleanly.
    #[test]
    fn retry_budget_exhaustion_returns_the_error() {
        let mut client = EchoClient { params: vec![0.0; 2] };
        let policy = RetryPolicy {
            max_attempts: 3,
            backoff_base_s: 0.001,
            backoff_cap_s: 0.002,
            seed: 7,
        };
        let mut dials = 0u32;
        let err = run_client_with_retry(
            || {
                dials += 1;
                Err(crate::Error::Transport("connect: refused".into()))
            },
            &mut client,
            ClientInfo {
                client_id: "c0".into(),
                device: "pixel4".into(),
                os: "Android 10".into(),
                num_examples: 10,
            },
            &policy,
        )
        .unwrap_err();
        assert_eq!(dials, 3);
        assert!(err.to_string().contains("connect: refused"), "{err}");
    }

    /// A clean frame-boundary EOF (server hangs up between messages)
    /// still exits `Ok` without consuming any retry attempts.
    #[test]
    fn clean_close_is_not_retried() {
        use crate::transport::inproc;
        let (server_end, client_end) = inproc::pair();
        let mut server = Connection::InProc(server_end);
        let mut ends = vec![client_end];
        let handle = std::thread::spawn(move || {
            let mut client = EchoClient { params: vec![0.0; 2] };
            let mut dials = 0u32;
            let out = run_client_with_retry(
                || {
                    dials += 1;
                    Ok(Connection::InProc(ends.pop().expect("only one dial")))
                },
                &mut client,
                ClientInfo {
                    client_id: "c0".into(),
                    device: "pixel4".into(),
                    os: "Android 10".into(),
                    num_examples: 10,
                },
                &RetryPolicy::default(),
            );
            (out, dials)
        });
        match server.recv_client_message().unwrap() {
            ClientMessage::Hello { .. } => {}
            other => panic!("expected Hello, got {other:?}"),
        }
        server
            .send_server_message(&ServerMessage::HelloAck {
                version: crate::proto::codec::VERSION,
            })
            .unwrap();
        assert!(matches!(server.recv_client_message().unwrap(), ClientMessage::Register(_)));
        drop(server); // frame-boundary EOF: clean
        let (out, dials) = handle.join().unwrap();
        out.unwrap();
        assert_eq!(dials, 1);
    }

    #[test]
    fn negotiated_client_upgrades_to_v2() {
        let (server_end, client_end) = inproc::pair();
        let mut server = Connection::InProc(server_end);

        let handle = std::thread::spawn(move || {
            let mut client = EchoClient { params: vec![0.0; 2] };
            run_client_negotiated(
                Connection::InProc(client_end),
                &mut client,
                ClientInfo {
                    client_id: "c1".into(),
                    device: "pixel4".into(),
                    os: "Android 10".into(),
                    num_examples: 10,
                },
            )
        });

        // hello greeting precedes registration
        match server.recv_client_message().unwrap() {
            ClientMessage::Hello { max_version } => {
                assert_eq!(max_version, crate::proto::MAX_WIRE_VERSION)
            }
            other => panic!("expected Hello, got {other:?}"),
        }
        server
            .send_server_message(&ServerMessage::HelloAck {
                version: crate::proto::codec::VERSION_V2,
            })
            .unwrap();
        let reg = server.recv_client_message().unwrap();
        assert!(matches!(reg, ClientMessage::Register(_)));

        // a v2 FitIns decodes on the client, and the FitRes comes back
        // as a v2 frame (version byte pinned on the raw frame)
        server
            .send_server_message_v(
                &ServerMessage::FitIns(FitIns {
                    parameters: Parameters::from_flat(vec![1.0, 2.0]),
                    config: Default::default(),
                }),
                crate::proto::codec::VERSION_V2,
            )
            .unwrap();
        let frame = server.recv_frame().unwrap();
        assert_eq!(frame.as_slice()[2], crate::proto::codec::VERSION_V2);
        match crate::proto::decode_client_frame(&frame).unwrap() {
            ClientMessage::FitRes(res) => {
                assert_eq!(res.parameters.to_flat().unwrap(), &[2.0, 3.0]);
            }
            other => panic!("expected FitRes, got {other:?}"),
        }

        server
            .send_server_message(&ServerMessage::Reconnect { seconds: 0 })
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::Disconnect { .. } => {}
            other => panic!("expected Disconnect, got {other:?}"),
        }
        handle.join().unwrap().unwrap();
    }
}
