//! The client event loop: register, then serve fit/evaluate requests until
//! the server says goodbye. This is the Rust analogue of the Android
//! client's background `StreamObserver` thread (paper Figure 2): messages
//! arrive, the appropriate on-device method runs, the result streams back.

use crate::client::Client;
use crate::error::{Error, Result};
use crate::proto::{ClientInfo, ClientMessage, ServerMessage, Status, StatusCode};
use crate::transport::Connection;

/// Run a client against an established connection. Returns when the server
/// sends `Reconnect` (clean shutdown) or the connection drops.
///
/// Speaks wire v1 end to end (no `Hello` greeting) — the legacy path
/// every pre-v2 peer takes. [`run_client_negotiated`] upgrades to the
/// zero-copy v2 wire when the server supports it.
pub fn run_client(
    mut conn: Connection,
    client: &mut dyn Client,
    info: ClientInfo,
) -> Result<()> {
    conn.send_client_message(&ClientMessage::Register(info.clone()))?;
    serve(conn, client)
}

/// Like [`run_client`], but greets the server with `Hello` first and
/// serves at the negotiated wire version (see `transport/PROTOCOL.md`):
/// the server answers `HelloAck` with the highest mutually supported
/// version, then registration proceeds as usual.
pub fn run_client_negotiated(
    mut conn: Connection,
    client: &mut dyn Client,
    info: ClientInfo,
) -> Result<()> {
    conn.send_client_message(&ClientMessage::Hello {
        max_version: crate::proto::MAX_WIRE_VERSION,
    })?;
    let wire = match conn.recv_server_message()? {
        // clamp defensively: never speak above what this build knows
        ServerMessage::HelloAck { version } => crate::proto::negotiate_version(version),
        other => {
            return Err(Error::Protocol(format!(
                "expected HelloAck to the version greeting, got {other:?}"
            )))
        }
    };
    conn.send_client_message(&ClientMessage::Register(info.clone()))?;
    serve_wire(conn, client, wire)
}

/// Serve an already-registered connection (the simulator registers the
/// proxy directly, so no `Register` message is sent here). Wire v1.
pub fn serve(conn: Connection, client: &mut dyn Client) -> Result<()> {
    serve_wire(conn, client, crate::proto::codec::VERSION)
}

/// [`serve`] at an explicit negotiated wire version: responses carrying
/// tensors (`FitRes`, `GetParametersRes`) are encoded v2 on v2
/// connections; incoming frames decode on either version transparently.
pub fn serve_wire(mut conn: Connection, client: &mut dyn Client, wire: u8) -> Result<()> {
    loop {
        let msg = match conn.recv_server_message() {
            Ok(m) => m,
            Err(Error::Transport(_)) => return Ok(()), // server went away
            Err(e) => return Err(e),
        };
        match msg {
            ServerMessage::GetParametersIns(ins) => {
                let res = client.get_parameters(ins).unwrap_or_else(|e| {
                    crate::proto::GetParametersRes {
                        status: Status {
                            code: StatusCode::FitError,
                            message: e.to_string(),
                        },
                        parameters: Default::default(),
                    }
                });
                conn.send_client_message_v(&ClientMessage::GetParametersRes(res), wire)?;
            }
            ServerMessage::FitIns(ins) => {
                let res = match client.fit(ins) {
                    Ok(res) => res,
                    Err(e) => crate::proto::FitRes {
                        status: Status {
                            code: StatusCode::FitError,
                            message: e.to_string(),
                        },
                        parameters: Default::default(),
                        num_examples: 0,
                        metrics: Default::default(),
                    },
                };
                conn.send_client_message_v(&ClientMessage::FitRes(res), wire)?;
            }
            ServerMessage::EvaluateIns(ins) => {
                let res = match client.evaluate(ins) {
                    Ok(res) => res,
                    Err(e) => crate::proto::EvaluateRes {
                        status: Status {
                            code: StatusCode::EvaluateError,
                            message: e.to_string(),
                        },
                        loss: f64::NAN,
                        num_examples: 0,
                        metrics: Default::default(),
                    },
                };
                conn.send_client_message(&ClientMessage::EvaluateRes(res))?;
            }
            ServerMessage::Reconnect { .. } => {
                let _ = conn.send_client_message(&ClientMessage::Disconnect {
                    reason: "server requested shutdown".into(),
                });
                return Ok(());
            }
            // negotiation is settled before serving; ignore stray acks
            ServerMessage::HelloAck { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::*;
    use crate::transport::{inproc, Connection};

    /// Minimal in-memory client used to exercise the loop without PJRT.
    struct EchoClient {
        params: Vec<f32>,
    }

    impl Client for EchoClient {
        fn get_parameters(&mut self, _: GetParametersIns) -> crate::Result<GetParametersRes> {
            Ok(GetParametersRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(self.params.clone()),
            })
        }
        fn fit(&mut self, ins: FitIns) -> crate::Result<FitRes> {
            // "training": add 1 to every parameter
            let mut p = ins.parameters.to_flat()?.to_vec();
            for v in &mut p {
                *v += 1.0;
            }
            self.params = p.clone();
            Ok(FitRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(p),
                num_examples: 10,
                metrics: Default::default(),
            })
        }
        fn evaluate(&mut self, _: EvaluateIns) -> crate::Result<EvaluateRes> {
            Err(crate::Error::Client("no test data".into()))
        }
    }

    #[test]
    fn loop_handles_all_message_kinds() {
        let (server_end, client_end) = inproc::pair();
        let mut server = Connection::InProc(server_end);

        let handle = std::thread::spawn(move || {
            let mut client = EchoClient { params: vec![0.0; 4] };
            run_client(
                Connection::InProc(client_end),
                &mut client,
                ClientInfo {
                    client_id: "c0".into(),
                    device: "pixel4".into(),
                    os: "Android 10".into(),
                    num_examples: 10,
                },
            )
        });

        // registration first
        let reg = server.recv_client_message().unwrap();
        assert!(matches!(reg, ClientMessage::Register(_)));

        // fit
        server
            .send_server_message(&ServerMessage::FitIns(FitIns {
                parameters: Parameters::from_flat(vec![1.0, 2.0]),
                config: Default::default(),
            }))
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::FitRes(res) => {
                assert_eq!(res.parameters.to_flat().unwrap(), &[2.0, 3.0]);
            }
            other => panic!("expected FitRes, got {other:?}"),
        }

        // evaluate: client errors internally but must answer with a status
        server
            .send_server_message(&ServerMessage::EvaluateIns(EvaluateIns {
                parameters: Parameters::from_flat(vec![0.0]),
                config: Default::default(),
            }))
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::EvaluateRes(res) => {
                assert_eq!(res.status.code, StatusCode::EvaluateError);
            }
            other => panic!("expected EvaluateRes, got {other:?}"),
        }

        // goodbye
        server
            .send_server_message(&ServerMessage::Reconnect { seconds: 0 })
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::Disconnect { .. } => {}
            other => panic!("expected Disconnect, got {other:?}"),
        }
        handle.join().unwrap().unwrap();
    }

    #[test]
    fn negotiated_client_upgrades_to_v2() {
        let (server_end, client_end) = inproc::pair();
        let mut server = Connection::InProc(server_end);

        let handle = std::thread::spawn(move || {
            let mut client = EchoClient { params: vec![0.0; 2] };
            run_client_negotiated(
                Connection::InProc(client_end),
                &mut client,
                ClientInfo {
                    client_id: "c1".into(),
                    device: "pixel4".into(),
                    os: "Android 10".into(),
                    num_examples: 10,
                },
            )
        });

        // hello greeting precedes registration
        match server.recv_client_message().unwrap() {
            ClientMessage::Hello { max_version } => {
                assert_eq!(max_version, crate::proto::MAX_WIRE_VERSION)
            }
            other => panic!("expected Hello, got {other:?}"),
        }
        server
            .send_server_message(&ServerMessage::HelloAck {
                version: crate::proto::codec::VERSION_V2,
            })
            .unwrap();
        let reg = server.recv_client_message().unwrap();
        assert!(matches!(reg, ClientMessage::Register(_)));

        // a v2 FitIns decodes on the client, and the FitRes comes back
        // as a v2 frame (version byte pinned on the raw frame)
        server
            .send_server_message_v(
                &ServerMessage::FitIns(FitIns {
                    parameters: Parameters::from_flat(vec![1.0, 2.0]),
                    config: Default::default(),
                }),
                crate::proto::codec::VERSION_V2,
            )
            .unwrap();
        let frame = server.recv_frame().unwrap();
        assert_eq!(frame.as_slice()[2], crate::proto::codec::VERSION_V2);
        match crate::proto::decode_client_frame(&frame).unwrap() {
            ClientMessage::FitRes(res) => {
                assert_eq!(res.parameters.to_flat().unwrap(), &[2.0, 3.0]);
            }
            other => panic!("expected FitRes, got {other:?}"),
        }

        server
            .send_server_message(&ServerMessage::Reconnect { seconds: 0 })
            .unwrap();
        match server.recv_client_message().unwrap() {
            ClientMessage::Disconnect { .. } => {}
            other => panic!("expected Disconnect, got {other:?}"),
        }
        handle.join().unwrap().unwrap();
    }
}
