//! Flower clients: the on-device side of the protocol.
//!
//! [`Client`] is the user-facing trait (the paper's `get_weights` / `fit` /
//! `evaluate` triple, §4.1). [`trainer::DeviceTrainer`] is the production
//! implementation that trains through the PJRT runtime under a device cost
//! profile; [`app::run_client`] is the event loop that speaks the Flower
//! Protocol over any [`crate::transport::Connection`] (the Rust analogue
//! of the Android `FLOWER CLIENT` background thread of Figure 2).

pub mod app;
pub mod masking;
pub mod trainer;

pub use app::{run_client_with_retry, RetryPolicy};
pub use masking::MaskedClient;
pub use trainer::{BaseModel, DeviceTrainer};

use crate::error::Result;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, GetParametersIns, GetParametersRes};

/// The three core methods required for federated training with Flower
/// (paper §4.1). Implementations must be `Send` so a deployment can host
/// the client behind its connection thread.
pub trait Client: Send {
    /// Current local model parameters (server-side aggregation requests).
    fn get_parameters(&mut self, ins: GetParametersIns) -> Result<GetParametersRes>;
    /// Update parameters by local training.
    fn fit(&mut self, ins: FitIns) -> Result<FitRes>;
    /// Compute test loss/accuracy on the local dataset.
    fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes>;
}

/// Delegation so wrappers (masking, failure injection) can compose over
/// boxed clients without generic explosion.
impl Client for Box<dyn Client> {
    fn get_parameters(&mut self, ins: GetParametersIns) -> Result<GetParametersRes> {
        (**self).get_parameters(ins)
    }
    fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
        (**self).fit(ins)
    }
    fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes> {
        (**self).evaluate(ins)
    }
}

/// Well-known config keys the server sends (kept in one place so the
/// strategies and trainer cannot drift apart).
pub mod keys {
    /// i64: number of local epochs E.
    pub const EPOCHS: &str = "epochs";
    /// f64: SGD learning rate.
    pub const LR: &str = "lr";
    /// f64: τ cutoff in seconds of *modeled device compute time*; 0 = none.
    pub const CUTOFF_S: &str = "cutoff_s";
    /// f64: FedProx μ; 0 = plain SGD.
    pub const PROX_MU: &str = "prox_mu";
    /// i64: current server round (informational, shows up in client logs).
    pub const ROUND: &str = "round";
    /// str: wire compression for the client's reply ("f16"); absent = f32.
    pub const QUANTIZE: &str = "quantize";
    /// str: comma-separated mask-group ids for secure aggregation
    /// (incl. self), entries percent-escaped per
    /// [`crate::client::masking::encode_peer_list`] so ids may contain
    /// commas.
    pub const SECAGG_PEERS: &str = "secagg_peers";
    /// i64: shared base seed for pairwise SecAgg masks.
    pub const SECAGG_SEED: &str = "secagg_seed";

    // Metrics reported back by the trainer:
    /// i64: train steps actually executed.
    pub const STEPS: &str = "steps";
    /// f64: modeled on-device compute time (s).
    pub const COMPUTE_TIME_S: &str = "compute_time_s";
    /// f64: modeled on-device energy (J) for the compute phase.
    pub const ENERGY_J: &str = "energy_j";
    /// f64: mean training loss over executed steps.
    pub const TRAIN_LOSS: &str = "train_loss";
    /// bool: whether the τ cutoff truncated local training.
    pub const TRUNCATED: &str = "truncated";
    /// f64: fraction of correct predictions (evaluate only).
    pub const ACCURACY: &str = "accuracy";
}
