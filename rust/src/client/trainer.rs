//! `DeviceTrainer`: the production on-device client.
//!
//! Local training runs *for real* through the PJRT runtime (the AOT train
//! artifacts); time and energy are *modeled* from the device profile via
//! the cost model — exactly the substitution DESIGN.md §2 documents for
//! the paper's physical testbed.
//!
//! Supports the full strategy surface:
//! * plain FedAvg local epochs (`epochs`, `lr`),
//! * the paper's τ cutoff (`cutoff_s`): stop mid-epoch once the modeled
//!   device compute time exceeds τ and return the partial result,
//! * FedProx (`prox_mu` > 0): proximal local steps via the `*_train_prox`
//!   artifact.
//!
//! For the Android transfer-learning workload (Figure 2) the trainer owns
//! a frozen [`BaseModel`]; raw local data is pushed through the
//! `base_features` artifact once at setup, then only the head trains.

use crate::client::keys;
use crate::data::Dataset;
use crate::device::DeviceProfile;
use crate::error::{Error, Result};
use crate::proto::{
    ConfigMap, EvaluateIns, EvaluateRes, FitIns, FitRes, GetParametersIns, GetParametersRes,
    Parameters, Scalar, Status,
};
use crate::proto::scalar::ConfigExt;
use crate::runtime::Runtime;
use crate::sim::cost::CostModel;
use crate::util::rng::Rng;

use super::Client;

/// The frozen "MobileNetV2" base model of the Android pipeline: a fixed
/// random projection shared by the whole federation (the paper ships the
/// same pre-trained TFLite base to every phone).
#[derive(Debug, Clone)]
pub struct BaseModel {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub in_dim: usize,
    pub out_dim: usize,
}

impl BaseModel {
    /// Deterministically generate the shared base from a seed.
    pub fn generate(seed: u64, in_dim: usize, out_dim: usize) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0xBA5E_0001);
        let scale = (2.0 / in_dim as f64).sqrt() as f32;
        let w = (0..in_dim * out_dim)
            .map(|_| scale * rng.normal_f32())
            .collect();
        let b = vec![0f32; out_dim];
        BaseModel { w, b, in_dim, out_dim }
    }
}

/// Builder-ish bundle of everything a device needs to participate.
pub struct DeviceTrainer {
    runtime: Runtime,
    model: String,
    profile: &'static DeviceProfile,
    cost: CostModel,
    train: Dataset,
    test: Dataset,
    base: Option<BaseModel>,
    rng: Rng,
    /// last parameters seen (for `get_parameters`)
    current: Vec<f32>,
    default_lr: f64,
}

impl DeviceTrainer {
    /// Create a trainer. For the `head` model, `train`/`test` must be raw
    /// base-model inputs and `base` must be provided — features are
    /// extracted through the AOT base artifact here (once, like the
    /// paper's on-device TFLite feature extractor).
    pub fn new(
        runtime: Runtime,
        model: &str,
        profile: &'static DeviceProfile,
        cost: CostModel,
        mut train: Dataset,
        mut test: Dataset,
        base: Option<BaseModel>,
        seed: u64,
    ) -> Result<Self> {
        let entry = runtime.manifest().model(model)?.clone();
        let current = runtime.initial_parameters(model)?;
        if let Some(base) = &base {
            train = extract_features(&runtime, model, base, &train, true)?;
            test = extract_features(&runtime, model, base, &test, false)?;
        }
        let expect = entry.example_elements();
        for (what, d) in [("train", &train), ("test", &test)] {
            if d.example_elements != expect {
                return Err(Error::Client(format!(
                    "{what} data has {} elems/example, model {model} wants {expect}",
                    d.example_elements
                )));
            }
        }
        if train.num_batches(entry.train_batch) == 0 {
            return Err(Error::Client(format!(
                "train split of {} examples is smaller than one batch ({})",
                train.len(),
                entry.train_batch
            )));
        }
        Ok(DeviceTrainer {
            runtime,
            model: model.to_string(),
            profile,
            cost,
            train,
            test,
            base,
            rng: Rng::seed_from(seed ^ TRAINER_SALT),
            current,
            default_lr: 0.05,
        })
    }

    pub fn profile(&self) -> &'static DeviceProfile {
        self.profile
    }

    pub fn num_train_examples(&self) -> usize {
        self.train.len()
    }

    pub fn base(&self) -> Option<&BaseModel> {
        self.base.as_ref()
    }
}

/// Salt decorrelating the trainer's shuffle stream from the data seed.
const TRAINER_SALT: u64 = 0x7A11_ED5A;

fn extract_features(
    runtime: &Runtime,
    model: &str,
    base: &BaseModel,
    data: &Dataset,
    train_path: bool,
) -> Result<Dataset> {
    let entry = runtime.manifest().model(model)?;
    let batch = if train_path { entry.train_batch } else { entry.eval_batch };
    let in_dim = base.in_dim;
    if data.example_elements != in_dim {
        return Err(Error::Client(format!(
            "raw data has {} elems/example, base model wants {in_dim}",
            data.example_elements
        )));
    }
    let usable = data.num_batches(batch) * batch;
    let mut feats = Vec::with_capacity(usable * base.out_dim);
    for i in 0..data.num_batches(batch) {
        let (x, _) = data.batch(i, batch);
        let f = runtime.base_features(model, x, &base.w, &base.b, train_path)?;
        feats.extend_from_slice(&f);
    }
    Dataset::new(feats, data.y[..usable].to_vec(), base.out_dim)
}

impl Client for DeviceTrainer {
    fn get_parameters(&mut self, _ins: GetParametersIns) -> Result<GetParametersRes> {
        Ok(GetParametersRes {
            status: Status::ok(),
            parameters: Parameters::from_flat(self.current.clone()),
        })
    }

    fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
        let entry = self.runtime.manifest().model(&self.model)?.clone();
        let global = ins.parameters.to_flat_vec()?;
        if global.len() != entry.param_count {
            return Err(Error::Client(format!(
                "server sent {} params, model wants {}",
                global.len(),
                entry.param_count
            )));
        }
        let epochs = ins.config.get_i64_or(keys::EPOCHS, 1).max(0) as u64;
        let lr = ins.config.get_f64_or(keys::LR, self.default_lr) as f32;
        let cutoff_s = ins.config.get_f64_or(keys::CUTOFF_S, 0.0);
        let mu = ins.config.get_f64_or(keys::PROX_MU, 0.0) as f32;

        let b = entry.train_batch;
        let steps_per_epoch = self.train.num_batches(b) as u64;
        let total_steps = epochs * steps_per_epoch;
        let max_steps = if cutoff_s > 0.0 {
            total_steps.min(self.cost.max_steps_within(self.profile, cutoff_s))
        } else {
            total_steps
        };

        let mut params = global.clone();
        let mut steps_done = 0u64;
        let mut loss_sum = 0f64;
        'epochs: for _ in 0..epochs {
            self.train.shuffle(&mut self.rng);
            for i in 0..self.train.num_batches(b) {
                if steps_done >= max_steps {
                    break 'epochs;
                }
                let (x, y) = self.train.batch(i, b);
                let (new_params, loss) = if mu > 0.0 {
                    self.runtime
                        .train_step_prox(&self.model, &params, &global, x, y, lr, mu)?
                } else {
                    self.runtime.train_step(&self.model, &params, x, y, lr)?
                };
                params = new_params;
                loss_sum += loss as f64;
                steps_done += 1;
            }
        }
        let compute = self.cost.compute(self.profile, steps_done);
        let truncated = steps_done < total_steps;
        self.current = params.clone();

        let reply_params = if matches!(ins.config.get_str(keys::QUANTIZE), Ok("f16")) {
            Parameters::from_flat(params).quantize_f16()?
        } else {
            Parameters::from_flat(params)
        };
        let mut metrics = ConfigMap::new();
        metrics.insert(keys::STEPS.into(), Scalar::I64(steps_done as i64));
        metrics.insert(keys::COMPUTE_TIME_S.into(), Scalar::F64(compute.time_s));
        metrics.insert(keys::ENERGY_J.into(), Scalar::F64(compute.energy_j));
        metrics.insert(
            keys::TRAIN_LOSS.into(),
            Scalar::F64(if steps_done > 0 { loss_sum / steps_done as f64 } else { f64::NAN }),
        );
        metrics.insert(keys::TRUNCATED.into(), Scalar::Bool(truncated));
        Ok(FitRes {
            status: Status::ok(),
            parameters: reply_params,
            num_examples: steps_done * b as u64,
            metrics,
        })
    }

    fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes> {
        let entry = self.runtime.manifest().model(&self.model)?.clone();
        let params = ins.parameters.to_flat_vec()?;
        let params = params.as_slice();
        let b = entry.eval_batch;
        let batches = self.test.num_batches(b);
        if batches == 0 {
            return Err(Error::Client(format!(
                "test split of {} examples is smaller than one eval batch ({b})",
                self.test.len()
            )));
        }
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        for i in 0..batches {
            let (x, y) = self.test.batch(i, b);
            let (loss, c) = self.runtime.eval_step(&self.model, params, x, y)?;
            loss_sum += loss as f64;
            correct += c as f64;
        }
        let n = (batches * b) as u64;
        let accuracy = correct / n as f64;
        let mut metrics = ConfigMap::new();
        metrics.insert(keys::ACCURACY.into(), Scalar::F64(accuracy));
        Ok(EvaluateRes {
            status: Status::ok(),
            loss: loss_sum / batches as f64,
            num_examples: n,
            metrics,
        })
    }
}
