//! Secure aggregation masking (client side).
//!
//! FL's privacy promise (the paper's opening motivation) is stronger when
//! the server never sees an individual update. Classic additive masking
//! (Bonawitz et al. 2017, the protocol behind Flower's SecAgg): every
//! pair of clients (a, b) derives a shared mask vector m_ab from a common
//! seed; a adds it, b subtracts it, so Σ masked = Σ plain while each
//! individual update is statistically noise to the server.
//!
//! This implementation is the honest "SecAgg0" core: pairwise masks from
//! a per-round shared seed, with **server-side residual unmasking** for
//! dropouts (the server knows the base seed, so it can subtract the
//! mask terms of any pair whose second half never reported — the
//! systems-cost stand-in for the full protocol's secret-shared
//! recovery). Because the server holds the base seed, this core models
//! the *system cost* of SecAgg (extra bytes, strict aggregation rules),
//! not its cryptographic guarantee; see `strategy/README.md`.
//!
//! ## Exact cancellation
//!
//! Masks and masked updates live on the fixed-point grid
//! `k · 2^-10` ([`MASK_GRID`]): [`mask_update`] first snaps the update
//! onto the grid (clamped to ±[`MASK_CLAMP`]) and every mask sample is a
//! grid multiple in `[-8, 8)`. Sums of grid multiples are **exact** in
//! f32 while partial sums stay below `2^24 · 2^-10 = 16384` — with
//! clamp 64 and masks < 8 that holds for any summation order over
//! cohorts of ≤ 64 clients (`64·64 + 8·64²/4·… < 2^14`), so
//! `Σ masked == Σ quantized-plain` **bit-for-bit over any cohort
//! permutation**, and subtracting a mask term recovers the exact
//! pre-mask bits. Property-locked in `rust/tests/strategy_props.rs`.

use crate::client::keys;
use crate::error::{Error, Result};
use crate::proto::scalar::ConfigExt;
use crate::proto::{
    EvaluateIns, EvaluateRes, FitIns, FitRes, GetParametersIns, GetParametersRes, Parameters,
};
use crate::util::rng::Rng;

use super::Client;

/// Encode a peer roster into the single comma-separated config value it
/// rides in ([`crate::client::keys::SECAGG_PEERS`]). Client ids are
/// externally supplied and may themselves contain commas, so each entry
/// is minimally percent-escaped (`%` → `%25`, `,` → `%2C`); the mask
/// derivation always hashes the *decoded* id, so both ends agree for
/// any id. Inverse: [`decode_peer_list`].
pub fn encode_peer_list<S: AsRef<str>>(ids: &[S]) -> String {
    ids.iter()
        .map(|id| id.as_ref().replace('%', "%25").replace(',', "%2C"))
        .collect::<Vec<String>>()
        .join(",")
}

/// Decode the roster encoded by [`encode_peer_list`].
pub fn decode_peer_list(csv: &str) -> Vec<String> {
    csv.split(',')
        .map(|s| s.replace("%2C", ",").replace("%25", "%"))
        .collect()
}

/// Stable 64-bit FNV-1a over a client id string.
pub fn id_hash(id: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in id.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The pairwise mask stream seed for (a, b) in a given round. Symmetric
/// in (a, b) — both ends derive the same stream. Public: the server's
/// residual unmasking (`strategy::secagg`) must derive the *identical*
/// stream for arbitrary string ids; it goes through this function, never
/// a parallel formula.
pub fn pair_seed(base: u64, round: u64, a: &str, b: &str) -> u64 {
    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
    base ^ round.wrapping_mul(0x9E3779B97F4A7C15) ^ id_hash(lo).rotate_left(17)
        ^ id_hash(hi).rotate_left(43)
}

/// Fixed-point grid step for masks and masked updates: 2^-10.
pub const MASK_GRID: f32 = 1.0 / 1024.0;

/// Updates entering the masked path are clamped to ±this bound (see the
/// module doc's exactness argument).
pub const MASK_CLAMP: f32 = 64.0;

/// Snap a value onto the mask grid: clamp to ±[`MASK_CLAMP`], round to
/// the nearest multiple of [`MASK_GRID`]. Non-finite values collapse to
/// 0 (a NaN would poison the whole aggregate).
pub fn quantize_to_grid(x: f32) -> f32 {
    if !x.is_finite() {
        return 0.0;
    }
    (x.clamp(-MASK_CLAMP, MASK_CLAMP) * 1024.0).round() / 1024.0
}

/// One mask sample: a grid multiple uniform in `[-8, 8)`.
fn grid_mask(rng: &mut Rng) -> f32 {
    (rng.below(16384) as f32 - 8192.0) / 1024.0
}

/// The signed pairwise mask stream `my_id` applies against `peer`
/// (sign convention: the lexicographically smaller id adds). `apply`
/// receives each element's mask term; both [`mask_update`] and the
/// server's subtraction walk this exact code path.
pub fn for_each_mask_term(
    my_id: &str,
    peer: &str,
    round: u64,
    base_seed: u64,
    len: usize,
    mut apply: impl FnMut(usize, f32),
) {
    let mut rng = Rng::seed_from(pair_seed(base_seed, round, my_id, peer));
    let sign = if my_id < peer { 1.0f32 } else { -1.0f32 };
    for i in 0..len {
        apply(i, sign * grid_mask(&mut rng));
    }
}

/// Apply pairwise masks to a flat update. `peers` must include every
/// cohort member of this round, *including* `my_id`. The update is
/// first snapped onto the mask grid ([`quantize_to_grid`] — a ≤ 2^-11
/// perturbation), which is what makes cancellation exact.
pub fn mask_update(
    params: &mut [f32],
    my_id: &str,
    peers: &[&str],
    round: u64,
    base_seed: u64,
) -> Result<()> {
    if !peers.contains(&my_id) {
        return Err(Error::Client(format!(
            "secagg peer list does not contain self ({my_id})"
        )));
    }
    for p in params.iter_mut() {
        *p = quantize_to_grid(*p);
    }
    for peer in peers {
        if *peer == my_id {
            continue;
        }
        for_each_mask_term(my_id, peer, round, base_seed, params.len(), |i, m| {
            params[i] += m;
        });
    }
    Ok(())
}

/// Server-side inverse of one client's masking: subtract every mask
/// term `my_id` applied against `peers` (self excluded). Exact — the
/// grid sums round-trip bit-for-bit, so unmasking a masked update
/// recovers the quantized plain update's exact bits.
pub fn unmask_update(
    params: &mut [f32],
    my_id: &str,
    peers: &[&str],
    round: u64,
    base_seed: u64,
) {
    for peer in peers {
        if *peer == my_id {
            continue;
        }
        for_each_mask_term(my_id, peer, round, base_seed, params.len(), |i, m| {
            params[i] -= m;
        });
    }
}

/// Client wrapper that masks outgoing fit updates when the server's
/// config carries the SecAgg keys (set by `strategy::SecAgg`).
pub struct MaskedClient<C: Client> {
    inner: C,
    client_id: String,
}

impl<C: Client> MaskedClient<C> {
    pub fn new(inner: C, client_id: &str) -> Self {
        MaskedClient { inner, client_id: client_id.to_string() }
    }
}

impl<C: Client> Client for MaskedClient<C> {
    fn get_parameters(&mut self, ins: GetParametersIns) -> Result<GetParametersRes> {
        self.inner.get_parameters(ins)
    }

    fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
        let peers_csv = ins.config.get_str(keys::SECAGG_PEERS).map(str::to_string);
        let seed = ins.config.get_i64(keys::SECAGG_SEED);
        let round = ins.config.get_i64_or(keys::ROUND, 0) as u64;
        let mut res = self.inner.fit(ins)?;
        if let (Ok(peers_csv), Ok(seed)) = (peers_csv, seed) {
            let decoded = decode_peer_list(&peers_csv);
            let peers: Vec<&str> = decoded.iter().map(String::as_str).collect();
            let mut flat = res.parameters.to_flat_vec()?;
            mask_update(&mut flat, &self.client_id, &peers, round, seed as u64)?;
            res.parameters = Parameters::from_flat(flat);
        }
        Ok(res)
    }

    fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes> {
        self.inner.evaluate(ins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_cancel_over_cohort_bit_exactly() {
        let peers = ["a", "b", "c", "d"];
        let p = 512;
        let plain: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..p).map(|j| (i * p + j) as f32 * 1e-3).collect())
            .collect();
        let quantized: Vec<Vec<f32>> = plain
            .iter()
            .map(|v| v.iter().map(|&x| quantize_to_grid(x)).collect())
            .collect();
        let mut masked = plain.clone();
        for (i, id) in peers.iter().enumerate() {
            mask_update(&mut masked[i], id, &peers, 3, 42).unwrap();
        }
        // each individual update is far from the original...
        for i in 0..4 {
            let dist: f32 = masked[i]
                .iter()
                .zip(&plain[i])
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / p as f32;
            assert!(dist > 1.0, "client {i} barely masked: {dist}");
        }
        // ...but the sums equal the quantized-plain sums bit for bit
        for j in 0..p {
            let sum_plain: f32 = quantized.iter().map(|v| v[j]).sum();
            let sum_masked: f32 = masked.iter().map(|v| v[j]).sum();
            assert_eq!(
                sum_plain.to_bits(),
                sum_masked.to_bits(),
                "j={j}: {sum_plain} vs {sum_masked}"
            );
        }
    }

    #[test]
    fn unmask_recovers_exact_quantized_update() {
        let peers = ["alpha", "beta-2", "γ node"];
        let plain: Vec<f32> = (0..64).map(|j| (j as f32 - 32.0) * 0.013).collect();
        let want: Vec<f32> = plain.iter().map(|&x| quantize_to_grid(x)).collect();
        let mut v = plain.clone();
        mask_update(&mut v, "beta-2", &peers, 9, 1234).unwrap();
        unmask_update(&mut v, "beta-2", &peers, 9, 1234);
        let got: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp);
    }

    #[test]
    fn masks_differ_per_round_and_seed() {
        let peers = ["a", "b"];
        let mk = |round, seed| {
            let mut v = vec![0f32; 16];
            mask_update(&mut v, "a", &peers, round, seed).unwrap();
            v
        };
        assert_ne!(mk(1, 42), mk(2, 42));
        assert_ne!(mk(1, 42), mk(1, 43));
        assert_eq!(mk(1, 42), mk(1, 42));
    }

    #[test]
    fn missing_self_in_peers_is_error() {
        let mut v = vec![0f32; 4];
        assert!(mask_update(&mut v, "x", &["a", "b"], 1, 1).is_err());
    }

    #[test]
    fn quantize_grid_properties() {
        assert_eq!(quantize_to_grid(0.0), 0.0);
        assert_eq!(quantize_to_grid(1.0), 1.0); // grid multiples pass through
        assert_eq!(quantize_to_grid(100.0), MASK_CLAMP);
        assert_eq!(quantize_to_grid(-100.0), -MASK_CLAMP);
        assert_eq!(quantize_to_grid(f32::NAN), 0.0);
        assert_eq!(quantize_to_grid(f32::INFINITY), 0.0);
        let x = 0.123_456_f32;
        assert!((quantize_to_grid(x) - x).abs() <= MASK_GRID / 2.0 + f32::EPSILON);
    }

    #[test]
    fn peer_list_roundtrips_ids_with_commas_and_percents() {
        let ids = ["plain", "a,b", "50%", "%2C", "x,%,y"];
        let csv = encode_peer_list(&ids);
        assert_eq!(decode_peer_list(&csv), ids);
        // every encoded entry is comma-free, so the CSV framing is safe
        assert_eq!(csv.split(',').count(), ids.len());
    }

    #[test]
    fn id_hash_stable_and_distinct() {
        assert_eq!(id_hash("tx2-0"), id_hash("tx2-0"));
        assert_ne!(id_hash("tx2-0"), id_hash("tx2-1"));
    }

    #[test]
    fn pair_seed_symmetric_for_arbitrary_string_ids() {
        for (a, b) in [("pixel4-0", "jetson_tx2_gpu-3"), ("β", "α"), ("a b", "c,d")] {
            assert_eq!(pair_seed(7, 3, a, b), pair_seed(7, 3, b, a));
            assert_ne!(pair_seed(7, 3, a, b), pair_seed(7, 4, a, b));
        }
    }
}
