//! Secure aggregation masking (client side).
//!
//! FL's privacy promise (the paper's opening motivation) is stronger when
//! the server never sees an individual update. Classic additive masking
//! (Bonawitz et al. 2017, the protocol behind Flower's SecAgg): every
//! pair of clients (a, b) derives a shared mask vector m_ab from a common
//! seed; a adds it, b subtracts it, so Σ masked = Σ plain while each
//! individual update is statistically noise to the server.
//!
//! This implementation is the honest "SecAgg0" core: pairwise masks from
//! a per-round shared seed, no dropout recovery (all maskers must report,
//! or the round fails — the full protocol adds secret-shared recovery;
//! see the doc-test in `strategy::secagg` for how failures surface).

use crate::client::keys;
use crate::error::{Error, Result};
use crate::proto::scalar::ConfigExt;
use crate::proto::{
    EvaluateIns, EvaluateRes, FitIns, FitRes, GetParametersIns, GetParametersRes, Parameters,
};
use crate::util::rng::Rng;

use super::Client;

/// Stable 64-bit FNV-1a over a client id string.
pub fn id_hash(id: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in id.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The pairwise mask stream seed for (a, b) in a given round. Symmetric
/// in (a, b) — both ends derive the same stream.
fn pair_seed(base: u64, round: u64, a: &str, b: &str) -> u64 {
    let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
    base ^ round.wrapping_mul(0x9E3779B97F4A7C15) ^ id_hash(lo).rotate_left(17)
        ^ id_hash(hi).rotate_left(43)
}

/// Mask scale: large enough that an individual update is useless to an
/// observer, small enough that f32 cancellation error stays ~1e-3.
const MASK_SCALE: f32 = 8.0;

/// Apply pairwise masks to a flat update. `peers` must include every
/// cohort member of this round, *including* `my_id`.
pub fn mask_update(
    params: &mut [f32],
    my_id: &str,
    peers: &[&str],
    round: u64,
    base_seed: u64,
) -> Result<()> {
    if !peers.contains(&my_id) {
        return Err(Error::Client(format!(
            "secagg peer list does not contain self ({my_id})"
        )));
    }
    for peer in peers {
        if *peer == my_id {
            continue;
        }
        let mut rng = Rng::seed_from(pair_seed(base_seed, round, my_id, peer));
        // sign convention: the lexicographically smaller id adds
        let sign = if my_id < *peer { 1.0f32 } else { -1.0f32 };
        for p in params.iter_mut() {
            *p += sign * MASK_SCALE * rng.normal_f32();
        }
    }
    Ok(())
}

/// Client wrapper that masks outgoing fit updates when the server's
/// config carries the SecAgg keys (set by `strategy::SecAgg`).
pub struct MaskedClient<C: Client> {
    inner: C,
    client_id: String,
}

impl<C: Client> MaskedClient<C> {
    pub fn new(inner: C, client_id: &str) -> Self {
        MaskedClient { inner, client_id: client_id.to_string() }
    }
}

impl<C: Client> Client for MaskedClient<C> {
    fn get_parameters(&mut self, ins: GetParametersIns) -> Result<GetParametersRes> {
        self.inner.get_parameters(ins)
    }

    fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
        let peers_csv = ins.config.get_str(keys::SECAGG_PEERS).map(str::to_string);
        let seed = ins.config.get_i64(keys::SECAGG_SEED);
        let round = ins.config.get_i64_or(keys::ROUND, 0) as u64;
        let mut res = self.inner.fit(ins)?;
        if let (Ok(peers_csv), Ok(seed)) = (peers_csv, seed) {
            let peers: Vec<&str> = peers_csv.split(',').collect();
            let mut flat = res.parameters.to_flat_vec()?;
            mask_update(&mut flat, &self.client_id, &peers, round, seed as u64)?;
            res.parameters = Parameters::from_flat(flat);
        }
        Ok(res)
    }

    fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes> {
        self.inner.evaluate(ins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_cancel_over_cohort() {
        let peers = ["a", "b", "c", "d"];
        let p = 512;
        let plain: Vec<Vec<f32>> = (0..4)
            .map(|i| (0..p).map(|j| (i * p + j) as f32 * 1e-3).collect())
            .collect();
        let mut masked = plain.clone();
        for (i, id) in peers.iter().enumerate() {
            mask_update(&mut masked[i], id, &peers, 3, 42).unwrap();
        }
        // each individual update is far from the original...
        for i in 0..4 {
            let dist: f32 = masked[i]
                .iter()
                .zip(&plain[i])
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / p as f32;
            assert!(dist > 1.0, "client {i} barely masked: {dist}");
        }
        // ...but the sums agree to f32 tolerance
        for j in 0..p {
            let sum_plain: f32 = plain.iter().map(|v| v[j]).sum();
            let sum_masked: f32 = masked.iter().map(|v| v[j]).sum();
            assert!(
                (sum_plain - sum_masked).abs() < 1e-3,
                "j={j}: {sum_plain} vs {sum_masked}"
            );
        }
    }

    #[test]
    fn masks_differ_per_round_and_seed() {
        let peers = ["a", "b"];
        let mk = |round, seed| {
            let mut v = vec![0f32; 16];
            mask_update(&mut v, "a", &peers, round, seed).unwrap();
            v
        };
        assert_ne!(mk(1, 42), mk(2, 42));
        assert_ne!(mk(1, 42), mk(1, 43));
        assert_eq!(mk(1, 42), mk(1, 42));
    }

    #[test]
    fn missing_self_in_peers_is_error() {
        let mut v = vec![0f32; 4];
        assert!(mask_update(&mut v, "x", &["a", "b"], 1, 1).is_err());
    }

    #[test]
    fn id_hash_stable_and_distinct() {
        assert_eq!(id_hash("tx2-0"), id_hash("tx2-0"));
        assert_ne!(id_hash("tx2-0"), id_hash("tx2-1"));
    }
}
