//! Length-prefixed framing over any `Read`/`Write`.
//!
//! ```text
//! frame := len:u32-LE payload[len]
//! ```
//!
//! `MAX_FRAME` bounds a single message at 256 MiB — far above any model
//! this system ships (the CIFAR CNN is ~0.5 MiB of f32), but small enough
//! that a corrupted length prefix cannot OOM the server.

use std::io::{IoSlice, Read, Write};

use crate::error::{Error, Result};
use crate::obs;
use crate::util::bytes::{LeReader, LeWriter};

/// Upper bound on a single frame's payload.
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// `write_all` over two buffers, coalescing prefix + payload into a
/// single vectored syscall per iteration (std's `write_all_vectored`
/// is unstable). In-memory writers (`Vec<u8>`) concatenate the slices,
/// so the output bytes are identical to two sequential `write_all`s.
fn write_all_vectored<W: Write>(w: &mut W, mut a: &[u8], mut b: &[u8]) -> std::io::Result<()> {
    while !a.is_empty() || !b.is_empty() {
        let n = match w.write_vectored(&[IoSlice::new(a), IoSlice::new(b)]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write whole frame",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if n >= a.len() {
            b = &b[n - a.len()..];
            a = &[];
        } else {
            a = &a[n..];
        }
    }
    Ok(())
}

/// Write one frame (length prefix + payload) and flush — one vectored
/// write instead of two sequential ones, so a whole frame is a single
/// syscall on an unbuffered socket. The prefix goes through the shared
/// [`crate::util::bytes`] codec, so all three byte formats (wire,
/// checkpoint, frame) agree on one little-endian implementation.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::Transport(format!(
            "frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
            payload.len()
        )));
    }
    let mut prefix = LeWriter::with_capacity(4);
    prefix.u32(payload.len() as u32);
    write_all_vectored(w, prefix.as_slice(), payload)?;
    w.flush()?;
    let total = (payload.len() + 4) as u64;
    obs::registry().counter("transport_frames_sent_total").inc();
    obs::registry().counter("transport_bytes_sent_total").add(total);
    obs::emit_global(&obs::Event::FrameSent {
        t_s: obs::wall_t_s(),
        bytes: total,
    });
    Ok(())
}

/// Read one whole frame; errors on EOF mid-frame or oversized prefix.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::Transport("connection closed".into())
        } else if matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ) {
            Error::Timeout("frame read timed out".into())
        } else {
            Error::Io(e)
        }
    })?;
    let len = LeReader::new(&len_buf, Error::Transport).u32()? as usize;
    if len > MAX_FRAME {
        return Err(Error::Transport(format!(
            "incoming frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| Error::Transport(format!("truncated frame: {e}")))?;
    let total = (len + 4) as u64;
    obs::registry().counter("transport_frames_recv_total").inc();
    obs::registry().counter("transport_bytes_recv_total").add(total);
    obs::emit_global(&obs::Event::FrameRecv {
        t_s: obs::wall_t_s(),
        bytes: total,
    });
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_prefix_bytes_are_pinned() {
        // golden vector: u32-LE length prefix, payload verbatim
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abc").unwrap();
        assert_eq!(buf, vec![3, 0, 0, 0, b'a', b'b', b'c']);
    }

    /// A writer that accepts one byte per call: exercises the vectored
    /// retry loop's resume-mid-prefix and resume-mid-payload paths.
    struct Dribble(Vec<u8>);

    impl std::io::Write for Dribble {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(1);
            self.0.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> std::io::Result<usize> {
            match bufs.iter().find(|b| !b.is_empty()) {
                Some(b) => {
                    self.0.push(b[0]);
                    Ok(1)
                }
                None => Ok(0),
            }
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn partial_vectored_writes_produce_identical_bytes() {
        let mut whole = Vec::new();
        write_frame(&mut whole, b"flower").unwrap();
        let mut dribble = Dribble(Vec::new());
        write_frame(&mut dribble, b"flower").unwrap();
        assert_eq!(dribble.0, whole);
    }

    #[test]
    fn roundtrip_in_memory() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello flower").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello flower");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
    }

    #[test]
    fn oversized_incoming_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(err.to_string().contains("MAX_FRAME"));
    }

    #[test]
    fn eof_is_clean_error() {
        let err = read_frame(&mut Cursor::new(Vec::new())).unwrap_err();
        assert!(err.to_string().contains("connection closed"));
    }

    #[test]
    fn truncated_payload_detected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]); // only 3 of 8 bytes
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
    }
}
