//! Byte transports under the Flower Protocol.
//!
//! The transport moves opaque frames; `proto::codec` gives them meaning.
//! Two implementations:
//!
//! * [`tcp`] — length-prefixed frames over TCP, thread-per-client on the
//!   server. This is the paper's deployment shape: a cloud-hosted RPC
//!   server, edge devices dialing in.
//! * [`inproc`] — a pair of in-process channels. Used by the device-farm
//!   simulator to run tens of clients in one process with the *exact
//!   same* server code path (messages still round-trip through the codec,
//!   so simulation exercises the full serialization stack).

pub mod frame;
pub mod inproc;
pub mod tcp;

use std::time::Duration;

use crate::error::Result;
use crate::proto::{ClientMessage, ServerMessage};
use crate::util::bytes::FrameBuf;

/// A bidirectional connection, server or client end.
///
/// Enum instead of `dyn` so both ends stay allocation- and vtable-free;
/// every variant moves whole frames (no partial reads surface to callers).
pub enum Connection {
    Tcp(tcp::TcpConnection),
    InProc(inproc::InProcConnection),
}

impl Connection {
    /// Send raw frame bytes.
    pub fn send(&mut self, frame: &[u8]) -> Result<()> {
        match self {
            Connection::Tcp(c) => c.send(frame),
            Connection::InProc(c) => c.send(frame),
        }
    }

    /// Receive one whole frame (blocking).
    pub fn recv(&mut self) -> Result<Vec<u8>> {
        match self {
            Connection::Tcp(c) => c.recv(),
            Connection::InProc(c) => c.recv(),
        }
    }

    /// Receive one whole frame with a deadline.
    pub fn recv_deadline(&mut self, timeout: Duration) -> Result<Vec<u8>> {
        match self {
            Connection::Tcp(c) => c.recv_timeout(timeout),
            Connection::InProc(c) => c.recv_timeout(timeout),
        }
    }

    /// Receive one whole frame as a shared, `Arc`-backed buffer — the
    /// zero-copy decode path (wraps the freshly read `Vec` without
    /// copying it).
    pub fn recv_frame(&mut self) -> Result<FrameBuf> {
        Ok(FrameBuf::new(self.recv()?))
    }

    /// Receive one whole frame as a shared buffer, with a deadline.
    pub fn recv_frame_deadline(&mut self, timeout: Duration) -> Result<FrameBuf> {
        Ok(FrameBuf::new(self.recv_deadline(timeout)?))
    }

    /// Server side: send a typed server message (wire v1).
    pub fn send_server_message(&mut self, msg: &ServerMessage) -> Result<()> {
        self.send_server_message_v(msg, crate::proto::codec::VERSION)
    }

    /// Server side: send a typed server message at a negotiated wire
    /// version (v2 connections ship tensor-bearing messages zero-copy).
    pub fn send_server_message_v(&mut self, msg: &ServerMessage, wire: u8) -> Result<()> {
        let buf = crate::proto::encode_server_message_v(msg, wire);
        self.send(&buf)
    }

    /// Server side: receive a typed client message (any wire version).
    pub fn recv_client_message(&mut self) -> Result<ClientMessage> {
        let buf = self.recv_frame()?;
        crate::proto::decode_client_frame(&buf)
    }

    /// Server side: receive a typed client message with a deadline.
    pub fn recv_client_message_timeout(&mut self, timeout: Duration) -> Result<ClientMessage> {
        let buf = self.recv_frame_deadline(timeout)?;
        crate::proto::decode_client_frame(&buf)
    }

    /// Client side: send a typed client message (wire v1).
    pub fn send_client_message(&mut self, msg: &ClientMessage) -> Result<()> {
        self.send_client_message_v(msg, crate::proto::codec::VERSION)
    }

    /// Client side: send a typed client message at a negotiated wire
    /// version.
    pub fn send_client_message_v(&mut self, msg: &ClientMessage, wire: u8) -> Result<()> {
        let buf = crate::proto::encode_client_message_v(msg, wire);
        self.send(&buf)
    }

    /// Client side: receive a typed server message (any wire version).
    pub fn recv_server_message(&mut self) -> Result<ServerMessage> {
        let buf = self.recv_frame()?;
        crate::proto::decode_server_frame(&buf)
    }
}
