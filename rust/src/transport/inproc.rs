//! In-process transport: channel pairs carrying encoded frames.
//!
//! Used by the device-farm simulator so the server talks to simulated
//! clients through the *identical* message/codec path as TCP — only the
//! byte-moving layer is swapped. Frames are still fully encoded/decoded,
//! so serialization bugs cannot hide in simulation.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::time::Duration;

use crate::error::{Error, Result};

/// Channel capacity: a handful of in-flight messages per direction is
/// plenty — the Flower Protocol is strictly request/response per client.
const CAPACITY: usize = 8;

/// One end of an in-process duplex connection.
pub struct InProcConnection {
    tx: SyncSender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

/// Create a connected pair: (server end, client end).
pub fn pair() -> (InProcConnection, InProcConnection) {
    let (tx_a, rx_b) = sync_channel(CAPACITY);
    let (tx_b, rx_a) = sync_channel(CAPACITY);
    (
        InProcConnection { tx: tx_a, rx: rx_a },
        InProcConnection { tx: tx_b, rx: rx_b },
    )
}

impl InProcConnection {
    pub fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| Error::Transport("in-proc peer closed".into()))
    }

    pub fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx
            .recv()
            .map_err(|_| Error::Transport("in-proc peer closed".into()))
    }

    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Vec<u8>> {
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => Error::Timeout("in-proc recv timed out".into()),
            RecvTimeoutError::Disconnected => Error::Transport("in-proc peer closed".into()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplex_roundtrip() {
        let (mut server, mut client) = pair();
        client.send(b"hello").unwrap();
        assert_eq!(server.recv().unwrap(), b"hello");
        server.send(b"world").unwrap();
        assert_eq!(client.recv().unwrap(), b"world");
    }

    #[test]
    fn closed_peer_errors() {
        let (mut server, client) = pair();
        drop(client);
        assert!(server.recv().is_err());
        assert!(server.send(b"x").is_err());
    }

    #[test]
    fn recv_timeout_elapses() {
        let (mut server, _client) = pair();
        let err = server.recv_timeout(Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, Error::Timeout(_)));
    }

    #[test]
    fn typed_messages_inproc() {
        use crate::proto::*;
        use crate::transport::Connection;

        let (server, client) = pair();
        let mut server = Connection::InProc(server);
        let mut client = Connection::InProc(client);

        let ins = ServerMessage::FitIns(FitIns {
            parameters: Parameters::from_flat(vec![1.0, 2.0]),
            config: crate::config! { "epochs" => 1i64 },
        });
        server.send_server_message(&ins).unwrap();
        assert_eq!(client.recv_server_message().unwrap(), ins);
    }
}
