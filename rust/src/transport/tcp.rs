//! TCP transport: the deployment path (cloud server, edge clients dial in).
//!
//! Blocking I/O; the server dedicates a thread per connected client (the
//! paper's cohorts are tens of devices — thread-per-client is the simple,
//! robust choice at that scale).

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::frame::{read_frame, write_frame};
use crate::error::{Error, Result};

/// One established TCP connection moving whole frames.
pub struct TcpConnection {
    stream: TcpStream,
    peer: String,
    /// Cached read deadline, so `recv`/`recv_timeout` only pay the
    /// `setsockopt` syscall when the deadline actually changes (a fresh
    /// stream has no timeout, matching `None`).
    read_timeout: Option<Duration>,
}

impl TcpConnection {
    pub fn from_stream(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true)?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        Ok(TcpConnection { stream, peer, read_timeout: None })
    }

    /// Dial a Flower server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Transport(format!("connect: {e}")))?;
        Self::from_stream(stream)
    }

    /// Dial with a connect timeout.
    pub fn connect_timeout(addr: &SocketAddr, timeout: Duration) -> Result<Self> {
        let stream = TcpStream::connect_timeout(addr, timeout)
            .map_err(|e| Error::Transport(format!("connect: {e}")))?;
        Self::from_stream(stream)
    }

    pub fn peer(&self) -> &str {
        &self.peer
    }

    pub fn send(&mut self, frame: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, frame)
    }

    fn set_read_timeout(&mut self, t: Option<Duration>) -> Result<()> {
        if self.read_timeout != t {
            self.stream.set_read_timeout(t)?;
            self.read_timeout = t;
        }
        Ok(())
    }

    pub fn recv(&mut self) -> Result<Vec<u8>> {
        self.set_read_timeout(None)?;
        read_frame(&mut self.stream)
    }

    /// Receive with a deadline; returns `Error::Timeout` when it elapses.
    /// The deadline stays armed on the socket afterwards (cached) — the
    /// next `recv` resets it, so callers never observe a stale timeout.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Vec<u8>> {
        self.set_read_timeout(Some(timeout))?;
        read_frame(&mut self.stream)
    }
}

/// Accept loop wrapper for the server side.
pub struct TcpTransportListener {
    listener: TcpListener,
}

impl TcpTransportListener {
    pub fn bind<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::Transport(format!("bind: {e}")))?;
        Ok(TcpTransportListener { listener })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::Io)
    }

    /// Accept the next client connection (blocking).
    pub fn accept(&self) -> Result<TcpConnection> {
        let (stream, _) = self
            .listener
            .accept()
            .map_err(|e| Error::Transport(format!("accept: {e}")))?;
        TcpConnection::from_stream(stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_frame_roundtrip() {
        let listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let client = std::thread::spawn(move || {
            let mut conn = TcpConnection::connect(addr).unwrap();
            conn.send(b"ping").unwrap();
            conn.recv().unwrap()
        });

        let mut server_conn = listener.accept().unwrap();
        assert_eq!(server_conn.recv().unwrap(), b"ping");
        server_conn.send(b"pong").unwrap();

        assert_eq!(client.join().unwrap(), b"pong");
    }

    #[test]
    fn recv_timeout_elapses() {
        let listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpConnection::connect(addr).unwrap();
        let mut server_conn = listener.accept().unwrap();
        let err = server_conn
            .recv_timeout(Duration::from_millis(50))
            .unwrap_err();
        assert!(
            matches!(err, Error::Timeout(_)),
            "expected timeout, got {err}"
        );
    }

    /// Regression: after a `recv_timeout` (which leaves the deadline
    /// cached on the socket), a plain `recv` must clear it and block
    /// until the frame actually arrives.
    #[test]
    fn recv_after_timeout_resets_deadline() {
        let listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let client = std::thread::spawn(move || {
            let mut conn = TcpConnection::connect(addr).unwrap();
            // arrive well after the server's elapsed 20ms deadline
            std::thread::sleep(Duration::from_millis(150));
            conn.send(b"late").unwrap();
        });

        let mut server_conn = listener.accept().unwrap();
        let err = server_conn
            .recv_timeout(Duration::from_millis(20))
            .unwrap_err();
        assert!(matches!(err, Error::Timeout(_)), "expected timeout, got {err}");
        // a stale deadline would time this out too; recv must block
        assert_eq!(server_conn.recv().unwrap(), b"late");
        client.join().unwrap();
    }

    /// Back-to-back deadline receives keep working through the cache
    /// (only the first one pays the setsockopt).
    #[test]
    fn repeated_recv_timeout_uses_cached_deadline() {
        let listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpConnection::connect(addr).unwrap();
        let mut server_conn = listener.accept().unwrap();

        for _ in 0..3 {
            let err = server_conn
                .recv_timeout(Duration::from_millis(10))
                .unwrap_err();
            assert!(matches!(err, Error::Timeout(_)));
        }
        client.send(b"now").unwrap();
        assert_eq!(
            server_conn.recv_timeout(Duration::from_secs(5)).unwrap(),
            b"now"
        );
    }

    #[test]
    fn typed_messages_over_tcp() {
        use crate::proto::*;
        use crate::transport::Connection;

        let listener = TcpTransportListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();

        let client = std::thread::spawn(move || {
            let mut conn = Connection::Tcp(TcpConnection::connect(addr).unwrap());
            conn.send_client_message(&ClientMessage::Register(ClientInfo {
                client_id: "c1".into(),
                device: "jetson_tx2_gpu".into(),
                os: "linux".into(),
                num_examples: 100,
            }))
            .unwrap();
            conn.recv_server_message().unwrap()
        });

        let mut conn = Connection::Tcp(listener.accept().unwrap());
        let msg = conn.recv_client_message().unwrap();
        assert!(matches!(msg, ClientMessage::Register(_)));
        conn.send_server_message(&ServerMessage::Reconnect { seconds: 3 })
            .unwrap();

        assert_eq!(client.join().unwrap(), ServerMessage::Reconnect { seconds: 3 });
    }
}
