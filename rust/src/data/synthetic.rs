//! Synthetic class-conditional Gaussian tasks standing in for CIFAR-10 and
//! Office-31 (see DESIGN.md §2 for the substitution rationale).
//!
//! Every example is `x = signal · μ_class + noise · ε`, with per-class
//! means μ drawn once from a task seed (shared by *all* clients and the
//! server — the federated problem must be one global task) and ε fresh
//! Gaussian noise. `signal/noise` sets the Bayes difficulty: the defaults
//! are tuned so the models land mid-range accuracies like the paper's
//! (CIFAR ≈ 0.48–0.67, Office ≈ 0.84–0.87) rather than saturating.

use super::Dataset;
use crate::util::rng::Rng;

/// Which paper workload a task mimics (sets shapes + default difficulty).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// 32×32×3 images, 10 classes (Jetson workload).
    CifarLike,
    /// 3072-dim raw "office" vectors, 31 classes, consumed by the frozen
    /// base model on-device (Android workload).
    OfficeLike,
}

/// Full description of a synthetic task.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub kind: TaskKind,
    pub classes: usize,
    pub example_elements: usize,
    /// Scale of the class mean component.
    pub signal: f32,
    /// Scale of the per-example Gaussian noise.
    pub noise: f32,
    /// Task seed: fixes the class means (the "world").
    pub seed: u64,
}

impl SyntheticSpec {
    pub fn cifar_like(seed: u64) -> Self {
        SyntheticSpec {
            kind: TaskKind::CifarLike,
            classes: 10,
            example_elements: 32 * 32 * 3,
            // hard-ish: accuracy climbs over tens of rounds, like CIFAR
            // (calibrated so C=10/E=1 lands near the paper's 0.48 band)
            signal: 0.40,
            noise: 1.0,
            seed,
        }
    }

    pub fn office_like(seed: u64) -> Self {
        SyntheticSpec {
            kind: TaskKind::OfficeLike,
            classes: 31,
            example_elements: 3072,
            // easier: transfer-learning accuracies in the paper are ~0.85
            // (calibrated: C=4/E=5/8 rounds lands near 0.80-0.84)
            signal: 0.5,
            noise: 1.4,
            seed,
        }
    }

    /// The class-mean matrix [classes × example_elements], derived from
    /// the task seed only.
    fn class_means(&self) -> Vec<f32> {
        let root = Rng::seed_from(self.seed ^ 0xC1A5_5E5);
        let mut means = Vec::with_capacity(self.classes * self.example_elements);
        for c in 0..self.classes {
            let mut rng = root.derive(c as u64);
            for _ in 0..self.example_elements {
                means.push(rng.normal_f32());
            }
        }
        means
    }

    /// Generate `n` examples with labels drawn uniformly, using `stream`
    /// to decorrelate different holders (clients, server test set).
    pub fn generate(&self, n: usize, stream: u64) -> Dataset {
        let means = self.class_means();
        let mut rng = Rng::seed_from(self.seed).derive(0x9E11 ^ stream);
        let e = self.example_elements;
        let mut x = Vec::with_capacity(n * e);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(self.classes);
            y.push(c as i32);
            let mu = &means[c * e..(c + 1) * e];
            for &m in mu {
                x.push(self.signal * m + self.noise * rng.normal_f32());
            }
        }
        Dataset { x, y, example_elements: e }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_kind() {
        let c = SyntheticSpec::cifar_like(1).generate(16, 0);
        assert_eq!(c.len(), 16);
        assert_eq!(c.example_elements, 3072);
        let o = SyntheticSpec::office_like(1).generate(8, 0);
        assert_eq!(o.example_elements, 3072);
        assert!(o.y.iter().all(|&y| (0..31).contains(&y)));
    }

    #[test]
    fn deterministic_per_seed_and_stream() {
        let spec = SyntheticSpec::cifar_like(7);
        let a = spec.generate(8, 3);
        let b = spec.generate(8, 3);
        let c = spec.generate(8, 4);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn clients_share_class_structure() {
        // Same class on two different streams must be closer (in mean)
        // than different classes: the world is shared.
        let spec = SyntheticSpec::office_like(5);
        let a = spec.generate(400, 1);
        let b = spec.generate(400, 2);
        let e = spec.example_elements;
        let mean_of = |d: &Dataset, cls: i32| -> Vec<f32> {
            let mut acc = vec![0f32; e];
            let mut count = 0;
            for i in 0..d.len() {
                if d.y[i] == cls {
                    for j in 0..e {
                        acc[j] += d.x[i * e + j];
                    }
                    count += 1;
                }
            }
            for v in &mut acc {
                *v /= count.max(1) as f32;
            }
            acc
        };
        let dist = |u: &[f32], v: &[f32]| -> f32 {
            u.iter().zip(v).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt()
        };
        let a0 = mean_of(&a, 0);
        let b0 = mean_of(&b, 0);
        let b1 = mean_of(&b, 1);
        assert!(dist(&a0, &b0) < dist(&a0, &b1));
    }

    #[test]
    fn labels_roughly_uniform() {
        let spec = SyntheticSpec::cifar_like(3);
        let d = spec.generate(5000, 0);
        let h = d.label_histogram(10);
        for &count in &h {
            assert!((350..650).contains(&count), "histogram {h:?}");
        }
    }
}
