//! Client partitioners: how the global training data is split across the
//! federation. IID matches the paper's experiments; Dirichlet and shard
//! splits are the standard non-IID stress tests (used by the ablation
//! benches).

use super::Dataset;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Partitioning policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Partitioner {
    /// Shuffle, then equal contiguous chunks.
    Iid,
    /// Label distribution per client ~ Dirichlet(alpha): small alpha =
    /// pathological heterogeneity, large alpha → IID.
    Dirichlet { alpha: f64 },
    /// Sort by label, split into `shards_per_client * n` shards, deal
    /// each client that many shards (McMahan et al. 2017 style).
    Shards { shards_per_client: usize },
}

impl Partitioner {
    /// Parse from a config string: `iid`, `dirichlet:0.5`, `shards:2`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut it = s.splitn(2, ':');
        match (it.next().unwrap_or(""), it.next()) {
            ("iid", None) => Ok(Partitioner::Iid),
            ("dirichlet", Some(a)) => a
                .parse()
                .map(|alpha| Partitioner::Dirichlet { alpha })
                .map_err(|_| Error::Config(format!("bad dirichlet alpha in {s:?}"))),
            ("shards", Some(k)) => k
                .parse()
                .map(|shards_per_client| Partitioner::Shards { shards_per_client })
                .map_err(|_| Error::Config(format!("bad shard count in {s:?}"))),
            _ => Err(Error::Config(format!(
                "unknown partitioner {s:?} (iid | dirichlet:<alpha> | shards:<k>)"
            ))),
        }
    }

    /// Split `data` into `n_clients` local datasets.
    pub fn split(&self, data: &Dataset, n_clients: usize, rng: &mut Rng) -> Result<Vec<Dataset>> {
        if n_clients == 0 {
            return Err(Error::Config("cannot partition to 0 clients".into()));
        }
        if data.len() < n_clients {
            return Err(Error::Config(format!(
                "{} examples cannot cover {n_clients} clients",
                data.len()
            )));
        }
        match self {
            Partitioner::Iid => {
                let mut idx: Vec<usize> = (0..data.len()).collect();
                rng.shuffle(&mut idx);
                let per = data.len() / n_clients;
                Ok((0..n_clients)
                    .map(|c| data.select(&idx[c * per..(c + 1) * per]))
                    .collect())
            }
            Partitioner::Dirichlet { alpha } => {
                if *alpha <= 0.0 {
                    return Err(Error::Config("dirichlet alpha must be > 0".into()));
                }
                // bucket example indices by label
                let classes = 1 + data.y.iter().copied().max().unwrap_or(0).max(0) as usize;
                let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); classes];
                for (i, &y) in data.y.iter().enumerate() {
                    buckets[y as usize].push(i);
                }
                let mut assignments: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
                for bucket in &mut buckets {
                    rng.shuffle(bucket);
                    let props = rng.dirichlet(*alpha, n_clients);
                    // convert proportions to contiguous cut points
                    let mut start = 0usize;
                    for (c, p) in props.iter().enumerate() {
                        let take = if c + 1 == n_clients {
                            bucket.len() - start
                        } else {
                            ((p * bucket.len() as f64).round() as usize)
                                .min(bucket.len() - start)
                        };
                        assignments[c].extend_from_slice(&bucket[start..start + take]);
                        start += take;
                    }
                }
                for a in &mut assignments {
                    rng.shuffle(a);
                }
                Ok(assignments.iter().map(|a| data.select(a)).collect())
            }
            Partitioner::Shards { shards_per_client } => {
                let k = shards_per_client * n_clients;
                if *shards_per_client == 0 || data.len() < k {
                    return Err(Error::Config(format!(
                        "cannot cut {} examples into {k} shards",
                        data.len()
                    )));
                }
                let mut idx: Vec<usize> = (0..data.len()).collect();
                idx.sort_by_key(|&i| data.y[i]);
                let shard_len = data.len() / k;
                let mut shard_ids: Vec<usize> = (0..k).collect();
                rng.shuffle(&mut shard_ids);
                Ok((0..n_clients)
                    .map(|c| {
                        let mut rows = Vec::with_capacity(shards_per_client * shard_len);
                        for s in 0..*shards_per_client {
                            let shard = shard_ids[c * shards_per_client + s];
                            rows.extend_from_slice(
                                &idx[shard * shard_len..(shard + 1) * shard_len],
                            );
                        }
                        data.select(&rows)
                    })
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    fn data() -> Dataset {
        SyntheticSpec::cifar_like(11).generate(1000, 0)
    }

    #[test]
    fn parse_forms() {
        assert_eq!(Partitioner::parse("iid").unwrap(), Partitioner::Iid);
        assert_eq!(
            Partitioner::parse("dirichlet:0.5").unwrap(),
            Partitioner::Dirichlet { alpha: 0.5 }
        );
        assert_eq!(
            Partitioner::parse("shards:2").unwrap(),
            Partitioner::Shards { shards_per_client: 2 }
        );
        assert!(Partitioner::parse("nope").is_err());
        assert!(Partitioner::parse("dirichlet:x").is_err());
    }

    #[test]
    fn iid_covers_without_overlap() {
        let d = data();
        let parts = Partitioner::Iid.split(&d, 10, &mut Rng::seed_from(1)).unwrap();
        assert_eq!(parts.len(), 10);
        let total: usize = parts.iter().map(Dataset::len).sum();
        assert_eq!(total, 1000);
        // label distribution per part roughly uniform
        for p in &parts {
            let h = p.label_histogram(10);
            assert!(h.iter().all(|&c| c > 0), "IID part missing a class: {h:?}");
        }
    }

    #[test]
    fn dirichlet_skews_labels() {
        let d = data();
        let parts = Partitioner::Dirichlet { alpha: 0.1 }
            .split(&d, 10, &mut Rng::seed_from(2))
            .unwrap();
        let total: usize = parts.iter().map(Dataset::len).sum();
        assert_eq!(total, 1000);
        // at alpha=0.1 most clients should be dominated by few classes
        let dominated = parts
            .iter()
            .filter(|p| {
                if p.is_empty() {
                    return false;
                }
                let h = p.label_histogram(10);
                let max = *h.iter().max().unwrap();
                max as f64 / p.len() as f64 > 0.5
            })
            .count();
        assert!(dominated >= 5, "only {dominated} clients dominated");
    }

    #[test]
    fn shards_give_few_classes() {
        let d = data();
        let parts = Partitioner::Shards { shards_per_client: 2 }
            .split(&d, 10, &mut Rng::seed_from(3))
            .unwrap();
        for p in &parts {
            let classes_present = p.label_histogram(10).iter().filter(|&&c| c > 0).count();
            assert!(classes_present <= 4, "{classes_present} classes in a 2-shard part");
        }
    }

    #[test]
    fn errors_on_degenerate_inputs() {
        let d = data();
        assert!(Partitioner::Iid.split(&d, 0, &mut Rng::seed_from(4)).is_err());
        assert!(Partitioner::Dirichlet { alpha: 0.0 }
            .split(&d, 4, &mut Rng::seed_from(4))
            .is_err());
        assert!(Partitioner::Shards { shards_per_client: 0 }
            .split(&d, 4, &mut Rng::seed_from(4))
            .is_err());
        let tiny = SyntheticSpec::cifar_like(1).generate(3, 0);
        assert!(Partitioner::Iid.split(&tiny, 10, &mut Rng::seed_from(4)).is_err());
    }
}
