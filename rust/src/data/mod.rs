//! Datasets and partitioning for the FL workloads.
//!
//! The paper trains on CIFAR-10 (Jetson) and Office-31 (Android). Neither
//! is downloadable in this environment, so [`synthetic`] generates
//! class-conditional Gaussian tasks with the same shapes and a tunable
//! difficulty — genuinely learnable, so accuracy responds to local epochs
//! E, cohort size C and the τ cutoff the way the paper's curves do
//! (substitution documented in DESIGN.md §2).
//!
//! [`partition`] splits a dataset across clients: IID, Dirichlet non-IID,
//! or label shards (the classic pathological FedAvg split).

pub mod partition;
pub mod synthetic;

pub use partition::Partitioner;
pub use synthetic::{SyntheticSpec, TaskKind};

use crate::error::{Error, Result};

/// A flat, row-major dataset: `n` examples of `example_elements` f32s each
/// plus one i32 label per example.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub example_elements: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<i32>, example_elements: usize) -> Result<Self> {
        if example_elements == 0 || x.len() != y.len() * example_elements {
            return Err(Error::Config(format!(
                "dataset shape mismatch: {} features, {} labels, {} elems/example",
                x.len(),
                y.len(),
                example_elements
            )));
        }
        Ok(Dataset { x, y, example_elements })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Number of whole batches of size `b` (remainder dropped).
    pub fn num_batches(&self, b: usize) -> usize {
        self.len() / b
    }

    /// Borrow batch `i` of size `b`.
    pub fn batch(&self, i: usize, b: usize) -> (&[f32], &[i32]) {
        let lo = i * b;
        let hi = lo + b;
        (
            &self.x[lo * self.example_elements..hi * self.example_elements],
            &self.y[lo..hi],
        )
    }

    /// Select a subset by example indices (used by partitioners).
    pub fn select(&self, indices: &[usize]) -> Dataset {
        let e = self.example_elements;
        let mut x = Vec::with_capacity(indices.len() * e);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(&self.x[i * e..(i + 1) * e]);
            y.push(self.y[i]);
        }
        Dataset { x, y, example_elements: e }
    }

    /// In-place example shuffle.
    pub fn shuffle(&mut self, rng: &mut crate::util::rng::Rng) {
        let n = self.len();
        let e = self.example_elements;
        for i in (1..n).rev() {
            let j = rng.below(i + 1);
            if i != j {
                self.y.swap(i, j);
                // swap rows i and j of x
                let (lo, hi) = (i.min(j), i.max(j));
                let (head, tail) = self.x.split_at_mut(hi * e);
                head[lo * e..(lo + 1) * e].swap_with_slice(&mut tail[..e]);
            }
        }
    }

    /// Replace feature space (e.g. after frozen-base feature extraction).
    pub fn with_features(&self, x: Vec<f32>, example_elements: usize) -> Result<Dataset> {
        Dataset::new(x, self.y.clone(), example_elements)
    }

    /// Per-class histogram over `classes` labels.
    pub fn label_histogram(&self, classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; classes];
        for &y in &self.y {
            if (y as usize) < classes {
                h[y as usize] += 1;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> Dataset {
        Dataset::new(
            (0..20).map(|i| i as f32).collect(),
            (0..10).map(|i| (i % 3) as i32).collect(),
            2,
        )
        .unwrap()
    }

    #[test]
    fn shape_validation() {
        assert!(Dataset::new(vec![0.0; 6], vec![0; 3], 2).is_ok());
        assert!(Dataset::new(vec![0.0; 5], vec![0; 3], 2).is_err());
        assert!(Dataset::new(vec![], vec![], 0).is_err());
    }

    #[test]
    fn batching() {
        let d = tiny();
        assert_eq!(d.num_batches(3), 3);
        let (x, y) = d.batch(1, 3);
        assert_eq!(x, &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(y, &[0, 1, 2]);
    }

    #[test]
    fn select_gathers_rows() {
        let d = tiny();
        let s = d.select(&[0, 9]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.x, vec![0.0, 1.0, 18.0, 19.0]);
        assert_eq!(s.y, vec![0, 0]);
    }

    #[test]
    fn shuffle_keeps_row_pairing() {
        let mut d = tiny();
        let before: std::collections::BTreeSet<(i64, i64, i32)> = (0..d.len())
            .map(|i| (d.x[2 * i] as i64, d.x[2 * i + 1] as i64, d.y[i]))
            .collect();
        d.shuffle(&mut Rng::seed_from(1));
        let after: std::collections::BTreeSet<(i64, i64, i32)> = (0..d.len())
            .map(|i| (d.x[2 * i] as i64, d.x[2 * i + 1] as i64, d.y[i]))
            .collect();
        assert_eq!(before, after);
    }

    #[test]
    fn histogram() {
        let d = tiny();
        assert_eq!(d.label_histogram(3), vec![4, 3, 3]);
    }
}
