//! `flowrs loadgen` — a live-cluster load harness.
//!
//! Holds N concurrent TCP clients against a real [`AsyncServer`] and
//! measures what the wire actually sustains: fit exchanges per second,
//! bytes per second, and frame round-trip latency (p50/p99 of the
//! `transport_rtt_s` histogram — the synthetic clients do near-zero
//! compute, so a fit round trip *is* a frame round trip).
//!
//! The harness owns both sides of the socket: it binds an ephemeral
//! listener, serves registrations (wire-version negotiation included —
//! every synthetic client greets with `Hello` and upgrades to the
//! zero-copy v2 wire, see `transport/PROTOCOL.md`), runs the FedBuff
//! streaming loop bounded by a wall-clock stop flag
//! ([`ServerConfig::stop`]), and reports a JSON summary whose
//! accounting must satisfy the [`AsyncStats`] identity
//! `dispatched == folded + failures + discarded + drained`.
//!
//! Backpressure is bounded by [`LoadgenConfig::max_concurrency`]
//! (0 = every registered client may have a fit outstanding).
//!
//! Metrics (process-global registry, live runs only — see
//! `obs/METRICS.md`): `loadgen_clients_total`,
//! `loadgen_client_errors_total`, `transport_rtt_s`. Counter deltas are
//! taken around the run so earlier in-process activity doesn't leak
//! into the report; histogram quantiles cannot be delta'd, so RTT
//! percentiles assume a fresh process (true for the CLI).
#![deny(missing_docs)]

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::client::{keys, Client};
use crate::error::{Error, Result};
use crate::obs;
use crate::proto::{
    ClientInfo, ConfigMap, EvaluateIns, EvaluateRes, FitIns, FitRes, GetParametersIns,
    GetParametersRes, Parameters, Scalar, Status,
};
use crate::server::{serve_registrations, AsyncServer, AsyncStats, ClientManager, ServerConfig};
use crate::sim::cost::CostModel;
use crate::strategy::fedavg::TrainingPlan;
use crate::strategy::{Aggregator, FedBuff};
use crate::telemetry::log;
use crate::transport::tcp::{TcpConnection, TcpTransportListener};
use crate::transport::Connection;
use crate::util::json::Json;

/// Load-harness knobs (see `flowrs loadgen --help`).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Concurrent TCP clients to hold against the server.
    pub clients: usize,
    /// Wall-clock run duration (the stop flag fires when it elapses;
    /// the loop exits at the next flush/event boundary and drains).
    pub duration: Duration,
    /// FedBuff buffer size K (folds per model version).
    pub buffer_k: usize,
    /// Model size in f32 parameters (the broadcast/update payload).
    pub param_count: usize,
    /// Max concurrent fit dispatches (0 = every registered client).
    pub max_concurrency: usize,
    /// How long to wait for all clients to register before giving up.
    pub quorum_timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            clients: 64,
            duration: Duration::from_secs(10),
            buffer_k: 32,
            param_count: 16_384,
            max_concurrency: 0,
            quorum_timeout: Duration::from_secs(120),
        }
    }
}

/// What one loadgen run measured. `wall_s` covers the measured phase
/// only (quorum ramp-up excluded); throughput figures divide by it.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Clients requested (== clients registered, or the run errors out).
    pub clients: usize,
    /// Client threads that exited with an error.
    pub client_errors: u64,
    /// Whole-run server accounting.
    pub stats: AsyncStats,
    /// Model versions flushed during the run.
    pub versions: usize,
    /// Measured wall-clock seconds (server loop start → drain done).
    pub wall_s: f64,
    /// Folded fit exchanges per wall second.
    pub fits_per_s: f64,
    /// Frames sent/received during the run (both directions, this
    /// process: server + synthetic clients).
    pub frames_sent: u64,
    /// See [`LoadgenReport::frames_sent`].
    pub frames_recv: u64,
    /// Frame payload bytes sent during the run.
    pub bytes_sent: u64,
    /// Frame payload bytes received during the run.
    pub bytes_recv: u64,
    /// `(bytes_sent + bytes_recv) / wall_s`.
    pub bytes_per_s: f64,
    /// Median fit round-trip seconds (`transport_rtt_s` p50).
    pub rtt_p50_s: Option<f64>,
    /// Tail fit round-trip seconds (`transport_rtt_s` p99).
    pub rtt_p99_s: Option<f64>,
    /// Round trips recorded into the RTT histogram.
    pub rtt_count: u64,
    /// Whether `dispatched == folded + failures + discarded + drained`.
    pub identity_ok: bool,
}

impl LoadgenReport {
    /// True when the run is clean: accounting identity intact, zero
    /// client errors, zero fit failures.
    pub fn ok(&self) -> bool {
        self.identity_ok && self.client_errors == 0 && self.stats.failures == 0
    }

    /// The report as a JSON object (stable, sorted keys).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let mut num = |k: &str, v: f64| {
            o.insert(k.to_string(), Json::Num(v));
        };
        num("clients", self.clients as f64);
        num("client_errors", self.client_errors as f64);
        num("dispatched", self.stats.dispatched as f64);
        num("folded", self.stats.folded as f64);
        num("flushed", self.stats.flushed as f64);
        num("failures", self.stats.failures as f64);
        num("discarded", self.stats.discarded as f64);
        num("drained", self.stats.drained as f64);
        num("versions", self.versions as f64);
        num("wall_s", self.wall_s);
        num("fits_per_s", self.fits_per_s);
        num("frames_sent", self.frames_sent as f64);
        num("frames_recv", self.frames_recv as f64);
        num("bytes_sent", self.bytes_sent as f64);
        num("bytes_recv", self.bytes_recv as f64);
        num("bytes_per_s", self.bytes_per_s);
        num("rtt_count", self.rtt_count as f64);
        o.insert(
            "rtt_p50_s".into(),
            self.rtt_p50_s.map(Json::Num).unwrap_or(Json::Null),
        );
        o.insert(
            "rtt_p99_s".into(),
            self.rtt_p99_s.map(Json::Num).unwrap_or(Json::Null),
        );
        o.insert("identity_ok".into(), Json::Bool(self.identity_ok));
        o.insert("ok".into(), Json::Bool(self.ok()));
        Json::Obj(o)
    }
}

/// The synthetic on-device client: echoes the received parameters back
/// as its "update" with near-zero compute, so the measured round trip
/// is transport cost, not training cost.
struct SyntheticClient;

impl SyntheticClient {
    fn metrics() -> ConfigMap {
        let mut m = ConfigMap::new();
        m.insert(keys::STEPS.into(), Scalar::I64(8));
        m.insert(keys::COMPUTE_TIME_S.into(), Scalar::F64(0.0));
        m.insert(keys::ENERGY_J.into(), Scalar::F64(0.0));
        m.insert(keys::TRAIN_LOSS.into(), Scalar::F64(1.0));
        m
    }
}

impl Client for SyntheticClient {
    fn get_parameters(&mut self, _: GetParametersIns) -> Result<GetParametersRes> {
        Ok(GetParametersRes { status: Status::ok(), parameters: Parameters::default() })
    }

    fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
        let p = ins.parameters.to_flat()?.to_vec();
        Ok(FitRes {
            status: Status::ok(),
            parameters: Parameters::from_flat(p),
            num_examples: 256,
            metrics: Self::metrics(),
        })
    }

    fn evaluate(&mut self, _: EvaluateIns) -> Result<EvaluateRes> {
        let mut m = ConfigMap::new();
        m.insert(keys::ACCURACY.into(), Scalar::F64(0.0));
        Ok(EvaluateRes { status: Status::ok(), loss: 0.0, num_examples: 100, metrics: m })
    }
}

/// Run one load test: spin up the server stack on an ephemeral local
/// port, hold [`LoadgenConfig::clients`] negotiated v2 clients against
/// it for [`LoadgenConfig::duration`], then drain and report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.clients == 0 {
        return Err(Error::Config("loadgen needs at least one client".into()));
    }
    if cfg.param_count == 0 {
        return Err(Error::Config("loadgen needs a non-empty model".into()));
    }
    let reg = obs::registry();
    let frames_sent0 = reg.counter("transport_frames_sent_total").get();
    let frames_recv0 = reg.counter("transport_frames_recv_total").get();
    let bytes_sent0 = reg.counter("transport_bytes_sent_total").get();
    let bytes_recv0 = reg.counter("transport_bytes_recv_total").get();

    let listener = TcpTransportListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let manager = Arc::new(ClientManager::new());
    let stop = Arc::new(AtomicBool::new(false));
    let reg_thread = serve_registrations(listener, Arc::clone(&manager), Arc::clone(&stop));

    log::info(&format!(
        "loadgen: {} clients x {} f32 params for {:?} on {addr} (K={}, max_concurrency={})",
        cfg.clients, cfg.param_count, cfg.duration, cfg.buffer_k, cfg.max_concurrency,
    ));

    let errors = Arc::new(AtomicU64::new(0));
    let client_threads: Vec<_> = (0..cfg.clients)
        .map(|i| {
            let errors = Arc::clone(&errors);
            std::thread::spawn(move || {
                let outcome = (|| -> Result<()> {
                    let conn = Connection::Tcp(TcpConnection::connect(addr)?);
                    obs::registry().counter("loadgen_clients_total").inc();
                    let mut client = SyntheticClient;
                    crate::client::app::run_client_negotiated(
                        conn,
                        &mut client,
                        ClientInfo {
                            client_id: format!("load-{i}"),
                            device: "jetson_tx2_gpu".into(),
                            os: "linux".into(),
                            num_examples: 256,
                        },
                    )
                })();
                if let Err(e) = outcome {
                    obs::registry().counter("loadgen_client_errors_total").inc();
                    errors.fetch_add(1, Ordering::Relaxed);
                    log::warn(&format!("loadgen client {i}: {e}"));
                }
            })
        })
        .collect();

    // Ramp-up is excluded from the measured window: wait for the full
    // cohort before starting the clock and the server loop.
    if !manager.wait_for(cfg.clients, cfg.quorum_timeout) {
        stop.store(true, Ordering::Relaxed);
        let registered = manager.len();
        for proxy in manager.snapshot() {
            let _ = proxy.reconnect(0);
        }
        let _ = TcpConnection::connect(addr); // nudge the accept loop
        for t in client_threads {
            let _ = t.join();
        }
        let _ = reg_thread.join();
        return Err(Error::Timeout(format!(
            "loadgen: only {registered} of {} clients registered within {:?}",
            cfg.clients, cfg.quorum_timeout,
        )));
    }

    let strategy = FedBuff::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust, cfg.buffer_k)
        .with_alpha(0.5);
    let mut server = AsyncServer::new(
        Arc::clone(&manager),
        Box::new(strategy),
        CostModel::default(),
        ServerConfig {
            // run "forever"; the stop flag bounds the run by wall clock
            num_rounds: u64::MAX,
            quorum: cfg.clients,
            quorum_timeout: cfg.quorum_timeout,
            async_buffer: Some(cfg.buffer_k),
            max_concurrency: cfg.max_concurrency,
            round_timeout: Duration::from_secs(60),
            stop: Some(Arc::clone(&stop)),
            ..Default::default()
        },
    );

    {
        // Detached wall-clock timer: fires the stop flag; the loop exits
        // at its next event boundary and drains. Harmless if the run
        // already ended (the flag is sticky and the loop is gone).
        let stop = Arc::clone(&stop);
        let duration = cfg.duration;
        std::thread::spawn(move || {
            std::thread::sleep(duration);
            stop.store(true, Ordering::Relaxed);
        });
    }

    let started = Instant::now();
    let history = server.run(Parameters::from_flat(vec![0.0; cfg.param_count]))?;
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);
    let stats = server.stats();

    // The run epilogue sent every client its Reconnect; unblock the
    // accept loop and collect the threads.
    let _ = TcpConnection::connect(addr);
    for t in client_threads {
        let _ = t.join();
    }
    let _ = reg_thread.join();

    let frames_sent = reg.counter("transport_frames_sent_total").get() - frames_sent0;
    let frames_recv = reg.counter("transport_frames_recv_total").get() - frames_recv0;
    let bytes_sent = reg.counter("transport_bytes_sent_total").get() - bytes_sent0;
    let bytes_recv = reg.counter("transport_bytes_recv_total").get() - bytes_recv0;
    let rtt = reg.histogram("transport_rtt_s");

    let report = LoadgenReport {
        clients: cfg.clients,
        client_errors: errors.load(Ordering::Relaxed),
        stats,
        versions: history.rounds.len(),
        wall_s,
        fits_per_s: stats.folded as f64 / wall_s,
        frames_sent,
        frames_recv,
        bytes_sent,
        bytes_recv,
        bytes_per_s: (bytes_sent + bytes_recv) as f64 / wall_s,
        rtt_p50_s: rtt.quantile(0.5),
        rtt_p99_s: rtt.quantile(0.99),
        rtt_count: rtt.count(),
        identity_ok: stats.dispatched
            == stats.folded + stats.failures + stats.discarded + stats.drained,
    };
    log::info(&format!(
        "loadgen: {} folded ({:.0} fits/s), {} versions, {:.1} MiB/s, \
         rtt p50 {:?} p99 {:?}, identity_ok={}",
        stats.folded,
        report.fits_per_s,
        report.versions,
        report.bytes_per_s / (1024.0 * 1024.0),
        report.rtt_p50_s,
        report.rtt_p99_s,
        report.identity_ok,
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A short real-TCP smoke: a handful of negotiated v2 clients, a
    /// sub-second window, and the report must come back clean — zero
    /// client errors, zero fit failures, accounting identity intact.
    #[test]
    fn loadgen_smoke_is_clean() {
        let cfg = LoadgenConfig {
            clients: 4,
            duration: Duration::from_millis(400),
            buffer_k: 2,
            param_count: 64,
            max_concurrency: 0,
            quorum_timeout: Duration::from_secs(30),
        };
        let report = run(&cfg).unwrap();
        assert!(report.ok(), "{report:?}");
        assert!(report.stats.dispatched > 0, "{report:?}");
        assert!(report.frames_sent > 0 && report.frames_recv > 0, "{report:?}");
        assert!(report.rtt_count > 0, "{report:?}");
        // the JSON report carries the verdict fields
        let json = report.to_json();
        assert!(json.get("ok").unwrap().as_bool().unwrap());
        assert!(json.get("identity_ok").unwrap().as_bool().unwrap());
    }
}
