//! # flowrs — On-device Federated Learning with Flower, in Rust
//!
//! A reproduction of *"On-device Federated Learning with Flower"* (Mathur et
//! al., MLSys 2021 on-device workshop) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the Flower coordinator: the FL loop ([`server`]),
//!   the RPC server and wire protocol ([`transport`], [`proto`]), the
//!   pluggable [`strategy`] abstraction (FedAvg and the paper's τ-cutoff
//!   variant among others), the on-device client runtime ([`client`]), the
//!   heterogeneous-device simulation substrate ([`device`], [`sim`]), and
//!   the cost-aware scheduler ([`sched`]): pluggable cohort-selection
//!   policies (uniform / deadline-aware / utility-based) over the
//!   calibrated cost model, per-device availability churn, and an
//!   event-driven virtual-time engine that scales policy experiments to
//!   100k–1M virtual devices ([`sim::population`], `flowrs sched`), and
//!   the checkpoint/resume subsystem ([`persist`]): atomic, versioned
//!   on-disk snapshots of server and engine state, so population-scale
//!   runs survive a coordinator kill and resume bit-identically, and the
//!   structured telemetry subsystem ([`obs`]): a typed event stream, a
//!   metric registry with deterministic histograms, and the per-round
//!   per-class system-cost ledger behind `flowrs sched --obs-out`.
//! * **L2 (JAX, build-time)** — the training workloads (CIFAR CNN, frozen
//!   base + trainable head), lowered once to HLO text under `artifacts/`.
//! * **L1 (Pallas, build-time)** — fused dense fwd/bwd, softmax-xent, SGD
//!   and FedAvg-aggregation kernels inside those HLO modules.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the `xla` crate's PJRT CPU client and executes
//! train / eval / feature-extraction / aggregation steps natively. The
//! PJRT binding sits behind the `xla` cargo feature (see `vendor/xla`);
//! without it the crate still builds and tests — the runtime is stubbed,
//! artifact-dependent paths skip, and population-scale scheduling uses
//! the surrogate trainer.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured numbers.

pub mod client;
pub mod config;
pub mod data;
pub mod device;
pub mod error;
pub mod loadgen;
pub mod metrics;
pub mod obs;
pub mod persist;
pub mod proto;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod sim;
pub mod strategy;
pub mod telemetry;
pub mod transport;
pub mod util;

pub use error::{Error, Result};
