//! Experiment configuration: one declarative struct drives the simulator,
//! the CLI launcher and the bench harness. Loadable from JSON (parsed by
//! the in-tree `util::json`), constructible via builder methods in code.

use std::path::Path;

use crate::data::Partitioner;
use crate::error::{Error, Result};
use crate::sched::availability::ChurnSpec;
use crate::sched::policy::{
    DeadlineAware, FairnessCap, SelectionPolicy, UniformRandom, UtilityBased,
    DEFAULT_EXPLORE_FRAC, DEFAULT_FAIRNESS_CAP, DEFAULT_UTILITY_ALPHA,
};
use crate::sim::cost::CostModel;
use crate::util::json::Json;

/// Which strategy drives the server.
#[derive(Debug, Clone, PartialEq)]
pub enum StrategyConfig {
    FedAvg,
    /// The paper's τ-cutoff FedAvg: per-device cutoffs in seconds.
    FedAvgCutoff {
        taus: Vec<(String, f64)>,
        default_tau_s: Option<f64>,
    },
    FedProx { mu: f64 },
    FedAvgM { beta: f64, server_lr: f64 },
    QFedAvg { q: f64 },
}

/// Parameter aggregation backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggBackend {
    Rust,
    /// The Pallas FedAvg kernel through the PJRT runtime.
    Pjrt,
}

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    /// "cifar_cnn" (Jetson workload) or "head" (Android transfer learning).
    pub model: String,
    pub num_clients: usize,
    pub rounds: u64,
    /// Local epochs E per round.
    pub epochs: i64,
    pub lr: f64,
    pub strategy: StrategyConfig,
    pub partitioner: Partitioner,
    /// Device profile names assigned round-robin; empty = workload default
    /// (TX2 GPU for cifar_cnn, the AWS phone farm for head).
    pub devices: Vec<String>,
    pub train_per_client: usize,
    pub test_per_client: usize,
    pub seed: u64,
    /// Synthetic-task difficulty overrides (None = workload default).
    pub signal: Option<f32>,
    pub noise: Option<f32>,
    pub agg_backend: AggBackend,
    pub cost: CostModel,
    pub count_idle_energy: bool,
    pub target_accuracy: Option<f64>,
    /// Sampling fraction per round (paper uses full participation).
    pub fraction_fit: f64,
    /// f16-quantize parameters on the wire (both directions).
    pub quantize_f16: bool,
    /// Probability a client fails a fit request (failure injection).
    pub dropout: f64,
    /// Secure aggregation (SecAgg0 pairwise masking; forces unweighted
    /// mean aggregation and full participation).
    pub secure_agg: bool,
    /// Asynchronous (FedBuff-style) server loop: flush the aggregation
    /// buffer every K successful results instead of barriering each
    /// round. `None` = the synchronous loop. `rounds` then counts model
    /// versions (flushes).
    pub async_buffer: Option<usize>,
    /// Polynomial staleness-discount exponent for async aggregation
    /// (`w(s) = (1+s)^-alpha`; 0 disables the discount).
    pub staleness_alpha: f64,
    /// Async loop: max concurrent fit dispatches (0 = every client).
    pub max_concurrency: usize,
    /// Write atomic checkpoints (parameters, history, accounting) to
    /// this directory at round/flush boundaries (see [`crate::persist`]).
    /// `None` = no checkpointing.
    pub checkpoint_dir: Option<String>,
    /// Checkpoint every N rounds / model versions (0 = every flush).
    pub checkpoint_every_rounds: u64,
    /// Resume from this checkpoint file — or, if the path is a
    /// directory, its newest valid checkpoint — before round 1.
    pub resume_from: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            model: "cifar_cnn".into(),
            num_clients: 10,
            rounds: 40,
            epochs: 1,
            lr: 0.05,
            strategy: StrategyConfig::FedAvg,
            partitioner: Partitioner::Iid,
            devices: Vec::new(),
            train_per_client: 256,
            test_per_client: 100,
            seed: 20260710,
            signal: None,
            noise: None,
            agg_backend: AggBackend::Pjrt,
            cost: CostModel::default(),
            count_idle_energy: true,
            target_accuracy: None,
            fraction_fit: 1.0,
            quantize_f16: false,
            dropout: 0.0,
            secure_agg: false,
            async_buffer: None,
            staleness_alpha: crate::strategy::fedbuff::DEFAULT_STALENESS_ALPHA,
            max_concurrency: 0,
            checkpoint_dir: None,
            checkpoint_every_rounds: 0,
            resume_from: None,
        }
    }
}

impl ExperimentConfig {
    // -- builder helpers (used heavily by examples and benches) ----------

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.into();
        self
    }
    pub fn model(mut self, model: &str) -> Self {
        self.model = model.into();
        self
    }
    pub fn clients(mut self, n: usize) -> Self {
        self.num_clients = n;
        self
    }
    pub fn rounds(mut self, n: u64) -> Self {
        self.rounds = n;
        self
    }
    pub fn epochs(mut self, e: i64) -> Self {
        self.epochs = e;
        self
    }
    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }
    pub fn strategy(mut self, s: StrategyConfig) -> Self {
        self.strategy = s;
        self
    }
    pub fn devices(mut self, names: &[&str]) -> Self {
        self.devices = names.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn data(mut self, train_per_client: usize, test_per_client: usize) -> Self {
        self.train_per_client = train_per_client;
        self.test_per_client = test_per_client;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn partitioner(mut self, p: Partitioner) -> Self {
        self.partitioner = p;
        self
    }
    pub fn difficulty(mut self, signal: f32, noise: f32) -> Self {
        self.signal = Some(signal);
        self.noise = Some(noise);
        self
    }
    pub fn agg(mut self, backend: AggBackend) -> Self {
        self.agg_backend = backend;
        self
    }
    pub fn quantized(mut self, on: bool) -> Self {
        self.quantize_f16 = on;
        self
    }
    pub fn dropout(mut self, p: f64) -> Self {
        self.dropout = p;
        self
    }
    pub fn secure(mut self, on: bool) -> Self {
        self.secure_agg = on;
        self
    }
    /// Switch the server loop to buffered async aggregation (FedBuff).
    pub fn buffered(mut self, k: usize) -> Self {
        self.async_buffer = Some(k);
        self
    }
    pub fn staleness(mut self, alpha: f64) -> Self {
        self.staleness_alpha = alpha;
        self
    }
    pub fn concurrency(mut self, n: usize) -> Self {
        self.max_concurrency = n;
        self
    }
    /// Write checkpoints into `dir` at round boundaries.
    pub fn checkpoints(mut self, dir: &str) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }
    /// Checkpoint cadence in rounds (0 = every round).
    pub fn checkpoint_every(mut self, n: u64) -> Self {
        self.checkpoint_every_rounds = n;
        self
    }
    /// Resume from a checkpoint file or directory.
    pub fn resume(mut self, path: &str) -> Self {
        self.resume_from = Some(path.into());
        self
    }

    /// Default device list for the workload, if none configured.
    pub fn effective_devices(&self) -> Vec<String> {
        if !self.devices.is_empty() {
            return self.devices.clone();
        }
        if self.model == "head" {
            crate::device::profiles::aws_device_farm_phones()
                .iter()
                .map(|p| p.name.to_string())
                .collect()
        } else {
            vec!["jetson_tx2_gpu".into()]
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.num_clients == 0 {
            return Err(Error::Config("num_clients must be > 0".into()));
        }
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be > 0".into()));
        }
        if self.epochs < 0 {
            return Err(Error::Config("epochs must be >= 0".into()));
        }
        if !(self.lr > 0.0) {
            return Err(Error::Config("lr must be > 0".into()));
        }
        if !(0.0 < self.fraction_fit && self.fraction_fit <= 1.0) {
            return Err(Error::Config("fraction_fit must be in (0, 1]".into()));
        }
        if !(0.0..1.0).contains(&self.dropout) {
            return Err(Error::Config("dropout must be in [0, 1)".into()));
        }
        if let Some(k) = self.async_buffer {
            if k == 0 {
                return Err(Error::Config("async_buffer must be > 0".into()));
            }
            // FedAvg (→ FedBuff), FedProx (→ FedProxBuff) and QFedAvg
            // (→ QFedAvgBuff) have buffered-async adapters; secure_agg and
            // quantize_f16 compose as async wrappers. Cutoff/momentum
            // remain barrier-only.
            match self.strategy {
                StrategyConfig::FedAvg
                | StrategyConfig::FedProx { .. }
                | StrategyConfig::QFedAvg { .. } => {}
                _ => {
                    return Err(Error::Config(format!(
                        "async_buffer supports fedavg, fedprox and qfedavg only \
                         — {:?} has no buffered-async adapter",
                        self.strategy
                    )))
                }
            }
            if self.secure_agg && self.strategy != StrategyConfig::FedAvg {
                return Err(Error::Config(
                    "secure_agg folds are unweighted (masked updates cannot be \
                     reweighted) — combine it with the fedavg strategy only".into(),
                ));
            }
            if self.fraction_fit != 1.0 {
                return Err(Error::Config(
                    "async_buffer streams results from every client \
                     (fraction_fit is not consulted); leave it at 1.0".into(),
                ));
            }
        }
        if self.staleness_alpha < 0.0 || !self.staleness_alpha.is_finite() {
            return Err(Error::Config(
                "staleness_alpha must be finite and >= 0".into(),
            ));
        }
        if self.model != "cifar_cnn" && self.model != "head" {
            return Err(Error::Config(format!("unknown model {:?}", self.model)));
        }
        for d in &self.devices {
            crate::device::profiles::by_name(d)?;
        }
        if let StrategyConfig::FedAvgCutoff { taus, .. } = &self.strategy {
            for (d, tau) in taus {
                crate::device::profiles::by_name(d)?;
                if *tau <= 0.0 {
                    return Err(Error::Config(format!("tau for {d} must be > 0")));
                }
            }
        }
        Ok(())
    }

    // -- JSON loading -----------------------------------------------------

    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let doc = Json::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        let get_str = |k: &str| -> Result<Option<String>> {
            doc.opt(k).map(|v| v.as_str().map(str::to_string)).transpose()
        };
        if let Some(v) = get_str("name")? {
            cfg.name = v;
        }
        if let Some(v) = get_str("model")? {
            cfg.model = v;
        }
        if let Some(v) = doc.opt("num_clients") {
            cfg.num_clients = v.as_usize()?;
        }
        if let Some(v) = doc.opt("rounds") {
            cfg.rounds = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("epochs") {
            cfg.epochs = v.as_f64()? as i64;
        }
        if let Some(v) = doc.opt("lr") {
            cfg.lr = v.as_f64()?;
        }
        if let Some(v) = doc.opt("fraction_fit") {
            cfg.fraction_fit = v.as_f64()?;
        }
        if let Some(v) = doc.opt("partitioner") {
            cfg.partitioner = Partitioner::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.opt("devices") {
            cfg.devices = v
                .as_arr()?
                .iter()
                .map(|d| d.as_str().map(str::to_string))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = doc.opt("train_per_client") {
            cfg.train_per_client = v.as_usize()?;
        }
        if let Some(v) = doc.opt("test_per_client") {
            cfg.test_per_client = v.as_usize()?;
        }
        if let Some(v) = doc.opt("seed") {
            cfg.seed = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("signal") {
            cfg.signal = Some(v.as_f64()? as f32);
        }
        if let Some(v) = doc.opt("noise") {
            cfg.noise = Some(v.as_f64()? as f32);
        }
        if let Some(v) = doc.opt("target_accuracy") {
            cfg.target_accuracy = Some(v.as_f64()?);
        }
        if let Some(v) = doc.opt("count_idle_energy") {
            cfg.count_idle_energy = v.as_bool()?;
        }
        if let Some(v) = doc.opt("t_step_ref_s") {
            cfg.cost.t_step_ref_s = v.as_f64()?;
        }
        if let Some(v) = doc.opt("server_overhead_s") {
            cfg.cost.server_overhead_s = v.as_f64()?;
        }
        if let Some(v) = doc.opt("agg_backend") {
            cfg.agg_backend = match v.as_str()? {
                "rust" => AggBackend::Rust,
                "pjrt" => AggBackend::Pjrt,
                other => {
                    return Err(Error::Config(format!(
                        "unknown agg_backend {other:?} (rust | pjrt)"
                    )))
                }
            };
        }
        if let Some(v) = doc.opt("quantize_f16") {
            cfg.quantize_f16 = v.as_bool()?;
        }
        if let Some(v) = doc.opt("dropout") {
            cfg.dropout = v.as_f64()?;
        }
        if let Some(v) = doc.opt("secure_agg") {
            cfg.secure_agg = v.as_bool()?;
        }
        if let Some(v) = doc.opt("async_buffer") {
            cfg.async_buffer = Some(v.as_usize()?);
        }
        if let Some(v) = doc.opt("staleness_alpha") {
            cfg.staleness_alpha = v.as_f64()?;
        }
        if let Some(v) = doc.opt("max_concurrency") {
            cfg.max_concurrency = v.as_usize()?;
        }
        if let Some(v) = doc.opt("checkpoint_dir") {
            cfg.checkpoint_dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("checkpoint_every_rounds") {
            cfg.checkpoint_every_rounds = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("resume_from") {
            cfg.resume_from = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("strategy") {
            cfg.strategy = parse_strategy(v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

fn parse_strategy(v: &Json) -> Result<StrategyConfig> {
    let kind = v.get("kind")?.as_str()?;
    Ok(match kind {
        "fedavg" => StrategyConfig::FedAvg,
        "fedavg_cutoff" => {
            let mut taus = Vec::new();
            if let Some(map) = v.opt("taus") {
                for (device, tau) in map.as_obj()? {
                    taus.push((device.clone(), tau.as_f64()?));
                }
            }
            let default_tau_s = v.opt("default_tau_s").map(Json::as_f64).transpose()?;
            StrategyConfig::FedAvgCutoff { taus, default_tau_s }
        }
        "fedprox" => StrategyConfig::FedProx { mu: v.get("mu")?.as_f64()? },
        "fedavgm" => StrategyConfig::FedAvgM {
            beta: v.get("beta")?.as_f64()?,
            server_lr: v.opt("server_lr").map(Json::as_f64).transpose()?.unwrap_or(1.0),
        },
        "qfedavg" => StrategyConfig::QFedAvg { q: v.get("q")?.as_f64()? },
        other => {
            return Err(Error::Config(format!("unknown strategy kind {other:?}")))
        }
    })
}

// ---------------------------------------------------------------------------
// Population-scale scheduling (the `sched` subsystem)
// ---------------------------------------------------------------------------

/// Which cohort-selection policy drives the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyConfig {
    Uniform,
    DeadlineAware,
    UtilityBased { alpha: f64, explore_frac: f64 },
    /// Fairness-aware uniform sampling with a per-device selection cap.
    FairnessCap { max_selections: u64 },
}

impl PolicyConfig {
    /// Parse `uniform` | `deadline` | `utility[:ALPHA[:EXPLORE]]` |
    /// `fair[:CAP]`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uniform" => return Ok(PolicyConfig::Uniform),
            "deadline" => return Ok(PolicyConfig::DeadlineAware),
            "utility" => {
                return Ok(PolicyConfig::UtilityBased {
                    alpha: DEFAULT_UTILITY_ALPHA,
                    explore_frac: DEFAULT_EXPLORE_FRAC,
                })
            }
            "fair" => {
                return Ok(PolicyConfig::FairnessCap {
                    max_selections: DEFAULT_FAIRNESS_CAP,
                })
            }
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("utility:") {
            let mut parts = rest.split(':');
            let alpha: f64 = parts
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| Error::Config(format!("bad alpha in {s:?}")))?;
            let explore_frac: f64 = match parts.next() {
                Some(v) => v
                    .parse()
                    .map_err(|_| Error::Config(format!("bad explore fraction in {s:?}")))?,
                None => DEFAULT_EXPLORE_FRAC,
            };
            if parts.next().is_some() {
                return Err(Error::Config(format!("trailing fields in {s:?}")));
            }
            return Ok(PolicyConfig::UtilityBased { alpha, explore_frac });
        }
        if let Some(rest) = s.strip_prefix("fair:") {
            let max_selections: u64 = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad selection cap in {s:?}")))?;
            return Ok(PolicyConfig::FairnessCap { max_selections });
        }
        Err(Error::Config(format!(
            "unknown policy {s:?} (uniform | deadline | utility[:ALPHA[:EXPLORE]] | fair[:CAP])"
        )))
    }

    /// Human-readable label that distinguishes variants — unlike the
    /// built policy's `name()`, which is the kind only ("utility" for
    /// every alpha).
    pub fn label(&self) -> String {
        match self {
            PolicyConfig::Uniform => "uniform".into(),
            PolicyConfig::DeadlineAware => "deadline".into(),
            PolicyConfig::UtilityBased { alpha, explore_frac } => {
                format!("utility:{alpha}:{explore_frac}")
            }
            PolicyConfig::FairnessCap { max_selections } => format!("fair:{max_selections}"),
        }
    }

    /// Instantiate the policy with a seed.
    pub fn build(&self, seed: u64) -> Box<dyn SelectionPolicy> {
        match self {
            PolicyConfig::Uniform => Box::new(UniformRandom::new(seed)),
            PolicyConfig::DeadlineAware => Box::new(DeadlineAware::new(seed)),
            PolicyConfig::UtilityBased { alpha, explore_frac } => Box::new(
                UtilityBased::new(seed)
                    .with_alpha(*alpha)
                    .with_exploration(*explore_frac),
            ),
            PolicyConfig::FairnessCap { max_selections } => {
                Box::new(FairnessCap::new(seed).with_cap(*max_selections))
            }
        }
    }

    fn validate(&self) -> Result<()> {
        match self {
            PolicyConfig::UtilityBased { alpha, explore_frac } => {
                if *alpha < 0.0 || !alpha.is_finite() {
                    return Err(Error::Config(
                        "utility alpha must be finite and >= 0".into(),
                    ));
                }
                if !(0.0..=1.0).contains(explore_frac) {
                    return Err(Error::Config("explore fraction must be in [0, 1]".into()));
                }
            }
            PolicyConfig::FairnessCap { max_selections } => {
                if *max_selections == 0 {
                    return Err(Error::Config("fairness cap must be > 0".into()));
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// Which aggregation strategy the population-scale engine (and the
/// live `ExecCore`) runs. Orthogonal to the sync/async *mode* knob
/// (`async_buffer`): any strategy composes with either mode, so
/// `fedbuff` is **not** a variant here — the CLI maps
/// `--strategy fedbuff[:K]` to `FedAvg` plus `async_buffer = K`.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedStrategyConfig {
    /// Plain example-weighted averaging (the engine's historical
    /// behavior; staleness-discounted in async mode).
    FedAvg,
    /// q-fair reweighting: fold weights scale with `loss^q`, steering
    /// capacity toward badly-served clients. `q = 0` is bit-identical
    /// to FedAvg.
    QFedAvg { q: f64 },
    /// Proximal surrogate term: clients optimize `f_i(w) + mu/2·|w-w_t|²`,
    /// damping fold aggressiveness by `1/(1+mu)`. `mu = 0` is
    /// bit-identical to FedAvg.
    FedProx { mu: f64 },
    /// f16-quantized payloads both ways — halves bytes-on-wire.
    Compressed,
    /// Pairwise-masked secure aggregation: masks cancel exactly in the
    /// fold; adds mask-exchange wire overhead and forbids per-client
    /// reweighting after masking (fold weight is 1.0).
    SecAgg,
}

/// Default fairness exponent for `--strategy qfedavg`.
pub const DEFAULT_QFEDAVG_Q: f64 = 1.0;
/// Default proximal coefficient for `--strategy fedprox`.
pub const DEFAULT_FEDPROX_MU: f64 = 0.01;

impl SchedStrategyConfig {
    /// Parse `fedavg` | `qfedavg[:Q]` | `fedprox[:MU]` | `compressed` |
    /// `secagg`. `fedbuff` is rejected with a hint: it is a *mode*, not
    /// a strategy (the CLI layer maps it to FedAvg + async).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fedavg" => return Ok(SchedStrategyConfig::FedAvg),
            "qfedavg" => return Ok(SchedStrategyConfig::QFedAvg { q: DEFAULT_QFEDAVG_Q }),
            "fedprox" => return Ok(SchedStrategyConfig::FedProx { mu: DEFAULT_FEDPROX_MU }),
            "compressed" => return Ok(SchedStrategyConfig::Compressed),
            "secagg" => return Ok(SchedStrategyConfig::SecAgg),
            "fedbuff" => {
                return Err(Error::Config(
                    "fedbuff is an engine mode, not an aggregation strategy; use \
                     --strategy fedbuff[:K] on the CLI (which maps to fedavg + \
                     async_buffer) or set async_buffer in JSON"
                        .into(),
                ))
            }
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("qfedavg:") {
            let q: f64 = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad q in {s:?}")))?;
            return Ok(SchedStrategyConfig::QFedAvg { q });
        }
        if let Some(rest) = s.strip_prefix("fedprox:") {
            let mu: f64 = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad mu in {s:?}")))?;
            return Ok(SchedStrategyConfig::FedProx { mu });
        }
        Err(Error::Config(format!(
            "unknown strategy {s:?} (fedavg | qfedavg[:Q] | fedprox[:MU] | compressed | secagg)"
        )))
    }

    /// Human-readable label distinguishing variants (comparison-table
    /// row names).
    pub fn label(&self) -> String {
        match self {
            SchedStrategyConfig::FedAvg => "fedavg".into(),
            SchedStrategyConfig::QFedAvg { q } => format!("qfedavg:{q}"),
            SchedStrategyConfig::FedProx { mu } => format!("fedprox:{mu}"),
            SchedStrategyConfig::Compressed => "compressed".into(),
            SchedStrategyConfig::SecAgg => "secagg".into(),
        }
    }

    fn validate(&self) -> Result<()> {
        match self {
            SchedStrategyConfig::QFedAvg { q } => {
                if *q < 0.0 || !q.is_finite() {
                    return Err(Error::Config("qfedavg q must be finite and >= 0".into()));
                }
            }
            SchedStrategyConfig::FedProx { mu } => {
                if *mu < 0.0 || !mu.is_finite() {
                    return Err(Error::Config("fedprox mu must be finite and >= 0".into()));
                }
            }
            _ => {}
        }
        Ok(())
    }
}

impl Default for SchedStrategyConfig {
    fn default() -> Self {
        SchedStrategyConfig::FedAvg
    }
}

/// Device→edge assignment rule for the two-tier topology
/// (`rust/src/sched/TOPOLOGY.md`). Pure functions of the device index —
/// no randomness, so the assignment is trivially mirrored by the Python
/// differential port and stable across resume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeAssignment {
    /// Device `i` belongs to edge `i % edges` (balanced shards).
    RoundRobin,
    /// Geometric shares: edge `e < edges-1` owns the next
    /// `population >> (e+1)` devices (contiguous block), the last edge
    /// absorbs the remainder — a deliberately skewed device→edge map.
    Skew,
}

impl EdgeAssignment {
    /// Stable wire/CLI name.
    pub fn as_str(self) -> &'static str {
        match self {
            EdgeAssignment::RoundRobin => "rr",
            EdgeAssignment::Skew => "skew",
        }
    }

    /// Parse a CLI/JSON name.
    pub fn parse(s: &str) -> Result<EdgeAssignment> {
        match s {
            "rr" => Ok(EdgeAssignment::RoundRobin),
            "skew" => Ok(EdgeAssignment::Skew),
            other => Err(Error::Config(format!(
                "unknown edge assignment {other:?} (rr | skew)"
            ))),
        }
    }

    /// Parse the `--edges N[:assignment]` CLI form.
    pub fn parse_edges(s: &str) -> Result<(usize, EdgeAssignment)> {
        let (n, asg) = match s.split_once(':') {
            Some((n, a)) => (n, EdgeAssignment::parse(a)?),
            None => (s, EdgeAssignment::RoundRobin),
        };
        let n: usize = n
            .parse()
            .map_err(|_| Error::Config(format!("--edges expects N[:rr|skew], got {s:?}")))?;
        Ok((n, asg))
    }
}

/// Parse the `--edge-fail E@T` CLI form: kill edge `E` at virtual time
/// `T` seconds.
pub fn parse_edge_fail(s: &str) -> Result<(u64, f64)> {
    let err = || Error::Config(format!("--edge-fail expects EDGE@T_SECONDS, got {s:?}"));
    let (e, t) = s.split_once('@').ok_or_else(err)?;
    let e: u64 = e.parse().map_err(|_| err())?;
    let t: f64 = t.parse().map_err(|_| err())?;
    Ok((e, t))
}

/// A population-scale scheduling experiment (the `sched` subcommand and
/// [`crate::sim::population`]).
#[derive(Debug, Clone)]
pub struct ScheduleConfig {
    pub name: String,
    pub policy: PolicyConfig,
    /// Aggregation strategy the engine folds with (fold weights +
    /// bytes-on-wire model). Orthogonal to `async_buffer`.
    pub strategy: SchedStrategyConfig,
    /// Round deadline τ (s): selected clients that have not reported by
    /// τ are dropped and their energy wasted. None = wait for everyone.
    pub deadline_s: Option<f64>,
    /// Clients trained per round.
    pub cohort_size: usize,
    /// Virtual devices in the population.
    pub population: usize,
    pub rounds: u64,
    /// Local epochs per selected client per round.
    pub epochs: i64,
    /// Train steps per local epoch (the paper's Table-2 workload runs 8).
    pub steps_per_epoch: u64,
    /// Parameter payload bytes on the wire, each way (CIFAR CNN ≈ 547 KB).
    pub model_bytes: usize,
    /// (device profile name, weight) population mix; empty = default mix.
    /// Trace class tags override the mix for the devices they tag.
    pub device_mix: Vec<(String, f64)>,
    /// On/off churn; None = everyone always available. Mutually
    /// exclusive with `trace_file` / `scenario` (those *replace* the
    /// synthetic availability model).
    pub churn: Option<ChurnSpec>,
    /// Replay availability (and per-device hardware classes) from this
    /// recorded trace file — CSV or JSON, spec in
    /// `rust/src/sched/TRACES.md`. `population` must equal the trace's
    /// device count. Mutually exclusive with `scenario` and `churn`.
    pub trace_file: Option<String>,
    /// Generate availability from a named scenario (`diurnal`,
    /// `charging-gated`, `flash-crowd`), deterministically from `seed`.
    /// Mutually exclusive with `trace_file` and `churn`.
    pub scenario: Option<String>,
    /// Horizon (seconds) scenario traces are materialized over; devices
    /// freeze in their final state past it, so pick one beyond the
    /// virtual time the run will reach.
    pub scenario_horizon_s: f64,
    pub seed: u64,
    pub cost: CostModel,
    /// Early-stop (and time-to-accuracy reporting) target.
    pub target_accuracy: Option<f64>,
    /// Asynchronous (FedBuff-style) engine mode: fold device-finish
    /// events into a buffer and flush a new model version every K folds,
    /// instead of barriering each round on the slowest cohort member.
    /// `None` = the synchronous round loop. `rounds` then counts model
    /// versions (flushes).
    pub async_buffer: Option<usize>,
    /// Polynomial staleness-discount exponent for async folds
    /// (`w(s) = (1+s)^-alpha`).
    pub staleness_alpha: f64,
    /// Async mode: max concurrent in-flight dispatches
    /// (0 = `cohort_size`).
    pub max_concurrency: usize,
    /// Write atomic engine checkpoints to this directory at flush
    /// boundaries (see [`crate::persist`]). `None` = no checkpointing.
    pub checkpoint_dir: Option<String>,
    /// Checkpoint every N rounds / model versions (0 = every flush).
    pub checkpoint_every_rounds: u64,
    /// Resume from this checkpoint file — or, if the path is a
    /// directory, its newest valid checkpoint. The resumed run replays
    /// the uninterrupted trajectory bit-identically.
    pub resume_from: Option<String>,
    /// Write structured telemetry into this directory
    /// (`events.jsonl`, `metrics.json`, `costs.csv` — see
    /// [`crate::obs`] and `rust/src/obs/METRICS.md`). `None` = no
    /// instrumentation output. Never affects the trajectory (excluded
    /// from [`ScheduleConfig::fingerprint`]).
    pub obs_out: Option<String>,
    /// Worker threads for the sharded engine paths (population
    /// synthesis, the per-round availability scan and candidate build,
    /// policy partition passes, and the weighted-average fold). Purely
    /// an execution knob: every sharded path merges in shard order, so
    /// any value produces byte-identical CSVs, `events.jsonl` and
    /// checkpoints to `--workers 1` — and is therefore excluded from
    /// [`ScheduleConfig::fingerprint`].
    pub workers: usize,
    /// Edge-aggregator tier width: the number of edge nodes folding
    /// device deltas before anything reaches the cloud coordinator.
    /// `1` (the default) is today's flat shape — the tier machinery is
    /// bypassed entirely and every output stays byte-identical to the
    /// pre-topology engine. Normative semantics in
    /// `rust/src/sched/TOPOLOGY.md`.
    pub edges: usize,
    /// Device→edge assignment rule; only meaningful when `edges > 1`.
    pub edge_assignment: EdgeAssignment,
    /// Fail edge `.0` at virtual time `.1` s: its buffered deltas drop
    /// (charged as churn waste), it ships nothing afterwards, and its
    /// devices degrade to direct-to-cloud dispatch for the rest of the
    /// run. `None` = no failure injection.
    pub edge_fail: Option<(u64, f64)>,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            name: "sched".into(),
            policy: PolicyConfig::Uniform,
            strategy: SchedStrategyConfig::FedAvg,
            deadline_s: None,
            cohort_size: 100,
            population: 100_000,
            rounds: 30,
            epochs: 1,
            steps_per_epoch: 8,
            model_bytes: 547_496,
            device_mix: Vec::new(),
            churn: None,
            trace_file: None,
            scenario: None,
            scenario_horizon_s: 172_800.0,
            seed: 20260710,
            cost: CostModel::default(),
            target_accuracy: None,
            async_buffer: None,
            staleness_alpha: crate::strategy::fedbuff::DEFAULT_STALENESS_ALPHA,
            max_concurrency: 0,
            checkpoint_dir: None,
            checkpoint_every_rounds: 0,
            resume_from: None,
            obs_out: None,
            workers: 1,
            edges: 1,
            edge_assignment: EdgeAssignment::RoundRobin,
            edge_fail: None,
        }
    }
}

impl ScheduleConfig {
    // -- builder helpers (tests and benches) -----------------------------

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.into();
        self
    }
    pub fn policy(mut self, p: PolicyConfig) -> Self {
        self.policy = p;
        self
    }
    pub fn strategy(mut self, s: SchedStrategyConfig) -> Self {
        self.strategy = s;
        self
    }
    pub fn deadline(mut self, tau_s: Option<f64>) -> Self {
        self.deadline_s = tau_s;
        self
    }
    pub fn cohort(mut self, k: usize) -> Self {
        self.cohort_size = k;
        self
    }
    pub fn population(mut self, n: usize) -> Self {
        self.population = n;
        self
    }
    pub fn rounds(mut self, n: u64) -> Self {
        self.rounds = n;
        self
    }
    pub fn epochs(mut self, e: i64) -> Self {
        self.epochs = e;
        self
    }
    pub fn churn(mut self, spec: Option<ChurnSpec>) -> Self {
        self.churn = spec;
        self
    }
    /// Replay availability from a recorded trace file (CSV or JSON).
    pub fn trace_file(mut self, path: &str) -> Self {
        self.trace_file = Some(path.into());
        self
    }
    /// Generate availability from a named scenario.
    pub fn scenario(mut self, name: &str) -> Self {
        self.scenario = Some(name.into());
        self
    }
    /// Horizon (seconds) scenario traces are materialized over.
    pub fn scenario_horizon(mut self, horizon_s: f64) -> Self {
        self.scenario_horizon_s = horizon_s;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    /// Switch the engine to buffered async aggregation (FedBuff-style).
    pub fn buffered(mut self, k: usize) -> Self {
        self.async_buffer = Some(k);
        self
    }
    pub fn staleness(mut self, alpha: f64) -> Self {
        self.staleness_alpha = alpha;
        self
    }
    pub fn concurrency(mut self, n: usize) -> Self {
        self.max_concurrency = n;
        self
    }

    /// Write checkpoints into `dir` at flush boundaries.
    pub fn checkpoints(mut self, dir: &str) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }
    /// Checkpoint cadence in rounds / versions (0 = every flush).
    pub fn checkpoint_every(mut self, n: u64) -> Self {
        self.checkpoint_every_rounds = n;
        self
    }
    /// Resume from a checkpoint file or directory.
    pub fn resume(mut self, path: &str) -> Self {
        self.resume_from = Some(path.into());
        self
    }
    /// Write structured telemetry (`events.jsonl`, `metrics.json`,
    /// `costs.csv`) into `dir`.
    pub fn obs(mut self, dir: &str) -> Self {
        self.obs_out = Some(dir.into());
        self
    }
    /// Worker threads for the sharded engine paths (≥ 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }
    /// Edge-aggregator tier width (1 = flat, no tier).
    pub fn edges(mut self, n: usize) -> Self {
        self.edges = n;
        self
    }
    /// Device→edge assignment rule.
    pub fn edge_assignment(mut self, a: EdgeAssignment) -> Self {
        self.edge_assignment = a;
        self
    }
    /// Fail edge `edge` at virtual time `t_s`.
    pub fn edge_fail(mut self, edge: u64, t_s: f64) -> Self {
        self.edge_fail = Some((edge, t_s));
        self
    }

    /// Stable fingerprint of every knob the engine's *trajectory*
    /// depends on. Excluded: `name`, `rounds`, `target_accuracy` (a
    /// resumed run may legitimately extend or re-target a finished
    /// one), the checkpoint knobs themselves, `obs_out` (observability
    /// must never affect trajectory identity — a resume may add or drop
    /// instrumentation freely), and `workers` (run identity is
    /// worker-count-invariant: every sharded path merges in shard
    /// order, so a `--workers 1` checkpoint resumes under `--workers 8`
    /// and vice versa). Resume refuses a checkpoint whose fingerprint
    /// does not match — a silent config drift would otherwise break the
    /// bit-identical-replay guarantee.
    ///
    /// The version prefix marks fingerprint-era boundaries (the
    /// FORMAT.md fingerprint policy): `v2` was the sharded-engine era
    /// (Debug shape gained `workers`); `v3` is the unified-strategy
    /// era (Debug shape gained `strategy`, and the cost books gained
    /// bytes-on-wire); `v4` is the two-tier-topology era (Debug shape
    /// gained `edges` / `edge_assignment` / `edge_fail`, all of which
    /// are trajectory knobs and stay pinned). Prefixes differ across
    /// eras, so old checkpoints fail resume with an explicit mismatch
    /// instead of a silent semantic drift.
    pub fn fingerprint(&self) -> String {
        let mut c = self.clone();
        c.name = String::new();
        c.rounds = 0;
        c.target_accuracy = None;
        c.checkpoint_dir = None;
        c.checkpoint_every_rounds = 0;
        c.resume_from = None;
        c.obs_out = None;
        c.workers = 1;
        format!("schedule-v4:{c:?}")
    }

    /// Async in-flight bound: explicit `max_concurrency`, or the cohort
    /// size when unset.
    pub fn effective_concurrency(&self) -> usize {
        if self.max_concurrency == 0 {
            self.cohort_size
        } else {
            self.max_concurrency
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.population == 0 {
            return Err(Error::Config("population must be > 0".into()));
        }
        if self.cohort_size == 0 {
            return Err(Error::Config("cohort_size must be > 0".into()));
        }
        if self.cohort_size > self.population {
            return Err(Error::Config(format!(
                "cohort_size {} exceeds population {}",
                self.cohort_size, self.population
            )));
        }
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be > 0".into()));
        }
        if self.epochs < 0 {
            return Err(Error::Config("epochs must be >= 0".into()));
        }
        if self.steps_per_epoch == 0 {
            return Err(Error::Config("steps_per_epoch must be > 0".into()));
        }
        if self.model_bytes == 0 {
            return Err(Error::Config("model_bytes must be > 0".into()));
        }
        if let Some(tau) = self.deadline_s {
            if tau <= 0.0 || !tau.is_finite() {
                return Err(Error::Config("deadline_s must be finite and > 0".into()));
            }
        }
        if let Some(churn) = &self.churn {
            if churn.mean_on_s <= 0.0 || !churn.mean_on_s.is_finite() {
                return Err(Error::Config("churn mean_on_s must be finite and > 0".into()));
            }
            if churn.mean_off_s < 0.0 || !churn.mean_off_s.is_finite() {
                return Err(Error::Config("churn mean_off_s must be finite and >= 0".into()));
            }
        }
        if self.trace_file.is_some() && self.scenario.is_some() {
            return Err(Error::Config(
                "trace_file and scenario are mutually exclusive".into(),
            ));
        }
        if (self.trace_file.is_some() || self.scenario.is_some()) && self.churn.is_some() {
            return Err(Error::Config(
                "churn describes the synthetic availability model; drop it when \
                 replaying a trace or scenario"
                    .into(),
            ));
        }
        if let Some(name) = &self.scenario {
            if !crate::sched::trace::SCENARIOS.contains(&name.as_str()) {
                return Err(Error::Config(format!(
                    "unknown scenario {name:?} ({})",
                    crate::sched::trace::SCENARIOS.join(" | ")
                )));
            }
        }
        if !(self.scenario_horizon_s > 0.0) || !self.scenario_horizon_s.is_finite() {
            return Err(Error::Config(
                "scenario_horizon_s must be finite and > 0".into(),
            ));
        }
        for (name, w) in &self.device_mix {
            crate::device::profiles::by_name(name)?;
            if *w <= 0.0 || !w.is_finite() {
                return Err(Error::Config(format!(
                    "device mix weight for {name} must be finite and > 0"
                )));
            }
        }
        if let Some(k) = self.async_buffer {
            if k == 0 {
                return Err(Error::Config("async_buffer must be > 0".into()));
            }
        }
        if self.staleness_alpha < 0.0 || !self.staleness_alpha.is_finite() {
            return Err(Error::Config(
                "staleness_alpha must be finite and >= 0".into(),
            ));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be >= 1".into()));
        }
        if self.edges == 0 {
            return Err(Error::Config("edges must be >= 1 (1 = flat, no tier)".into()));
        }
        if self.edges > self.population {
            return Err(Error::Config(format!(
                "edges {} exceeds population {}",
                self.edges, self.population
            )));
        }
        if let Some((edge, t_s)) = self.edge_fail {
            if self.edges <= 1 {
                return Err(Error::Config(
                    "edge_fail requires a real tier (edges > 1)".into(),
                ));
            }
            if edge >= self.edges as u64 {
                return Err(Error::Config(format!(
                    "edge_fail edge {} out of range (edges = {})",
                    edge, self.edges
                )));
            }
            if !(t_s >= 0.0) || !t_s.is_finite() {
                return Err(Error::Config(
                    "edge_fail time must be finite and >= 0".into(),
                ));
            }
        }
        self.strategy.validate()?;
        self.policy.validate()
    }

    // -- JSON loading -----------------------------------------------------

    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let doc = Json::parse(text)?;
        let mut cfg = ScheduleConfig::default();
        if let Some(v) = doc.opt("name") {
            cfg.name = v.as_str()?.to_string();
        }
        if let Some(v) = doc.opt("policy") {
            cfg.policy = PolicyConfig::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.opt("strategy") {
            cfg.strategy = SchedStrategyConfig::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.opt("deadline_s") {
            cfg.deadline_s = Some(v.as_f64()?);
        }
        if let Some(v) = doc.opt("cohort_size") {
            cfg.cohort_size = v.as_usize()?;
        }
        if let Some(v) = doc.opt("population") {
            cfg.population = v.as_usize()?;
        }
        if let Some(v) = doc.opt("rounds") {
            cfg.rounds = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("epochs") {
            cfg.epochs = v.as_f64()? as i64;
        }
        if let Some(v) = doc.opt("steps_per_epoch") {
            cfg.steps_per_epoch = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("model_bytes") {
            cfg.model_bytes = v.as_usize()?;
        }
        if let Some(v) = doc.opt("device_mix") {
            cfg.device_mix = v
                .as_obj()?
                .iter()
                .map(|(name, w)| Ok((name.clone(), w.as_f64()?)))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = doc.opt("churn") {
            cfg.churn = Some(ChurnSpec {
                mean_on_s: v.get("mean_on_s")?.as_f64()?,
                mean_off_s: v.get("mean_off_s")?.as_f64()?,
            });
        }
        if let Some(v) = doc.opt("trace_file") {
            cfg.trace_file = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("scenario") {
            cfg.scenario = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("scenario_horizon_s") {
            cfg.scenario_horizon_s = v.as_f64()?;
        }
        if let Some(v) = doc.opt("seed") {
            cfg.seed = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("t_step_ref_s") {
            cfg.cost.t_step_ref_s = v.as_f64()?;
        }
        if let Some(v) = doc.opt("server_overhead_s") {
            cfg.cost.server_overhead_s = v.as_f64()?;
        }
        if let Some(v) = doc.opt("target_accuracy") {
            cfg.target_accuracy = Some(v.as_f64()?);
        }
        if let Some(v) = doc.opt("async_buffer") {
            cfg.async_buffer = Some(v.as_usize()?);
        }
        if let Some(v) = doc.opt("staleness_alpha") {
            cfg.staleness_alpha = v.as_f64()?;
        }
        if let Some(v) = doc.opt("max_concurrency") {
            cfg.max_concurrency = v.as_usize()?;
        }
        if let Some(v) = doc.opt("checkpoint_dir") {
            cfg.checkpoint_dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("checkpoint_every_rounds") {
            cfg.checkpoint_every_rounds = v.as_usize()? as u64;
        }
        if let Some(v) = doc.opt("resume_from") {
            cfg.resume_from = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("obs_out") {
            cfg.obs_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.opt("workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = doc.opt("edges") {
            cfg.edges = v.as_usize()?;
        }
        if let Some(v) = doc.opt("edge_assignment") {
            cfg.edge_assignment = EdgeAssignment::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.opt("edge_fail") {
            cfg.edge_fail = Some(parse_edge_fail(v.as_str()?)?);
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn builder_chains() {
        let cfg = ExperimentConfig::default()
            .named("t2a")
            .model("cifar_cnn")
            .clients(10)
            .rounds(40)
            .epochs(10)
            .lr(0.05)
            .devices(&["jetson_tx2_gpu"]);
        cfg.validate().unwrap();
        assert_eq!(cfg.epochs, 10);
    }

    #[test]
    fn effective_devices_defaults() {
        let cifar = ExperimentConfig::default().model("cifar_cnn");
        assert_eq!(cifar.effective_devices(), vec!["jetson_tx2_gpu"]);
        let head = ExperimentConfig::default().model("head");
        assert_eq!(head.effective_devices().len(), 5);
    }

    #[test]
    fn json_roundtrip_full() {
        let cfg = ExperimentConfig::from_json(
            r#"{
                "name": "table3",
                "model": "cifar_cnn",
                "num_clients": 10,
                "rounds": 40,
                "epochs": 10,
                "lr": 0.05,
                "partitioner": "dirichlet:0.5",
                "devices": ["jetson_tx2_cpu"],
                "strategy": {
                    "kind": "fedavg_cutoff",
                    "taus": {"jetson_tx2_cpu": 119.4}
                },
                "agg_backend": "rust",
                "t_step_ref_s": 0.01
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "table3");
        assert_eq!(cfg.partitioner, Partitioner::Dirichlet { alpha: 0.5 });
        assert_eq!(cfg.agg_backend, AggBackend::Rust);
        assert!(
            matches!(cfg.strategy, StrategyConfig::FedAvgCutoff { ref taus, .. } if taus[0].1 == 119.4)
        );
        assert_eq!(cfg.cost.t_step_ref_s, 0.01);
    }

    #[test]
    fn validation_catches_mistakes() {
        assert!(ExperimentConfig::default().clients(0).validate().is_err());
        assert!(ExperimentConfig::default().model("resnet152").validate().is_err());
        assert!(ExperimentConfig::default()
            .devices(&["nokia3310"])
            .validate()
            .is_err());
        assert!(ExperimentConfig::from_json(r#"{"agg_backend": "gpu"}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"strategy": {"kind": "sgd"}}"#).is_err());
    }

    #[test]
    fn policy_config_parses_all_forms() {
        assert_eq!(PolicyConfig::parse("uniform").unwrap(), PolicyConfig::Uniform);
        assert_eq!(PolicyConfig::parse("deadline").unwrap(), PolicyConfig::DeadlineAware);
        assert_eq!(
            PolicyConfig::parse("utility").unwrap(),
            PolicyConfig::UtilityBased { alpha: 2.0, explore_frac: 0.1 }
        );
        assert_eq!(
            PolicyConfig::parse("utility:3.5").unwrap(),
            PolicyConfig::UtilityBased { alpha: 3.5, explore_frac: 0.1 }
        );
        assert_eq!(
            PolicyConfig::parse("utility:1.0:0.25").unwrap(),
            PolicyConfig::UtilityBased { alpha: 1.0, explore_frac: 0.25 }
        );
        assert_eq!(
            PolicyConfig::parse("fair").unwrap(),
            PolicyConfig::FairnessCap { max_selections: 10 }
        );
        assert_eq!(
            PolicyConfig::parse("fair:3").unwrap(),
            PolicyConfig::FairnessCap { max_selections: 3 }
        );
        assert!(PolicyConfig::parse("oort").is_err());
        assert!(PolicyConfig::parse("utility:x").is_err());
        assert!(PolicyConfig::parse("utility:1:0.1:9").is_err());
        assert!(PolicyConfig::parse("fair:zero").is_err());
        assert!(PolicyConfig::FairnessCap { max_selections: 0 }.validate().is_err());
    }

    #[test]
    fn sched_strategy_parses_all_forms() {
        assert_eq!(
            SchedStrategyConfig::parse("fedavg").unwrap(),
            SchedStrategyConfig::FedAvg
        );
        assert_eq!(
            SchedStrategyConfig::parse("qfedavg").unwrap(),
            SchedStrategyConfig::QFedAvg { q: DEFAULT_QFEDAVG_Q }
        );
        assert_eq!(
            SchedStrategyConfig::parse("qfedavg:2.5").unwrap(),
            SchedStrategyConfig::QFedAvg { q: 2.5 }
        );
        assert_eq!(
            SchedStrategyConfig::parse("fedprox").unwrap(),
            SchedStrategyConfig::FedProx { mu: DEFAULT_FEDPROX_MU }
        );
        assert_eq!(
            SchedStrategyConfig::parse("fedprox:0.5").unwrap(),
            SchedStrategyConfig::FedProx { mu: 0.5 }
        );
        assert_eq!(
            SchedStrategyConfig::parse("compressed").unwrap(),
            SchedStrategyConfig::Compressed
        );
        assert_eq!(SchedStrategyConfig::parse("secagg").unwrap(), SchedStrategyConfig::SecAgg);
        // fedbuff is a mode, not a strategy — rejected with a hint
        let err = SchedStrategyConfig::parse("fedbuff").unwrap_err().to_string();
        assert!(err.contains("mode"), "{err}");
        assert!(SchedStrategyConfig::parse("qfedavg:x").is_err());
        assert!(SchedStrategyConfig::parse("fedprox:").is_err());
        assert!(SchedStrategyConfig::parse("dp-sgd").is_err());
        assert!(SchedStrategyConfig::QFedAvg { q: -1.0 }.validate().is_err());
        assert!(SchedStrategyConfig::FedProx { mu: f64::NAN }.validate().is_err());
        // labels round-trip through parse
        for s in [
            SchedStrategyConfig::FedAvg,
            SchedStrategyConfig::QFedAvg { q: 2.5 },
            SchedStrategyConfig::FedProx { mu: 0.5 },
            SchedStrategyConfig::Compressed,
            SchedStrategyConfig::SecAgg,
        ] {
            assert_eq!(SchedStrategyConfig::parse(&s.label()).unwrap(), s);
        }
        // JSON knob
        let cfg = ScheduleConfig::from_json(r#"{"strategy": "qfedavg:2"}"#).unwrap();
        assert_eq!(cfg.strategy, SchedStrategyConfig::QFedAvg { q: 2.0 });
        assert!(ScheduleConfig::from_json(r#"{"strategy": "fedbuff"}"#).is_err());
    }

    #[test]
    fn policy_labels_distinguish_variants() {
        let a = PolicyConfig::parse("utility:1.5").unwrap();
        let b = PolicyConfig::parse("utility:3").unwrap();
        assert_ne!(a.label(), b.label());
        assert_eq!(PolicyConfig::Uniform.label(), "uniform");
        assert_eq!(PolicyConfig::DeadlineAware.label(), "deadline");
    }

    #[test]
    fn schedule_default_is_valid() {
        ScheduleConfig::default().validate().unwrap();
    }

    #[test]
    fn schedule_json_roundtrip_full() {
        let cfg = ScheduleConfig::from_json(
            r#"{
                "name": "pop-exp",
                "policy": "utility:2.0:0.2",
                "deadline_s": 250.0,
                "cohort_size": 128,
                "population": 100000,
                "rounds": 25,
                "epochs": 10,
                "steps_per_epoch": 8,
                "model_bytes": 547496,
                "device_mix": {"pixel4": 3, "raspberry_pi4": 1},
                "churn": {"mean_on_s": 600, "mean_off_s": 300},
                "seed": 99,
                "t_step_ref_s": 1.48,
                "target_accuracy": 0.5,
                "workers": 4
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "pop-exp");
        assert_eq!(
            cfg.policy,
            PolicyConfig::UtilityBased { alpha: 2.0, explore_frac: 0.2 }
        );
        assert_eq!(cfg.deadline_s, Some(250.0));
        assert_eq!(cfg.cohort_size, 128);
        assert_eq!(cfg.population, 100_000);
        assert_eq!(cfg.device_mix.len(), 2);
        assert_eq!(
            cfg.churn,
            Some(crate::sched::availability::ChurnSpec {
                mean_on_s: 600.0,
                mean_off_s: 300.0
            })
        );
        assert_eq!(cfg.target_accuracy, Some(0.5));
        assert_eq!(cfg.workers, 4);
        assert!(ScheduleConfig::from_json(r#"{"workers": 0}"#).is_err());
    }

    #[test]
    fn async_knobs_roundtrip_and_validate() {
        let cfg = ExperimentConfig::from_json(
            r#"{"async_buffer": 8, "staleness_alpha": 0.5, "max_concurrency": 32}"#,
        )
        .unwrap();
        assert_eq!(cfg.async_buffer, Some(8));
        assert_eq!(cfg.staleness_alpha, 0.5);
        assert_eq!(cfg.max_concurrency, 32);
        assert!(ExperimentConfig::from_json(r#"{"async_buffer": 0}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"staleness_alpha": -1}"#).is_err());
        // the async loop now composes secagg/f16/fedprox/qfedavg adapters
        ExperimentConfig::from_json(r#"{"async_buffer": 4, "secure_agg": true}"#).unwrap();
        ExperimentConfig::from_json(r#"{"async_buffer": 4, "quantize_f16": true}"#).unwrap();
        ExperimentConfig::from_json(
            r#"{"async_buffer": 4, "strategy": {"kind": "fedprox", "mu": 0.1}}"#,
        )
        .unwrap();
        ExperimentConfig::from_json(
            r#"{"async_buffer": 4, "strategy": {"kind": "qfedavg", "q": 1.0}}"#,
        )
        .unwrap();
        assert!(
            ExperimentConfig::from_json(
                r#"{"async_buffer": 4, "strategy": {"kind": "fedavgm", "beta": 0.9}}"#
            )
            .is_err(),
            "momentum has no buffered-async adapter"
        );
        assert!(
            ExperimentConfig::from_json(
                r#"{"async_buffer": 4, "secure_agg": true,
                    "strategy": {"kind": "fedprox", "mu": 0.1}}"#
            )
            .is_err(),
            "secagg folds are unweighted — fedavg only"
        );
        assert!(
            ExperimentConfig::from_json(r#"{"async_buffer": 4, "fraction_fit": 0.5}"#).is_err()
        );

        let s = ScheduleConfig::from_json(
            r#"{"async_buffer": 8, "staleness_alpha": 1.5, "max_concurrency": 64}"#,
        )
        .unwrap();
        assert_eq!(s.async_buffer, Some(8));
        assert_eq!(s.staleness_alpha, 1.5);
        assert_eq!(s.effective_concurrency(), 64);
        assert_eq!(
            ScheduleConfig::default().cohort(24).effective_concurrency(),
            24,
            "max_concurrency 0 defaults to the cohort size"
        );
        assert!(ScheduleConfig::from_json(r#"{"async_buffer": 0}"#).is_err());
        assert!(ScheduleConfig::from_json(r#"{"staleness_alpha": -0.1}"#).is_err());
        // sync default stays valid and untouched
        assert_eq!(ScheduleConfig::default().async_buffer, None);
        ScheduleConfig::default().buffered(8).staleness(0.5).validate().unwrap();
    }

    #[test]
    fn checkpoint_knobs_roundtrip_both_configs() {
        let cfg = ExperimentConfig::from_json(
            r#"{"checkpoint_dir": "/tmp/ck", "checkpoint_every_rounds": 5, "resume_from": "/tmp/ck"}"#,
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_dir.as_deref(), Some("/tmp/ck"));
        assert_eq!(cfg.checkpoint_every_rounds, 5);
        assert_eq!(cfg.resume_from.as_deref(), Some("/tmp/ck"));

        let s = ScheduleConfig::from_json(
            r#"{"checkpoint_dir": "ckpts", "checkpoint_every_rounds": 2, "resume_from": "ckpts",
                "obs_out": "obs"}"#,
        )
        .unwrap();
        assert_eq!(s.checkpoint_dir.as_deref(), Some("ckpts"));
        assert_eq!(s.checkpoint_every_rounds, 2);
        assert_eq!(s.resume_from.as_deref(), Some("ckpts"));
        assert_eq!(s.obs_out.as_deref(), Some("obs"));
        assert_eq!(ScheduleConfig::default().obs_out, None);
        assert_eq!(ScheduleConfig::default().obs("o").obs_out.as_deref(), Some("o"));

        // builders mirror the JSON knobs; defaults stay off
        assert_eq!(ScheduleConfig::default().checkpoint_dir, None);
        let b = ScheduleConfig::default().checkpoints("d").checkpoint_every(3).resume("d");
        assert_eq!(b.checkpoint_dir.as_deref(), Some("d"));
        assert_eq!(b.checkpoint_every_rounds, 3);
        let e = ExperimentConfig::default().checkpoints("d").checkpoint_every(3).resume("d");
        assert_eq!(e.resume_from.as_deref(), Some("d"));
    }

    #[test]
    fn fingerprint_ignores_run_length_but_pins_trajectory_knobs() {
        let base = ScheduleConfig::default();
        // name / rounds / target / checkpoint knobs do not change identity
        assert_eq!(base.fingerprint(), base.clone().named("other").fingerprint());
        assert_eq!(base.fingerprint(), base.clone().rounds(99).fingerprint());
        let mut t = base.clone();
        t.target_accuracy = Some(0.9);
        assert_eq!(base.fingerprint(), t.fingerprint());
        assert_eq!(
            base.fingerprint(),
            base.clone().checkpoints("x").checkpoint_every(7).resume("y").fingerprint()
        );
        // observability never changes trajectory identity
        assert_eq!(base.fingerprint(), base.clone().obs("obs-dir").fingerprint());
        // worker count is an execution knob, not an identity knob
        assert_eq!(base.fingerprint(), base.clone().workers(8).fingerprint());
        // the two-tier-topology era is a new fingerprint namespace
        assert!(base.fingerprint().starts_with("schedule-v4:"));
        // the topology is a trajectory knob (fold grouping + wire bytes)
        assert_ne!(base.fingerprint(), base.clone().edges(2).fingerprint());
        assert_ne!(
            base.clone().edges(2).fingerprint(),
            base.clone().edges(2).edge_assignment(EdgeAssignment::Skew).fingerprint()
        );
        assert_ne!(
            base.clone().edges(2).fingerprint(),
            base.clone().edges(2).edge_fail(0, 100.0).fingerprint()
        );
        // the strategy is a trajectory knob (fold weights + wire bytes)
        assert_ne!(
            base.fingerprint(),
            base.clone()
                .strategy(SchedStrategyConfig::QFedAvg { q: 1.0 })
                .fingerprint()
        );
        assert_ne!(
            base.clone()
                .strategy(SchedStrategyConfig::QFedAvg { q: 1.0 })
                .fingerprint(),
            base.clone()
                .strategy(SchedStrategyConfig::QFedAvg { q: 2.0 })
                .fingerprint()
        );
        assert_ne!(
            base.fingerprint(),
            base.clone().strategy(SchedStrategyConfig::SecAgg).fingerprint()
        );
        // everything trajectory-relevant does
        assert_ne!(base.fingerprint(), base.clone().seed(1).fingerprint());
        assert_ne!(base.fingerprint(), base.clone().cohort(7).fingerprint());
        assert_ne!(base.fingerprint(), base.clone().population(7).fingerprint());
        assert_ne!(base.fingerprint(), base.clone().buffered(4).fingerprint());
        assert_ne!(
            base.fingerprint(),
            base.clone().policy(PolicyConfig::DeadlineAware).fingerprint()
        );
        assert_ne!(
            base.fingerprint(),
            base.clone()
                .churn(Some(crate::sched::availability::ChurnSpec {
                    mean_on_s: 1.0,
                    mean_off_s: 1.0
                }))
                .fingerprint()
        );
    }

    #[test]
    fn edge_knobs_parse_and_validate() {
        assert_eq!(
            EdgeAssignment::parse_edges("4").unwrap(),
            (4, EdgeAssignment::RoundRobin)
        );
        assert_eq!(
            EdgeAssignment::parse_edges("2:skew").unwrap(),
            (2, EdgeAssignment::Skew)
        );
        assert!(EdgeAssignment::parse_edges("2:zigzag").is_err());
        assert!(EdgeAssignment::parse_edges("many").is_err());
        assert_eq!(parse_edge_fail("1@120.5").unwrap(), (1, 120.5));
        assert!(parse_edge_fail("120.5").is_err());
        assert!(parse_edge_fail("x@y").is_err());

        let base = ScheduleConfig::default().population(100).cohort(10);
        base.clone().edges(2).validate().unwrap();
        base.clone().edges(4).edge_fail(3, 60.0).validate().unwrap();
        assert!(base.clone().edges(0).validate().is_err());
        assert!(base.clone().edges(101).validate().is_err());
        // failing an edge needs a real tier, and an existing edge
        assert!(base.clone().edge_fail(0, 60.0).validate().is_err());
        assert!(base.clone().edges(2).edge_fail(2, 60.0).validate().is_err());
        assert!(base.clone().edges(2).edge_fail(0, f64::NAN).validate().is_err());

        let cfg = ScheduleConfig::from_json(
            r#"{"population": 24, "cohort_size": 8, "edges": 2,
                "edge_assignment": "skew", "edge_fail": "0@90"}"#,
        )
        .unwrap();
        assert_eq!(cfg.edges, 2);
        assert_eq!(cfg.edge_assignment, EdgeAssignment::Skew);
        assert_eq!(cfg.edge_fail, Some((0, 90.0)));
    }

    #[test]
    fn trace_and_scenario_knobs_roundtrip_and_validate() {
        let s = ScheduleConfig::from_json(
            r#"{"scenario": "diurnal", "scenario_horizon_s": 86400, "population": 500}"#,
        )
        .unwrap();
        assert_eq!(s.scenario.as_deref(), Some("diurnal"));
        assert_eq!(s.scenario_horizon_s, 86_400.0);
        let t = ScheduleConfig::from_json(r#"{"trace_file": "traces/pop.csv"}"#).unwrap();
        assert_eq!(t.trace_file.as_deref(), Some("traces/pop.csv"));

        // builders mirror the JSON knobs
        let b = ScheduleConfig::default()
            .scenario("flash-crowd")
            .scenario_horizon(3_600.0);
        assert_eq!(b.scenario.as_deref(), Some("flash-crowd"));
        assert_eq!(b.scenario_horizon_s, 3_600.0);
        b.validate().unwrap();
        ScheduleConfig::default()
            .trace_file("x.csv")
            .validate()
            .unwrap();

        // unknown scenario name
        assert!(ScheduleConfig::from_json(r#"{"scenario": "weekend"}"#).is_err());
        // trace_file + scenario, and either + churn, are exclusive
        assert!(ScheduleConfig::default()
            .trace_file("x.csv")
            .scenario("diurnal")
            .validate()
            .is_err());
        assert!(ScheduleConfig::default()
            .scenario("diurnal")
            .churn(Some(crate::sched::availability::ChurnSpec {
                mean_on_s: 1.0,
                mean_off_s: 1.0
            }))
            .validate()
            .is_err());
        assert!(ScheduleConfig::default()
            .trace_file("x.csv")
            .churn(Some(crate::sched::availability::ChurnSpec {
                mean_on_s: 1.0,
                mean_off_s: 1.0
            }))
            .validate()
            .is_err());
        // broken horizon
        assert!(ScheduleConfig::from_json(r#"{"scenario_horizon_s": 0}"#).is_err());
        assert!(ScheduleConfig::from_json(r#"{"scenario_horizon_s": -5}"#).is_err());
    }

    #[test]
    fn fingerprint_pins_trace_and_scenario_knobs() {
        let base = ScheduleConfig::default();
        assert_ne!(
            base.fingerprint(),
            base.clone().scenario("diurnal").fingerprint()
        );
        assert_ne!(
            base.clone().scenario("diurnal").fingerprint(),
            base.clone().scenario("flash-crowd").fingerprint()
        );
        assert_ne!(
            base.fingerprint(),
            base.clone().trace_file("x.csv").fingerprint()
        );
        assert_ne!(
            base.clone().scenario("diurnal").fingerprint(),
            base.clone()
                .scenario("diurnal")
                .scenario_horizon(3_600.0)
                .fingerprint()
        );
    }

    #[test]
    fn schedule_validation_catches_mistakes() {
        assert!(ScheduleConfig::default().population(0).validate().is_err());
        assert!(ScheduleConfig::default().cohort(0).validate().is_err());
        assert!(ScheduleConfig::default()
            .population(10)
            .cohort(11)
            .validate()
            .is_err());
        assert!(ScheduleConfig::default().deadline(Some(-1.0)).validate().is_err());
        let mut bad_mix = ScheduleConfig::default();
        bad_mix.device_mix = vec![("nokia3310".into(), 1.0)];
        assert!(bad_mix.validate().is_err());
        let mut bad_w = ScheduleConfig::default();
        bad_w.device_mix = vec![("pixel4".into(), 0.0)];
        assert!(bad_w.validate().is_err());
        assert!(ScheduleConfig::from_json(r#"{"policy": "magic"}"#).is_err());
        assert!(ScheduleConfig::from_json(r#"{"churn": {"mean_on_s": -5, "mean_off_s": 1}}"#)
            .is_err());
    }
}
