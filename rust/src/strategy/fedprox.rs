//! FedProx (Li et al. 2018): FedAvg plus a proximal term μ/2‖w−w_global‖²
//! in the local objective, tolerant of partial work. The paper positions
//! its τ-cutoff as having "parallels with the FedProx algorithm which also
//! accepts partial results from clients" — this implementation lets the
//! benches compare the two directly.

use crate::client::keys;
use crate::error::Result;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters, Scalar};

use super::{AsyncStrategy, ClientHandle, EvalSummary, FedAvg, FedBuff, Strategy};

/// FedAvg + proximal local objective (clients use the `*_train_prox`
/// artifact when `prox_mu > 0`).
pub struct FedProx {
    pub inner: FedAvg,
    pub mu: f64,
}

impl FedProx {
    pub fn new(inner: FedAvg, mu: f64) -> Self {
        FedProx { inner, mu }
    }
}

impl Strategy for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let mut plan = self.inner.configure_fit(round, parameters, cohort);
        for (_, ins) in &mut plan {
            ins.config.insert(keys::PROX_MU.into(), Scalar::F64(self.mu));
        }
        plan
    }

    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters> {
        self.inner.aggregate_fit(round, results, failures)
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

/// Proximal local objective for the buffered-asynchronous loop: FedBuff
/// aggregation (a proximal term changes the *client's* objective, not
/// the server's fold weights) with `prox_mu` riding on every fit
/// config. At `mu = 0` clients run plain SGD and the flush is
/// bit-identical to FedBuff.
pub struct FedProxBuff {
    pub inner: FedBuff,
    pub mu: f64,
}

impl FedProxBuff {
    pub fn new(inner: FedBuff, mu: f64) -> Self {
        FedProxBuff { inner, mu }
    }
}

impl AsyncStrategy for FedProxBuff {
    fn name(&self) -> &'static str {
        "fedprox_async"
    }

    fn buffer_size(&self) -> usize {
        self.inner.buffer_size()
    }

    fn configure_fit(
        &mut self,
        version: u64,
        parameters: &Parameters,
        handle: &ClientHandle,
    ) -> FitIns {
        let mut ins = self.inner.configure_fit(version, parameters, handle);
        ins.config.insert(keys::PROX_MU.into(), Scalar::F64(self.mu));
        ins
    }

    fn on_fit_result(
        &mut self,
        handle: &ClientHandle,
        staleness: u64,
        res: FitRes,
    ) -> Result<Option<Parameters>> {
        self.inner.on_fit_result(handle, staleness, res)
    }

    fn flush(&mut self) -> Result<Option<Parameters>> {
        self.inner.flush()
    }

    fn configure_evaluate(
        &mut self,
        version: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(version, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        version: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(version, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator};
    use super::*;
    use crate::proto::scalar::ConfigExt;

    #[test]
    fn mu_rides_on_config() {
        let mut s = FedProx::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            0.01,
        );
        let cohort = handles(3);
        let plan = s.configure_fit(2, &Parameters::from_flat(vec![0.0]), &cohort);
        assert_eq!(plan.len(), 3);
        for (_, ins) in &plan {
            assert_eq!(ins.config.get_f64(keys::PROX_MU).unwrap(), 0.01);
            assert_eq!(ins.config.get_i64(keys::ROUND).unwrap(), 2);
        }
    }

    #[test]
    fn async_mu_rides_on_config_and_aggregates_like_fedbuff() {
        let mut s = FedProxBuff::new(
            FedBuff::new(TrainingPlan::default(), Aggregator::Rust, 2),
            0.1,
        );
        assert_eq!(s.buffer_size(), 2);
        let h = handles(2);
        let ins = s.configure_fit(3, &Parameters::from_flat(vec![0.0]), &h[0]);
        assert_eq!(ins.config.get_f64(keys::PROX_MU).unwrap(), 0.1);
        assert!(s
            .on_fit_result(&h[0], 0, fit_res(vec![1.0], 10, 1.0))
            .unwrap()
            .is_none());
        let p = s
            .on_fit_result(&h[1], 0, fit_res(vec![3.0], 10, 1.0))
            .unwrap()
            .unwrap();
        assert_eq!(p.to_flat().unwrap(), &[2.0]);
    }
}
