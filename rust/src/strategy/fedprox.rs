//! FedProx (Li et al. 2018): FedAvg plus a proximal term μ/2‖w−w_global‖²
//! in the local objective, tolerant of partial work. The paper positions
//! its τ-cutoff as having "parallels with the FedProx algorithm which also
//! accepts partial results from clients" — this implementation lets the
//! benches compare the two directly.

use crate::client::keys;
use crate::error::Result;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters, Scalar};

use super::{ClientHandle, EvalSummary, FedAvg, Strategy};

/// FedAvg + proximal local objective (clients use the `*_train_prox`
/// artifact when `prox_mu > 0`).
pub struct FedProx {
    pub inner: FedAvg,
    pub mu: f64,
}

impl FedProx {
    pub fn new(inner: FedAvg, mu: f64) -> Self {
        FedProx { inner, mu }
    }
}

impl Strategy for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let mut plan = self.inner.configure_fit(round, parameters, cohort);
        for (_, ins) in &mut plan {
            ins.config.insert(keys::PROX_MU.into(), Scalar::F64(self.mu));
        }
        plan
    }

    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters> {
        self.inner.aggregate_fit(round, results, failures)
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator};
    use super::*;
    use crate::proto::scalar::ConfigExt;

    #[test]
    fn mu_rides_on_config() {
        let mut s = FedProx::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            0.01,
        );
        let cohort = handles(3);
        let plan = s.configure_fit(2, &Parameters::from_flat(vec![0.0]), &cohort);
        assert_eq!(plan.len(), 3);
        for (_, ins) in &plan {
            assert_eq!(ins.config.get_f64(keys::PROX_MU).unwrap(), 0.01);
            assert_eq!(ins.config.get_i64(keys::ROUND).unwrap(), 2);
        }
    }
}
