//! FedAvgM: server-side momentum over the aggregated pseudo-gradient
//! (Hsu et al. 2019). An ablation strategy: shows how the coordinator's
//! Strategy abstraction hosts server-state-carrying algorithms.

use crate::error::{Error, Result};
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters};

use super::{ClientHandle, EvalSummary, FedAvg, Strategy};

/// FedAvg + server momentum:
/// ```text
/// Δ_t = avg(w_clients) − w_{t}
/// v_t = β·v_{t−1} + Δ_t
/// w_{t+1} = w_t + η_server · v_t
/// ```
pub struct FedAvgM {
    pub inner: FedAvg,
    pub beta: f64,
    pub server_lr: f64,
    velocity: Vec<f64>,
    /// global params snapshot taken at configure_fit
    current: Vec<f32>,
}

impl FedAvgM {
    pub fn new(inner: FedAvg, beta: f64, server_lr: f64) -> Self {
        FedAvgM { inner, beta, server_lr, velocity: Vec::new(), current: Vec::new() }
    }
}

impl Strategy for FedAvgM {
    fn name(&self) -> &'static str {
        "fedavgm"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        self.current = parameters
            .to_flat()
            .map(<[f32]>::to_vec)
            .unwrap_or_default();
        self.inner.configure_fit(round, parameters, cohort)
    }

    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters> {
        let avg = self.inner.aggregate_fit(round, results, failures)?;
        let avg = avg.to_flat()?;
        if self.current.len() != avg.len() {
            return Err(Error::Aggregation(
                "FedAvgM: configure_fit was not called before aggregate_fit".into(),
            ));
        }
        if self.velocity.len() != avg.len() {
            self.velocity = vec![0f64; avg.len()];
        }
        let mut new = Vec::with_capacity(avg.len());
        for i in 0..avg.len() {
            let delta = avg[i] as f64 - self.current[i] as f64;
            self.velocity[i] = self.beta * self.velocity[i] + delta;
            new.push((self.current[i] as f64 + self.server_lr * self.velocity[i]) as f32);
        }
        Ok(Parameters::from_flat(new))
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator};
    use super::*;

    fn strategy(beta: f64, server_lr: f64) -> FedAvgM {
        FedAvgM::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            beta,
            server_lr,
        )
    }

    #[test]
    fn beta_zero_lr_one_equals_fedavg() {
        let mut s = strategy(0.0, 1.0);
        let cohort = handles(2);
        let global = Parameters::from_flat(vec![0.0, 0.0]);
        s.configure_fit(1, &global, &cohort);
        let results = vec![
            (cohort[0].clone(), fit_res(vec![1.0, 2.0], 100, 1.0)),
            (cohort[1].clone(), fit_res(vec![3.0, 4.0], 100, 1.0)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert_eq!(p.to_flat().unwrap(), &[2.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates_across_rounds() {
        let mut s = strategy(0.9, 1.0);
        let cohort = handles(1);
        let mut global = Parameters::from_flat(vec![0.0]);
        // each round the client reports global+1
        for round in 1..=3 {
            s.configure_fit(round, &global, &cohort);
            let client_w = global.to_flat().unwrap()[0] + 1.0;
            let results = vec![(cohort[0].clone(), fit_res(vec![client_w], 10, 1.0))];
            global = s.aggregate_fit(round, &results, 0).unwrap();
        }
        // with momentum the cumulative step exceeds the 3.0 of plain FedAvg
        assert!(global.to_flat().unwrap()[0] > 3.0);
    }

    #[test]
    fn aggregate_without_configure_errors() {
        let mut s = strategy(0.9, 1.0);
        let cohort = handles(1);
        let results = vec![(cohort[0].clone(), fit_res(vec![1.0], 10, 1.0))];
        assert!(s.aggregate_fit(1, &results, 0).is_err());
    }
}
