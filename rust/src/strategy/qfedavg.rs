//! q-FedAvg-style fairness reweighting (Li et al. 2020, simplified):
//! clients with higher local loss get up-weighted by (loss + ε)^q, pushing
//! the global model toward uniform per-client performance. q = 0 recovers
//! plain FedAvg. Included as an ablation strategy for the benches.

use crate::client::keys;
use crate::error::Result;
use crate::proto::scalar::ConfigExt;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters};

use super::{ClientHandle, EvalSummary, FedAvg, Strategy};

/// FedAvg with loss-skewed aggregation weights.
pub struct QFedAvg {
    pub inner: FedAvg,
    pub q: f64,
}

const EPS: f64 = 1e-10;

impl QFedAvg {
    pub fn new(inner: FedAvg, q: f64) -> Self {
        QFedAvg { inner, q }
    }
}

impl Strategy for QFedAvg {
    fn name(&self) -> &'static str {
        "qfedavg"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        self.inner.configure_fit(round, parameters, cohort)
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        results: &[(ClientHandle, FitRes)],
        _failures: usize,
    ) -> Result<Parameters> {
        let q = self.q;
        self.inner.average(results, |_, res| {
            let loss = res.metrics.get_f64_or(keys::TRAIN_LOSS, 1.0).max(0.0);
            res.num_examples as f64 * (loss + EPS).powf(q)
        })
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator};
    use super::*;

    #[test]
    fn q_zero_matches_fedavg() {
        let mut s = QFedAvg::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            0.0,
        );
        let h = handles(2);
        let results = vec![
            (h[0].clone(), fit_res(vec![0.0], 100, 5.0)),
            (h[1].clone(), fit_res(vec![1.0], 300, 0.1)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert!((p.to_flat().unwrap()[0] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn higher_loss_gets_more_weight() {
        let mut s = QFedAvg::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            2.0,
        );
        let h = handles(2);
        // equal examples; client 1 has much higher loss and params=1.0
        let results = vec![
            (h[0].clone(), fit_res(vec![0.0], 100, 0.1)),
            (h[1].clone(), fit_res(vec![1.0], 100, 10.0)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert!(p.to_flat().unwrap()[0] > 0.99, "got {:?}", p.to_flat());
    }
}
