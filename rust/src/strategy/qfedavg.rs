//! q-FedAvg-style fairness reweighting (Li et al. 2020, simplified):
//! clients with higher local loss get up-weighted by (loss + ε)^q, pushing
//! the global model toward uniform per-client performance. q = 0 recovers
//! plain FedAvg. Included as an ablation strategy for the benches.

use crate::client::keys;
use crate::error::Result;
use crate::proto::scalar::ConfigExt;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters};

use super::fedavg::{weighted_parameter_average, TrainingPlan};
use super::fedbuff::staleness_weight;
use super::{
    weighted_eval_summary, Aggregator, AsyncStrategy, ClientHandle, EvalSummary, FedAvg, Strategy,
};

/// FedAvg with loss-skewed aggregation weights.
pub struct QFedAvg {
    pub inner: FedAvg,
    pub q: f64,
}

/// Loss floor added before exponentiation so `0^q` never collapses a
/// client's weight to zero. Public: the population-scale engine's
/// q-fair fold weights must use the identical constant
/// (`sched::engine::Engine::fold_weights`).
pub const EPS: f64 = 1e-10;

impl QFedAvg {
    pub fn new(inner: FedAvg, q: f64) -> Self {
        QFedAvg { inner, q }
    }
}

impl Strategy for QFedAvg {
    fn name(&self) -> &'static str {
        "qfedavg"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        self.inner.configure_fit(round, parameters, cohort)
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        results: &[(ClientHandle, FitRes)],
        _failures: usize,
    ) -> Result<Parameters> {
        let q = self.q;
        self.inner.average(results, |_, res| {
            let loss = res.metrics.get_f64_or(keys::TRAIN_LOSS, 1.0).max(0.0);
            res.num_examples as f64 * (loss + EPS).powf(q)
        })
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

/// q-fair aggregation for the buffered-asynchronous loop: FedBuff
/// mechanics (K-buffer, polynomial staleness discount) with each fold's
/// weight further scaled by `(loss + ε)^q`. At `q = 0` the extra factor
/// is `powf(_, 0) = 1.0` exactly, so the flush is **bit-identical** to
/// FedBuff (property-locked in `rust/tests/strategy_props.rs`).
pub struct QFedAvgBuff {
    pub plan: TrainingPlan,
    pub buffer_size: usize,
    /// Polynomial staleness exponent (0 = no discount).
    pub alpha: f64,
    pub q: f64,
    aggregator: Aggregator,
    buffer: Vec<(u64, FitRes)>,
}

impl QFedAvgBuff {
    pub fn new(plan: TrainingPlan, aggregator: Aggregator, buffer_size: usize, q: f64) -> Self {
        QFedAvgBuff {
            plan,
            buffer_size: buffer_size.max(1),
            alpha: super::fedbuff::DEFAULT_STALENESS_ALPHA,
            q,
            aggregator,
            buffer: Vec::new(),
        }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    /// Results currently waiting in the buffer.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    fn flush_buffer(&mut self) -> Result<Option<Parameters>> {
        if self.buffer.is_empty() {
            return Ok(None);
        }
        let (alpha, q) = (self.alpha, self.q);
        let params = weighted_parameter_average(
            &self.aggregator,
            self.buffer.iter().map(|(s, r)| {
                let loss = r.metrics.get_f64_or(keys::TRAIN_LOSS, 1.0).max(0.0);
                (
                    r,
                    staleness_weight(r.num_examples, *s, alpha) * (loss + EPS).powf(q),
                )
            }),
        )?;
        self.buffer.clear();
        Ok(Some(params))
    }
}

impl AsyncStrategy for QFedAvgBuff {
    fn name(&self) -> &'static str {
        "qfedavg_async"
    }

    fn buffer_size(&self) -> usize {
        self.buffer_size
    }

    fn configure_fit(
        &mut self,
        version: u64,
        parameters: &Parameters,
        _handle: &ClientHandle,
    ) -> FitIns {
        FitIns {
            parameters: parameters.clone(),
            config: self.plan.to_config(version),
        }
    }

    fn on_fit_result(
        &mut self,
        _handle: &ClientHandle,
        staleness: u64,
        res: FitRes,
    ) -> Result<Option<Parameters>> {
        if !res.status.is_ok() || res.num_examples == 0 {
            return Ok(None);
        }
        self.buffer.push((staleness, res));
        if self.buffer.len() >= self.buffer_size {
            self.flush_buffer()
        } else {
            Ok(None)
        }
    }

    fn flush(&mut self) -> Result<Option<Parameters>> {
        self.flush_buffer()
    }

    fn configure_evaluate(
        &mut self,
        version: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        let config = crate::config! { keys::ROUND => version as i64 };
        (0..cohort.len())
            .map(|idx| {
                (
                    idx,
                    EvaluateIns { parameters: parameters.clone(), config: config.clone() },
                )
            })
            .collect()
    }

    fn aggregate_evaluate(
        &mut self,
        _version: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        weighted_eval_summary(results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::FedBuff;
    use super::*;

    #[test]
    fn q_zero_matches_fedavg() {
        let mut s = QFedAvg::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            0.0,
        );
        let h = handles(2);
        let results = vec![
            (h[0].clone(), fit_res(vec![0.0], 100, 5.0)),
            (h[1].clone(), fit_res(vec![1.0], 300, 0.1)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert!((p.to_flat().unwrap()[0] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn async_q_zero_matches_fedbuff_bit_exactly() {
        let mk_results = || {
            vec![
                (0u64, fit_res(vec![0.125, 4.0], 100, 5.0)),
                (2u64, fit_res(vec![1.5, -2.25], 300, 0.1)),
                (1u64, fit_res(vec![-0.75, 8.5], 50, 2.0)),
            ]
        };
        let h = handles(3);
        let mut qf = QFedAvgBuff::new(TrainingPlan::default(), Aggregator::Rust, 3, 0.0);
        let mut fb = FedBuff::new(TrainingPlan::default(), Aggregator::Rust, 3);
        let (mut got_q, mut got_f) = (None, None);
        for (i, (s, r)) in mk_results().into_iter().enumerate() {
            got_q = qf.on_fit_result(&h[i], s, r).unwrap();
        }
        for (i, (s, r)) in mk_results().into_iter().enumerate() {
            got_f = fb.on_fit_result(&h[i], s, r).unwrap();
        }
        let (q, f) = (got_q.unwrap(), got_f.unwrap());
        let (q, f) = (q.to_flat().unwrap(), f.to_flat().unwrap());
        let qb: Vec<u32> = q.iter().map(|x| x.to_bits()).collect();
        let fb_: Vec<u32> = f.iter().map(|x| x.to_bits()).collect();
        assert_eq!(qb, fb_);
    }

    #[test]
    fn async_higher_loss_gets_more_weight() {
        let mut s = QFedAvgBuff::new(TrainingPlan::default(), Aggregator::Rust, 2, 2.0);
        let h = handles(2);
        assert!(s
            .on_fit_result(&h[0], 0, fit_res(vec![0.0], 100, 0.1))
            .unwrap()
            .is_none());
        let p = s
            .on_fit_result(&h[1], 0, fit_res(vec![1.0], 100, 10.0))
            .unwrap()
            .unwrap();
        assert!(p.to_flat().unwrap()[0] > 0.99);
    }

    #[test]
    fn higher_loss_gets_more_weight() {
        let mut s = QFedAvg::new(
            FedAvg::new(TrainingPlan::default(), Aggregator::Rust),
            2.0,
        );
        let h = handles(2);
        // equal examples; client 1 has much higher loss and params=1.0
        let results = vec![
            (h[0].clone(), fit_res(vec![0.0], 100, 0.1)),
            (h[1].clone(), fit_res(vec![1.0], 100, 10.0)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert!(p.to_flat().unwrap()[0] > 0.99, "got {:?}", p.to_flat());
    }
}
