//! FedAvgCutoff — the paper's contribution (§5, Table 3).
//!
//! "We implement a modified version of FedAvg where each client device is
//! assigned a cutoff time (τ) after which it must send its model
//! parameters to the server, irrespective of whether it has finished its
//! local epochs or not. ... the key advantage of using Flower is that we
//! can compute and assign a *processor-specific* cutoff time for each
//! client."
//!
//! The strategy wraps [`FedAvg`] and injects a per-device `cutoff_s`
//! config key; the trainer stops once the modeled device compute time
//! crosses τ and returns the partial update, which aggregation weights by
//! the examples actually processed.

use std::collections::BTreeMap;

use crate::client::keys;
use crate::error::Result;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters, Scalar};

use super::{ClientHandle, EvalSummary, FedAvg, Strategy};

/// FedAvg + per-processor τ cutoffs.
pub struct FedAvgCutoff {
    pub inner: FedAvg,
    /// Device-profile name → τ in seconds of modeled compute time.
    taus: BTreeMap<String, f64>,
    /// Fallback τ for devices not in the map (None = no cutoff).
    default_tau_s: Option<f64>,
}

impl FedAvgCutoff {
    pub fn new(inner: FedAvg) -> Self {
        FedAvgCutoff { inner, taus: BTreeMap::new(), default_tau_s: None }
    }

    /// Assign τ (seconds) for one device profile.
    pub fn with_tau(mut self, device: &str, tau_s: f64) -> Self {
        self.taus.insert(device.to_string(), tau_s);
        self
    }

    /// Assign a τ for every device without an explicit entry.
    pub fn with_default_tau(mut self, tau_s: f64) -> Self {
        self.default_tau_s = Some(tau_s);
        self
    }

    fn tau_for(&self, device: &str) -> Option<f64> {
        self.taus.get(device).copied().or(self.default_tau_s)
    }
}

impl Strategy for FedAvgCutoff {
    fn name(&self) -> &'static str {
        "fedavg_cutoff"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let mut plan = self.inner.configure_fit(round, parameters, cohort);
        for (idx, ins) in &mut plan {
            if let Some(tau) = self.tau_for(cohort[*idx].device.name) {
                ins.config
                    .insert(keys::CUTOFF_S.into(), Scalar::F64(tau));
            }
        }
        plan
    }

    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters> {
        // Partial results are first-class: weighting by examples processed
        // (inner FedAvg behavior) is exactly what makes truncation safe.
        self.inner.aggregate_fit(round, results, failures)
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator};
    use super::*;
    use crate::device::profiles;
    use crate::proto::scalar::ConfigExt;

    fn cutoff_strategy() -> FedAvgCutoff {
        FedAvgCutoff::new(FedAvg::new(
            TrainingPlan { epochs: 10, lr: 0.05 },
            Aggregator::Rust,
        ))
        .with_tau("jetson_tx2_cpu", 1.99 * 60.0)
    }

    #[test]
    fn injects_tau_only_for_mapped_devices() {
        let mut s = cutoff_strategy();
        let mut cohort = handles(2);
        cohort[1].device = profiles::by_name("jetson_tx2_cpu").unwrap();
        let plan = s.configure_fit(1, &Parameters::from_flat(vec![0.0]), &cohort);
        let by_idx: std::collections::BTreeMap<usize, &FitIns> =
            plan.iter().map(|(i, ins)| (*i, ins)).collect();
        // GPU client: no cutoff key
        assert!(by_idx[&0].config.get(keys::CUTOFF_S).is_none());
        // CPU client: τ = 1.99 min
        assert!(
            (by_idx[&1].config.get_f64(keys::CUTOFF_S).unwrap() - 119.4).abs() < 1e-9
        );
    }

    #[test]
    fn default_tau_applies_everywhere() {
        let mut s = cutoff_strategy().with_default_tau(60.0);
        let cohort = handles(3); // all TX2 GPU
        let plan = s.configure_fit(1, &Parameters::from_flat(vec![0.0]), &cohort);
        for (_, ins) in &plan {
            assert_eq!(ins.config.get_f64(keys::CUTOFF_S).unwrap(), 60.0);
        }
    }

    #[test]
    fn partial_results_weighted_by_examples() {
        let mut s = cutoff_strategy();
        let h = handles(2);
        // client 0 finished 80 steps (2560 ex), client 1 was cut at 63 (2016 ex)
        let results = vec![
            (h[0].clone(), fit_res(vec![1.0], 2560, 1.0)),
            (h[1].clone(), fit_res(vec![0.0], 2016, 1.0)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        let got = p.to_flat().unwrap()[0];
        let want = 2560.0 / (2560.0 + 2016.0);
        assert!((got - want as f32).abs() < 1e-6);
    }
}
