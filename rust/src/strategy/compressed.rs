//! Communication compression: f16-quantized parameter exchange.
//!
//! FL's dominant system cost besides compute is moving the model (the
//! paper's §2 cites communication-efficiency as FedAvg's original
//! motivation). `QuantizedComm` wraps any inner strategy and:
//!
//! * quantizes outgoing global parameters (FitIns/EvaluateIns) to IEEE
//!   binary16 — half the downlink bytes;
//! * asks clients (via the `quantize` config key) to quantize their
//!   updates — half the uplink bytes;
//! * dequantizes client results before delegating aggregation to the
//!   inner strategy, which keeps full f32 precision server-side.
//!
//! The comm-cost model sees the smaller payloads automatically (byte
//! accounting follows tensor dtype), so the time/energy savings show up
//! in the history without further plumbing.
//!
//! Quantization can fail (e.g. a non-float tensor in the parameter set).
//! When it does, the wrapper ships the original f32 payload and — for
//! fit — **omits** the `quantize` config flag, warning through
//! `telemetry::log`: flag and payload must agree, or clients would
//! halve their uplink while the cost model books full-size downlinks
//! that were never compressed.

use crate::client::keys;
use crate::error::Result;
use crate::telemetry::log;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters, Scalar};

use super::{AsyncStrategy, ClientHandle, EvalSummary, Strategy};

/// Wraps a strategy with f16 wire compression in both directions.
pub struct QuantizedComm {
    inner: Box<dyn Strategy>,
}

impl QuantizedComm {
    pub fn new(inner: Box<dyn Strategy>) -> Self {
        QuantizedComm { inner }
    }
}

impl Strategy for QuantizedComm {
    fn name(&self) -> &'static str {
        "quantized_comm"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let mut plan = self.inner.configure_fit(round, parameters, cohort);
        for (id, ins) in &mut plan {
            match ins.parameters.quantize_f16() {
                Ok(q) => {
                    ins.parameters = q;
                    // Flag only what was actually quantized: the flag asks
                    // the client to f16 its uplink and tells the cost
                    // model the downlink was halved.
                    ins.config
                        .insert(keys::QUANTIZE.into(), Scalar::Str("f16".into()));
                }
                Err(e) => log::warn(&format!(
                    "quantized_comm: fit round {round} client {id}: \
                     f16 quantization failed ({e}); sending f32 unflagged"
                )),
            }
        }
        plan
    }

    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters> {
        // Dequantize client updates so the inner strategy aggregates in f32.
        let dequantized: Vec<(ClientHandle, FitRes)> = results
            .iter()
            .map(|(h, res)| {
                let mut res = res.clone();
                if let Ok(flat) = res.parameters.to_flat_vec() {
                    res.parameters = Parameters::from_flat(flat);
                }
                (h.clone(), res)
            })
            .collect();
        self.inner.aggregate_fit(round, &dequantized, failures)
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        let mut plan = self.inner.configure_evaluate(round, parameters, cohort);
        for (id, ins) in &mut plan {
            match ins.parameters.quantize_f16() {
                Ok(q) => ins.parameters = q,
                Err(e) => log::warn(&format!(
                    "quantized_comm: evaluate round {round} client {id}: \
                     f16 quantization failed ({e}); sending f32"
                )),
            }
        }
        plan
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

/// f16 wire compression for the buffered-asynchronous loop: wraps any
/// [`AsyncStrategy`] (FedBuff, the q-fair/proximal adapters, …) with the
/// same downlink-quantize / uplink-dequantize rules as [`QuantizedComm`],
/// including the failure-path rule that flag and payload must agree.
pub struct QuantizedCommAsync {
    inner: Box<dyn AsyncStrategy>,
}

impl QuantizedCommAsync {
    pub fn new(inner: Box<dyn AsyncStrategy>) -> Self {
        QuantizedCommAsync { inner }
    }
}

impl AsyncStrategy for QuantizedCommAsync {
    fn name(&self) -> &'static str {
        "quantized_comm_async"
    }

    fn buffer_size(&self) -> usize {
        self.inner.buffer_size()
    }

    fn configure_fit(
        &mut self,
        version: u64,
        parameters: &Parameters,
        handle: &ClientHandle,
    ) -> FitIns {
        let mut ins = self.inner.configure_fit(version, parameters, handle);
        match ins.parameters.quantize_f16() {
            Ok(q) => {
                ins.parameters = q;
                ins.config
                    .insert(keys::QUANTIZE.into(), Scalar::Str("f16".into()));
            }
            Err(e) => log::warn(&format!(
                "quantized_comm_async: fit version {version} client {}: \
                 f16 quantization failed ({e}); sending f32 unflagged",
                handle.id
            )),
        }
        ins
    }

    fn on_fit_result(
        &mut self,
        handle: &ClientHandle,
        staleness: u64,
        res: FitRes,
    ) -> Result<Option<Parameters>> {
        // Dequantize the uplink so the inner strategy buffers f32.
        let mut res = res;
        if let Ok(flat) = res.parameters.to_flat_vec() {
            res.parameters = Parameters::from_flat(flat);
        }
        self.inner.on_fit_result(handle, staleness, res)
    }

    fn flush(&mut self) -> Result<Option<Parameters>> {
        self.inner.flush()
    }

    fn configure_evaluate(
        &mut self,
        version: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        let mut plan = self.inner.configure_evaluate(version, parameters, cohort);
        for (id, ins) in &mut plan {
            match ins.parameters.quantize_f16() {
                Ok(q) => ins.parameters = q,
                Err(e) => log::warn(&format!(
                    "quantized_comm_async: evaluate version {version} client {id}: \
                     f16 quantization failed ({e}); sending f32"
                )),
            }
        }
        plan
    }

    fn aggregate_evaluate(
        &mut self,
        version: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(version, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator, FedAvg, FedBuff};
    use super::*;
    use crate::proto::scalar::ConfigExt;

    fn quantized() -> QuantizedComm {
        QuantizedComm::new(Box::new(FedAvg::new(
            TrainingPlan::default(),
            Aggregator::Rust,
        )))
    }

    #[test]
    fn downlink_is_quantized_and_flagged() {
        let mut s = quantized();
        let cohort = handles(2);
        let params = Parameters::from_flat(vec![0.5; 100]);
        let plan = s.configure_fit(1, &params, &cohort);
        for (_, ins) in &plan {
            assert_eq!(ins.parameters.byte_len(), 200); // half of 400
            assert_eq!(ins.config.get_str(keys::QUANTIZE).unwrap(), "f16");
        }
    }

    #[test]
    fn aggregation_dequantizes_uplink() {
        let mut s = quantized();
        let h = handles(2);
        let q1 = Parameters::from_flat(vec![1.0, 2.0]).quantize_f16().unwrap();
        let q2 = Parameters::from_flat(vec![3.0, 4.0]).quantize_f16().unwrap();
        let mk = |p: Parameters| FitRes {
            status: crate::proto::Status::ok(),
            parameters: p,
            num_examples: 10,
            metrics: Default::default(),
        };
        let results = vec![(h[0].clone(), mk(q1)), (h[1].clone(), mk(q2))];
        let out = s.aggregate_fit(1, &results, 0).unwrap();
        assert_eq!(out.to_flat().unwrap(), &[2.0, 3.0]);
    }

    /// Failure path: a parameter set containing a non-float tensor cannot
    /// be f16-quantized. The wrapper must ship the original payload and —
    /// crucially — must NOT insert the `quantize=f16` flag: an earlier
    /// version swallowed the error but flagged anyway, telling clients and
    /// the byte-accounting cost model the payload was halved when it
    /// wasn't.
    #[test]
    fn quantization_failure_ships_original_without_flag() {
        let mut s = quantized();
        let cohort = handles(2);
        let params = Parameters {
            tensors: vec![crate::proto::Tensor::i32(vec![3], vec![1, 2, 3]).unwrap()],
        };
        let plan = s.configure_fit(1, &params, &cohort);
        assert_eq!(plan.len(), 2);
        for (_, ins) in &plan {
            assert_eq!(ins.parameters, params, "payload must pass through unchanged");
            assert!(
                !ins.config.contains_key(keys::QUANTIZE),
                "flag must not claim a quantization that failed"
            );
        }
        let eplan = s.configure_evaluate(1, &params, &cohort);
        for (_, ins) in &eplan {
            assert_eq!(ins.parameters, params);
        }
        // and the happy path still flags (guards against over-fixing)
        let ok = Parameters::from_flat(vec![0.5; 4]);
        let plan = s.configure_fit(2, &ok, &cohort);
        for (_, ins) in &plan {
            assert_eq!(ins.config.get_str(keys::QUANTIZE).unwrap(), "f16");
        }
    }

    #[test]
    fn evaluate_passthrough() {
        let mut s = quantized();
        let h = handles(1);
        let results = vec![(h[0].clone(), eval_res(1.0, 0.8, 100))];
        let sum = s.aggregate_evaluate(1, &results).unwrap();
        assert!((sum.accuracy - 0.8).abs() < 1e-9);
    }

    #[test]
    fn async_wrapper_quantizes_downlink_and_dequantizes_uplink() {
        let mut s = QuantizedCommAsync::new(Box::new(FedBuff::new(
            TrainingPlan::default(),
            Aggregator::Rust,
            2,
        )));
        assert_eq!(s.buffer_size(), 2);
        let h = handles(2);
        let params = Parameters::from_flat(vec![0.5; 100]);
        let ins = s.configure_fit(1, &params, &h[0]);
        assert_eq!(ins.parameters.byte_len(), 200); // half of 400
        assert_eq!(ins.config.get_str(keys::QUANTIZE).unwrap(), "f16");
        // uplink arrives f16; flush must aggregate dequantized f32
        let q1 = Parameters::from_flat(vec![1.0, 2.0]).quantize_f16().unwrap();
        let q2 = Parameters::from_flat(vec![3.0, 4.0]).quantize_f16().unwrap();
        let mk = |p: Parameters| FitRes {
            status: crate::proto::Status::ok(),
            parameters: p,
            num_examples: 10,
            metrics: Default::default(),
        };
        assert!(s.on_fit_result(&h[0], 0, mk(q1)).unwrap().is_none());
        let out = s.on_fit_result(&h[1], 0, mk(q2)).unwrap().unwrap();
        assert_eq!(out.to_flat().unwrap(), &[2.0, 3.0]);
    }
}
