//! FedBuff (Nguyen et al. 2022): buffered asynchronous aggregation.
//!
//! The synchronous FL loop barriers every round on its slowest
//! participant — the paper's Table 3 shows stragglers dominating round
//! wall-time and wasted energy. FedBuff removes the barrier: the server
//! keeps fit work outstanding on every client, folds results into a
//! buffer *as they arrive*, and emits a new model version every K
//! results. A result trained from model version `v` folded at version
//! `v'` has staleness `s = v' - v` and is discounted by the polynomial
//! weight `(1 + s)^-alpha`, so updates from stragglers still contribute
//! but cannot drag the model backwards.
//!
//! With `K = cohort size` and zero staleness the flush reduces to plain
//! example-weighted FedAvg — bit-identical, since both run the same
//! `weighted_parameter_average` path (property-tested in
//! `rust/tests/proptests.rs`).

use crate::client::keys;
use crate::config;
use crate::error::{Error, Result};
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters};

use super::fedavg::{weighted_parameter_average, TrainingPlan};
use super::{
    weighted_eval_summary, Aggregator, AsyncStrategy, ClientHandle, EvalSummary,
};

/// Default polynomial staleness exponent (FedBuff's `a = 0.5`).
pub const DEFAULT_STALENESS_ALPHA: f64 = 0.5;

/// Default buffer size K (the FedBuff paper's sweet spot, and what
/// `flowrs sched --mode async|both` uses when `--async-buffer` is not
/// given).
pub const DEFAULT_BUFFER_SIZE: usize = 8;

/// Polynomial staleness discount `w(s) = (1 + s)^-alpha`.
///
/// Properties (property-tested): `w(0) = 1`, `w` is in `(0, 1]`, and is
/// monotonically non-increasing in `s` for every `alpha >= 0`.
pub fn staleness_discount(staleness: u64, alpha: f64) -> f64 {
    (1.0 + staleness as f64).powf(-alpha)
}

/// Raw FedBuff weight of one buffered result: `examples × w(staleness)`.
/// This exact expression feeds the flush aggregation; the property tests
/// exercise it through [`normalized_staleness_weights`] so they cover the
/// production weight path, not a parallel formula.
pub fn staleness_weight(num_examples: u64, staleness: u64, alpha: f64) -> f64 {
    num_examples as f64 * staleness_discount(staleness, alpha)
}

/// Normalize per-result weights `examples_i × w(s_i)` into a convex
/// combination (non-negative, summing to 1) — the same normalization the
/// aggregator applies to the flush weights. Errors when every weight
/// vanishes (no successful result carries mass).
pub fn normalized_staleness_weights(
    examples: &[u64],
    staleness: &[u64],
    alpha: f64,
) -> Result<Vec<f64>> {
    debug_assert_eq!(examples.len(), staleness.len());
    let raw: Vec<f64> = examples
        .iter()
        .zip(staleness)
        .map(|(&n, &s)| staleness_weight(n, s, alpha))
        .collect();
    let total: f64 = raw.iter().sum();
    if total <= 0.0 || !total.is_finite() {
        return Err(Error::Aggregation("staleness weights sum to zero".into()));
    }
    Ok(raw.into_iter().map(|w| w / total).collect())
}

/// The buffered asynchronous strategy.
pub struct FedBuff {
    pub plan: TrainingPlan,
    /// Buffer size K: successful results per model-version flush.
    pub buffer_size: usize,
    /// Polynomial staleness exponent (0 = no discount).
    pub alpha: f64,
    aggregator: Aggregator,
    /// Arrived-but-unflushed results: (staleness, result).
    buffer: Vec<(u64, FitRes)>,
}

impl FedBuff {
    pub fn new(plan: TrainingPlan, aggregator: Aggregator, buffer_size: usize) -> Self {
        FedBuff {
            plan,
            buffer_size: buffer_size.max(1),
            alpha: DEFAULT_STALENESS_ALPHA,
            aggregator,
            buffer: Vec::new(),
        }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    /// Results currently waiting in the buffer.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    fn flush_buffer(&mut self) -> Result<Option<Parameters>> {
        if self.buffer.is_empty() {
            return Ok(None);
        }
        let alpha = self.alpha;
        let params = weighted_parameter_average(
            &self.aggregator,
            self.buffer
                .iter()
                .map(|(s, r)| (r, staleness_weight(r.num_examples, *s, alpha))),
        )?;
        self.buffer.clear();
        Ok(Some(params))
    }
}

impl AsyncStrategy for FedBuff {
    fn name(&self) -> &'static str {
        "fedbuff"
    }

    fn buffer_size(&self) -> usize {
        self.buffer_size
    }

    fn configure_fit(
        &mut self,
        version: u64,
        parameters: &Parameters,
        _handle: &ClientHandle,
    ) -> FitIns {
        FitIns {
            parameters: parameters.clone(),
            config: self.plan.to_config(version),
        }
    }

    fn on_fit_result(
        &mut self,
        _handle: &ClientHandle,
        staleness: u64,
        res: FitRes,
    ) -> Result<Option<Parameters>> {
        // Failed or empty results carry no mass; the server accounts for
        // them separately, the buffer only ever holds usable updates.
        if !res.status.is_ok() || res.num_examples == 0 {
            return Ok(None);
        }
        self.buffer.push((staleness, res));
        if self.buffer.len() >= self.buffer_size {
            self.flush_buffer()
        } else {
            Ok(None)
        }
    }

    fn flush(&mut self) -> Result<Option<Parameters>> {
        self.flush_buffer()
    }

    fn configure_evaluate(
        &mut self,
        version: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        let config = config! { keys::ROUND => version as i64 };
        (0..cohort.len())
            .map(|idx| {
                (
                    idx,
                    EvaluateIns { parameters: parameters.clone(), config: config.clone() },
                )
            })
            .collect()
    }

    fn aggregate_evaluate(
        &mut self,
        _version: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        weighted_eval_summary(results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    fn fedbuff(k: usize, alpha: f64) -> FedBuff {
        FedBuff::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust, k).with_alpha(alpha)
    }

    #[test]
    fn discount_is_one_at_zero_staleness() {
        for alpha in [0.0, 0.5, 1.0, 3.0] {
            assert_eq!(staleness_discount(0, alpha), 1.0);
        }
    }

    #[test]
    fn discount_decreases_with_staleness() {
        let w: Vec<f64> = (0..6).map(|s| staleness_discount(s, 0.5)).collect();
        assert!(w.windows(2).all(|p| p[1] < p[0]), "{w:?}");
        assert!((staleness_discount(3, 0.5) - 0.5).abs() < 1e-12); // (1+3)^-0.5
    }

    #[test]
    fn zero_alpha_ignores_staleness() {
        assert_eq!(staleness_discount(100, 0.0), 1.0);
    }

    #[test]
    fn buffer_flushes_on_kth_result() {
        let mut s = fedbuff(3, 0.5);
        let h = handles(3);
        assert!(s
            .on_fit_result(&h[0], 0, fit_res(vec![1.0, 1.0], 10, 1.0))
            .unwrap()
            .is_none());
        assert!(s
            .on_fit_result(&h[1], 0, fit_res(vec![2.0, 2.0], 10, 1.0))
            .unwrap()
            .is_none());
        assert_eq!(s.buffered(), 2);
        let p = s
            .on_fit_result(&h[2], 0, fit_res(vec![3.0, 3.0], 10, 1.0))
            .unwrap()
            .expect("third result must flush");
        assert_eq!(p.to_flat().unwrap(), &[2.0, 2.0]);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn stale_results_are_downweighted() {
        // Equal examples; staleness 3 at alpha 0.5 discounts to 1/2, so
        // weights are 2:1 in favour of the fresh result.
        let mut s = fedbuff(2, 0.5);
        let h = handles(2);
        assert!(s
            .on_fit_result(&h[0], 0, fit_res(vec![0.0], 100, 1.0))
            .unwrap()
            .is_none());
        let p = s
            .on_fit_result(&h[1], 3, fit_res(vec![3.0], 100, 1.0))
            .unwrap()
            .unwrap();
        let got = p.to_flat().unwrap()[0];
        assert!((got - 1.0).abs() < 1e-6, "got {got}"); // (0·1 + 3·0.5) / 1.5
    }

    #[test]
    fn failed_results_never_enter_the_buffer() {
        use crate::proto::{Status, StatusCode};
        let mut s = fedbuff(2, 0.5);
        let h = handles(2);
        let mut bad = fit_res(vec![9.0], 10, 1.0);
        bad.status = Status { code: StatusCode::FitError, message: "oom".into() };
        assert!(s.on_fit_result(&h[0], 0, bad).unwrap().is_none());
        assert_eq!(s.buffered(), 0);
        let empty = fit_res(vec![9.0], 0, 1.0);
        assert!(s.on_fit_result(&h[1], 0, empty).unwrap().is_none());
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn explicit_flush_drains_partial_buffer() {
        let mut s = fedbuff(8, 0.5);
        let h = handles(1);
        assert!(s
            .on_fit_result(&h[0], 0, fit_res(vec![4.0], 10, 1.0))
            .unwrap()
            .is_none());
        let p = s.flush().unwrap().expect("partial buffer must flush");
        assert_eq!(p.to_flat().unwrap(), &[4.0]);
        assert!(s.flush().unwrap().is_none(), "empty buffer flushes to None");
    }

    #[test]
    fn normalized_weights_are_convex() {
        let w = normalized_staleness_weights(&[100, 50, 10], &[0, 2, 7], 0.5).unwrap();
        assert_eq!(w.len(), 3);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w.iter().all(|&x| x > 0.0));
        assert!(normalized_staleness_weights(&[0, 0], &[0, 0], 0.5).is_err());
    }
}
