//! Parameter aggregation backends.
//!
//! The weighted average at the core of FedAvg can run two ways:
//! * [`Aggregator::Rust`] — portable f64-accumulated loop (default);
//! * [`Aggregator::Pjrt`] — the Pallas `fedavg_aggregate` kernel via the
//!   AOT artifact, streaming K client vectors through the XLA runtime.
//!
//! Both are exercised by tests and compared by `rust/benches/aggregate.rs`;
//! the PJRT artifact has a fixed slot count, so larger cohorts are folded
//! in linear chunks (weighted sums are associative).

use crate::error::{Error, Result};
use crate::runtime::Runtime;

/// Which backend aggregates parameters.
#[derive(Clone)]
pub enum Aggregator {
    /// Portable CPU loop, f64 accumulation.
    Rust,
    /// The AOT Pallas kernel for `model` through `runtime`.
    Pjrt { runtime: Runtime, model: String },
}

impl std::fmt::Debug for Aggregator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Aggregator::Rust => write!(f, "Aggregator::Rust"),
            Aggregator::Pjrt { model, .. } => write!(f, "Aggregator::Pjrt({model})"),
        }
    }
}

impl Aggregator {
    /// Weighted average of `(vector, weight)` pairs. Weights need not be
    /// normalized; they must be non-negative with a positive sum.
    pub fn weighted_average(&self, inputs: &[(&[f32], f64)]) -> Result<Vec<f32>> {
        if inputs.is_empty() {
            return Err(Error::Aggregation("nothing to aggregate".into()));
        }
        let p = inputs[0].0.len();
        for (i, (v, w)) in inputs.iter().enumerate() {
            if v.len() != p {
                return Err(Error::Aggregation(format!(
                    "vector {i} has {} params, expected {p}",
                    v.len()
                )));
            }
            if *w < 0.0 || !w.is_finite() {
                return Err(Error::Aggregation(format!("bad weight {w} at {i}")));
            }
        }
        let total: f64 = inputs.iter().map(|(_, w)| w).sum();
        if total <= 0.0 {
            return Err(Error::Aggregation("weights sum to zero".into()));
        }
        match self {
            Aggregator::Rust => Ok(rust_weighted_average(inputs, total)),
            Aggregator::Pjrt { runtime, model } => {
                pjrt_weighted_average(runtime, model, inputs, total)
            }
        }
    }
}

fn rust_weighted_average(inputs: &[(&[f32], f64)], total: f64) -> Vec<f32> {
    let p = inputs[0].0.len();
    let mut acc = vec![0f64; p];
    for (v, w) in inputs {
        let wn = w / total;
        for (a, &x) in acc.iter_mut().zip(v.iter()) {
            *a += wn * x as f64;
        }
    }
    acc.into_iter().map(|x| x as f32).collect()
}

fn pjrt_weighted_average(
    runtime: &Runtime,
    model: &str,
    inputs: &[(&[f32], f64)],
    total: f64,
) -> Result<Vec<f32>> {
    let slots = runtime.manifest().model(model)?.agg_slots;
    // Fold in chunks of `slots`: weighted sums are associative, so each
    // chunk contributes its partial sum with normalized weights.
    let mut partials: Vec<Vec<f32>> = Vec::new();
    for chunk in inputs.chunks(slots) {
        let vectors: Vec<&[f32]> = chunk.iter().map(|(v, _)| *v).collect();
        let weights: Vec<f32> = chunk.iter().map(|(_, w)| (*w / total) as f32).collect();
        partials.push(runtime.aggregate(model, &vectors, &weights)?);
    }
    if partials.len() == 1 {
        return Ok(partials.pop().unwrap());
    }
    // Sum the partials (already correctly scaled).
    let refs: Vec<(&[f32], f64)> = partials.iter().map(|v| (v.as_slice(), 1.0)).collect();
    Ok(rust_weighted_average(&refs, 1.0)
        .into_iter()
        .map(|x| x * partials.len() as f32) // undo the mean: we want the sum
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_weighted_average_basic() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let out = Aggregator::Rust
            .weighted_average(&[(&a, 1.0), (&b, 3.0)])
            .unwrap();
        assert_eq!(out, vec![2.5, 5.0]);
    }

    #[test]
    fn identity_single_input() {
        let a = vec![1.5f32; 100];
        let out = Aggregator::Rust.weighted_average(&[(&a, 42.0)]).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn rejects_bad_inputs() {
        let a = vec![1.0f32, 2.0];
        let b = vec![1.0f32];
        assert!(Aggregator::Rust.weighted_average(&[]).is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, 1.0), (&b, 1.0)])
            .is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, -1.0)])
            .is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, 0.0)])
            .is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, f64::NAN)])
            .is_err());
    }

    #[test]
    fn permutation_invariant() {
        let v1 = vec![1.0f32, -1.0, 0.5];
        let v2 = vec![2.0f32, 3.0, -0.5];
        let v3 = vec![0.0f32, 1.0, 1.0];
        let fwd = Aggregator::Rust
            .weighted_average(&[(&v1, 1.0), (&v2, 2.0), (&v3, 3.0)])
            .unwrap();
        let rev = Aggregator::Rust
            .weighted_average(&[(&v3, 3.0), (&v1, 1.0), (&v2, 2.0)])
            .unwrap();
        for (a, b) in fwd.iter().zip(&rev) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
