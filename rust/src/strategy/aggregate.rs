//! Parameter aggregation backends.
//!
//! The weighted average at the core of FedAvg can run two ways:
//! * [`Aggregator::Rust`] — portable f64-accumulated loop (default);
//! * [`Aggregator::Pjrt`] — the Pallas `fedavg_aggregate` kernel via the
//!   AOT artifact, streaming K client vectors through the XLA runtime.
//!
//! Both are exercised by tests and compared by `rust/benches/aggregate.rs`;
//! the PJRT artifact has a fixed slot count, so larger cohorts are folded
//! in linear chunks (weighted sums are associative).
//!
//! The Rust fold parallelizes across `util::par::workers()` threads by
//! splitting the **parameter (output) dimension** into fixed-size chunks
//! ([`FOLD_CHUNK`]): every output element is still accumulated over the
//! inputs in their original order inside one f64 accumulator, so the
//! result is bit-identical to the sequential loop for every worker count
//! (there is no cross-thread combine to re-associate).

use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::util::par;

/// Which backend aggregates parameters.
#[derive(Clone)]
pub enum Aggregator {
    /// Portable CPU loop, f64 accumulation.
    Rust,
    /// The AOT Pallas kernel for `model` through `runtime`.
    Pjrt { runtime: Runtime, model: String },
}

impl std::fmt::Debug for Aggregator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Aggregator::Rust => write!(f, "Aggregator::Rust"),
            Aggregator::Pjrt { model, .. } => write!(f, "Aggregator::Pjrt({model})"),
        }
    }
}

impl Aggregator {
    /// Weighted average of `(vector, weight)` pairs. Weights need not be
    /// normalized; they must be non-negative with a positive sum.
    pub fn weighted_average(&self, inputs: &[(&[f32], f64)]) -> Result<Vec<f32>> {
        if inputs.is_empty() {
            return Err(Error::Aggregation("nothing to aggregate".into()));
        }
        let p = inputs[0].0.len();
        for (i, (v, w)) in inputs.iter().enumerate() {
            if v.len() != p {
                return Err(Error::Aggregation(format!(
                    "vector {i} has {} params, expected {p}",
                    v.len()
                )));
            }
            if *w < 0.0 || !w.is_finite() {
                return Err(Error::Aggregation(format!("bad weight {w} at {i}")));
            }
        }
        let total: f64 = inputs.iter().map(|(_, w)| w).sum();
        if total <= 0.0 {
            return Err(Error::Aggregation("weights sum to zero".into()));
        }
        match self {
            Aggregator::Rust => Ok(rust_weighted_average(inputs, total)),
            Aggregator::Pjrt { runtime, model } => {
                pjrt_weighted_average(runtime, model, inputs, total)
            }
        }
    }
}

/// Output-dimension chunk size for the parallel Rust fold. Chunk
/// boundaries depend only on the parameter count — never on the worker
/// count — so the work split is deterministic by construction.
pub const FOLD_CHUNK: usize = 8192;

/// The portable fold with the process-wide worker count
/// (`util::par::workers()`).
pub fn rust_weighted_average(inputs: &[(&[f32], f64)], total: f64) -> Vec<f32> {
    rust_weighted_average_with_workers(inputs, total, par::workers())
}

/// The portable f64-accumulated weighted average, fanned out over
/// `workers` threads along the parameter dimension.
///
/// Each worker owns a disjoint contiguous run of whole [`FOLD_CHUNK`]
/// blocks of the output vector and accumulates *all* inputs, in input
/// order, into its own f64 accumulator. Because every output element's
/// accumulation chain is the same as in the sequential loop, the result
/// is **bit-identical for every `workers` value** — pinned by the
/// differential property test below and relied on by the golden-trace
/// suite (the engine's folds may not drift when `--workers` changes).
pub fn rust_weighted_average_with_workers(
    inputs: &[(&[f32], f64)],
    total: f64,
    workers: usize,
) -> Vec<f32> {
    let p = inputs[0].0.len();
    let n_chunks = p.div_ceil(FOLD_CHUNK);
    let threads = workers.max(1).min(n_chunks.max(1));
    if threads <= 1 {
        let mut out = vec![0f32; p];
        fold_range(inputs, total, 0, p, &mut out);
        return out;
    }
    crate::obs::registry()
        .counter("aggregate_fold_chunks_total")
        .add(n_chunks as u64);
    // Contiguous runs of whole chunks per worker; boundaries are a pure
    // function of (p, threads).
    let runs: Vec<(usize, usize)> = par::shard_ranges(n_chunks, threads)
        .into_iter()
        .map(|(clo, chi)| ((clo * FOLD_CHUNK).min(p), (chi * FOLD_CHUNK).min(p)))
        .collect();
    let parts = par::run_sharded(runs.len(), |i| {
        let (lo, hi) = runs[i];
        let mut part = vec![0f32; hi - lo];
        fold_range(inputs, total, lo, hi, &mut part);
        part
    });
    let mut out = Vec::with_capacity(p);
    for part in parts {
        out.extend_from_slice(&part);
    }
    out
}

/// Accumulate `out[..] = Σ_i (w_i/total) * inputs_i[lo..hi]` in f64, inputs
/// in their given order — the same per-element chain as the sequential
/// fold, restricted to one output range.
fn fold_range(inputs: &[(&[f32], f64)], total: f64, lo: usize, hi: usize, out: &mut [f32]) {
    let mut acc = vec![0f64; hi - lo];
    for (v, w) in inputs {
        let wn = w / total;
        for (a, &x) in acc.iter_mut().zip(v[lo..hi].iter()) {
            *a += wn * x as f64;
        }
    }
    for (o, a) in out.iter_mut().zip(acc) {
        *o = a as f32;
    }
}

/// Fold `inputs` in chunks of `slots` through `exec`, which computes one
/// chunk's weighted sum from `(vectors, normalized f32 weights)` — the
/// shape of [`Runtime::aggregate`]. Extracted from the PJRT path so the
/// chunked fold logic is testable without loadable AOT artifacts.
///
/// The per-chunk weights are already globally normalized (`w / total`), so
/// each partial is a partial *sum* of the final average; summing the
/// partials (unit weights over an explicit total of 1.0) is the whole
/// combine step. An earlier version multiplied that sum by the partial
/// count to "undo the mean" — but nothing here ever divided by it, so any
/// cohort larger than `slots` came out `len×` too large. The regression
/// test below drives >1 chunk and asserts bit-equality with
/// [`Aggregator::Rust`].
pub fn chunked_weighted_average<F>(
    inputs: &[(&[f32], f64)],
    total: f64,
    slots: usize,
    mut exec: F,
) -> Result<Vec<f32>>
where
    F: FnMut(&[&[f32]], &[f32]) -> Result<Vec<f32>>,
{
    let slots = slots.max(1);
    let mut partials: Vec<Vec<f32>> = Vec::new();
    for chunk in inputs.chunks(slots) {
        let vectors: Vec<&[f32]> = chunk.iter().map(|(v, _)| *v).collect();
        let weights: Vec<f32> = chunk.iter().map(|(_, w)| (*w / total) as f32).collect();
        partials.push(exec(&vectors, &weights)?);
    }
    if partials.len() == 1 {
        return Ok(partials.pop().unwrap());
    }
    // Sum the partials: unit weights with total pinned to 1.0 make the
    // "average" an exact sum.
    let refs: Vec<(&[f32], f64)> = partials.iter().map(|v| (v.as_slice(), 1.0)).collect();
    Ok(rust_weighted_average(&refs, 1.0))
}

fn pjrt_weighted_average(
    runtime: &Runtime,
    model: &str,
    inputs: &[(&[f32], f64)],
    total: f64,
) -> Result<Vec<f32>> {
    let slots = runtime.manifest().model(model)?.agg_slots;
    chunked_weighted_average(inputs, total, slots, |vectors, weights| {
        runtime.aggregate(model, vectors, weights)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_weighted_average_basic() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let out = Aggregator::Rust
            .weighted_average(&[(&a, 1.0), (&b, 3.0)])
            .unwrap();
        assert_eq!(out, vec![2.5, 5.0]);
    }

    #[test]
    fn identity_single_input() {
        let a = vec![1.5f32; 100];
        let out = Aggregator::Rust.weighted_average(&[(&a, 42.0)]).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn rejects_bad_inputs() {
        let a = vec![1.0f32, 2.0];
        let b = vec![1.0f32];
        assert!(Aggregator::Rust.weighted_average(&[]).is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, 1.0), (&b, 1.0)])
            .is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, -1.0)])
            .is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, 0.0)])
            .is_err());
        assert!(Aggregator::Rust
            .weighted_average(&[(&a, f64::NAN)])
            .is_err());
    }

    /// Chunk-fold regression for the PJRT path's `* partials.len()` bug:
    /// with >1 chunk the fold must equal `Aggregator::Rust` exactly, not
    /// `len×` it. The stub runtime can't load artifacts, so the chunk
    /// executor is a closure computing exactly what `Runtime::aggregate`
    /// computes for a chunk — an f32 weighted sum in input order. All
    /// values are dyadic (quarters of small integers), so every
    /// intermediate is exactly representable in f32 *and* f64 and the
    /// f32-kernel / f64-fold results are bit-equal, not just close.
    #[test]
    fn chunked_fold_matches_rust_aggregator_exactly() {
        let vs: [Vec<f32>; 4] = [
            vec![1.0, 2.0, -8.0, 0.5],
            vec![4.0, -2.0, 0.25, 8.0],
            vec![-1.0, 16.0, 2.0, -0.5],
            vec![2.0, 0.0, 4.0, -4.0],
        ];
        // unit weights over 4 inputs: wn = 0.25 exactly, in f32 and f64
        let inputs: Vec<(&[f32], f64)> = vs.iter().map(|v| (v.as_slice(), 1.0)).collect();
        let total: f64 = 4.0;
        let want = Aggregator::Rust.weighted_average(&inputs).unwrap();

        let exec = |vectors: &[&[f32]], weights: &[f32]| -> Result<Vec<f32>> {
            let p = vectors[0].len();
            let mut out = vec![0f32; p];
            for (v, w) in vectors.iter().zip(weights) {
                for (o, &x) in out.iter_mut().zip(v.iter()) {
                    *o += w * x;
                }
            }
            Ok(out)
        };

        for slots in [1usize, 2, 3] {
            // slots < 4 ⇒ >1 chunk (3 gives a ragged tail chunk of 1)
            let got = chunked_weighted_average(&inputs, total, slots, exec).unwrap();
            let chunks = inputs.len().div_ceil(slots);
            assert!(chunks > 1);
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "slots={slots} param {j}: chunked {g} != rust {w} \
                     (the old code would return {}×)",
                    chunks
                );
            }
        }
        // single chunk (slots >= len) stays the passthrough fast path
        let got = chunked_weighted_average(&inputs, total, 8, exec).unwrap();
        assert_eq!(got, want);
    }

    /// The parallel fold is bit-identical to the sequential one for every
    /// worker count — random input counts, weights, and vector lengths
    /// both below and above `FOLD_CHUNK`.
    #[test]
    fn parallel_fold_bit_identical_across_workers() {
        use crate::util::prop;
        // deterministic boundary lengths first
        let boundary = [1usize, 2, FOLD_CHUNK - 1, FOLD_CHUNK, FOLD_CHUNK + 1];
        let mut case = 0u64;
        prop::check("parallel fold == sequential fold", 48, |rng| {
            let len = if (case as usize) < boundary.len() {
                boundary[case as usize]
            } else {
                1 + rng.below(3 * FOLD_CHUNK)
            };
            case += 1;
            let k = 1 + rng.below(5);
            let vs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect())
                .collect();
            let ws: Vec<f64> = (0..k).map(|_| 0.1 + rng.f64() * 9.9).collect();
            let inputs: Vec<(&[f32], f64)> =
                vs.iter().zip(&ws).map(|(v, &w)| (v.as_slice(), w)).collect();
            let total: f64 = ws.iter().sum();
            let seq = rust_weighted_average_with_workers(&inputs, total, 1);
            for workers in [2usize, 8] {
                let par = rust_weighted_average_with_workers(&inputs, total, workers);
                prop::ensure(par.len() == seq.len(), || {
                    format!("len mismatch at workers={workers}")
                })?;
                for (j, (a, b)) in par.iter().zip(&seq).enumerate() {
                    prop::ensure(a.to_bits() == b.to_bits(), || {
                        format!(
                            "workers={workers} len={len} k={k} param {j}: {a} != {b}"
                        )
                    })?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn permutation_invariant() {
        let v1 = vec![1.0f32, -1.0, 0.5];
        let v2 = vec![2.0f32, 3.0, -0.5];
        let v3 = vec![0.0f32, 1.0, 1.0];
        let fwd = Aggregator::Rust
            .weighted_average(&[(&v1, 1.0), (&v2, 2.0), (&v3, 3.0)])
            .unwrap();
        let rev = Aggregator::Rust
            .weighted_average(&[(&v3, 3.0), (&v1, 1.0), (&v2, 2.0)])
            .unwrap();
        for (a, b) in fwd.iter().zip(&rev) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
