//! Secure aggregation (server side): the strategy wrapper pairing with
//! `client::masking::MaskedClient`.
//!
//! The server broadcasts the round's cohort (peer ids) and a shared base
//! seed; clients return pairwise-masked updates; the *unweighted mean*
//! over the full cohort cancels every mask. Two protocol consequences,
//! both enforced here:
//!
//! * aggregation must weight every client equally (weighted means would
//!   scale masks asymmetrically and leak), so `aggregate_fit` uses the
//!   plain mean — the classic SecAgg trade-off;
//! * every masked participant must report (no dropout recovery in this
//!   SecAgg0 core): missing results leave un-cancelled masks, so the
//!   round fails loudly instead of aggregating noise.

use std::collections::BTreeSet;

use crate::client::keys;
use crate::error::{Error, Result};
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters, Scalar};

use super::{ClientHandle, EvalSummary, Strategy};

/// Wraps an inner strategy with SecAgg0 masking coordination.
pub struct SecAgg {
    inner: Box<dyn Strategy>,
    base_seed: u64,
    /// cohort ids announced in the current round's configure_fit
    current_cohort: BTreeSet<String>,
}

impl SecAgg {
    pub fn new(inner: Box<dyn Strategy>, base_seed: u64) -> Self {
        SecAgg { inner, base_seed, current_cohort: BTreeSet::new() }
    }
}

impl Strategy for SecAgg {
    fn name(&self) -> &'static str {
        "secagg"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let mut plan = self.inner.configure_fit(round, parameters, cohort);
        let peer_ids: Vec<String> = plan
            .iter()
            .map(|(idx, _)| cohort[*idx].id.clone())
            .collect();
        self.current_cohort = peer_ids.iter().cloned().collect();
        let peers_csv = peer_ids.join(",");
        for (_, ins) in &mut plan {
            ins.config
                .insert(keys::SECAGG_PEERS.into(), Scalar::Str(peers_csv.clone()));
            ins.config
                .insert(keys::SECAGG_SEED.into(), Scalar::I64(self.base_seed as i64));
        }
        plan
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters> {
        // every announced masker must have reported successfully
        let reported: BTreeSet<String> = results
            .iter()
            .filter(|(_, res)| res.status.is_ok() && !res.parameters.is_empty())
            .map(|(h, _)| h.id.clone())
            .collect();
        if reported != self.current_cohort || failures > 0 {
            let missing: Vec<&String> =
                self.current_cohort.difference(&reported).collect();
            return Err(Error::Aggregation(format!(
                "secagg round incomplete: masks cannot cancel \
                 (missing {missing:?}, {failures} failures) — SecAgg0 has no \
                 dropout recovery"
            )));
        }
        // unweighted mean: the only aggregation masks survive
        let mut acc: Vec<f64> = Vec::new();
        let n = results.len() as f64;
        for (_, res) in results {
            let flat = res.parameters.to_flat_vec()?;
            if acc.is_empty() {
                acc = vec![0f64; flat.len()];
            }
            if acc.len() != flat.len() {
                return Err(Error::Aggregation("secagg: parameter size mismatch".into()));
            }
            for (a, x) in acc.iter_mut().zip(&flat) {
                *a += *x as f64 / n;
            }
        }
        if acc.is_empty() {
            return Err(Error::Aggregation("secagg: no results".into()));
        }
        Ok(Parameters::from_flat(acc.into_iter().map(|x| x as f32).collect()))
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{fedavg::TrainingPlan, Aggregator, FedAvg};
    use super::*;
    use crate::client::masking::mask_update;
    use crate::proto::scalar::ConfigExt;

    fn secagg() -> SecAgg {
        SecAgg::new(
            Box::new(FedAvg::new(TrainingPlan::default(), Aggregator::Rust)),
            777,
        )
    }

    #[test]
    fn announces_cohort_and_seed() {
        let mut s = secagg();
        let cohort = handles(3);
        let plan = s.configure_fit(1, &Parameters::from_flat(vec![0.0]), &cohort);
        for (_, ins) in &plan {
            // plan order follows the inner strategy's sampling; compare as set
            let mut peers: Vec<&str> = ins
                .config
                .get_str(keys::SECAGG_PEERS)
                .unwrap()
                .split(',')
                .collect();
            peers.sort_unstable();
            assert_eq!(peers, vec!["c0", "c1", "c2"]);
            assert_eq!(ins.config.get_i64(keys::SECAGG_SEED).unwrap(), 777);
        }
    }

    #[test]
    fn masked_mean_equals_plain_mean() {
        let mut s = secagg();
        let cohort = handles(3);
        let plan = s.configure_fit(4, &Parameters::from_flat(vec![0.0; 64]), &cohort);
        assert_eq!(plan.len(), 3);
        let peers: Vec<&str> = vec!["c0", "c1", "c2"];
        let plain: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..64).map(|j| (i + j) as f32 * 0.01).collect())
            .collect();
        let results: Vec<(ClientHandle, FitRes)> = (0..3)
            .map(|i| {
                let mut masked = plain[i].clone();
                mask_update(&mut masked, &cohort[i].id, &peers, 4, 777).unwrap();
                (cohort[i].clone(), fit_res(masked, 100, 1.0))
            })
            .collect();
        let agg = s.aggregate_fit(4, &results, 0).unwrap();
        let agg = agg.to_flat().unwrap();
        for j in 0..64 {
            let want: f32 = plain.iter().map(|v| v[j]).sum::<f32>() / 3.0;
            assert!((agg[j] - want).abs() < 1e-3, "j={j}: {} vs {want}", agg[j]);
        }
    }

    #[test]
    fn missing_masker_fails_the_round() {
        let mut s = secagg();
        let cohort = handles(3);
        let _ = s.configure_fit(1, &Parameters::from_flat(vec![0.0; 8]), &cohort);
        // only 2 of 3 report
        let results = vec![
            (cohort[0].clone(), fit_res(vec![0.0; 8], 10, 1.0)),
            (cohort[1].clone(), fit_res(vec![0.0; 8], 10, 1.0)),
        ];
        let err = s.aggregate_fit(1, &results, 1).unwrap_err();
        assert!(err.to_string().contains("masks cannot cancel"), "{err}");
    }
}
