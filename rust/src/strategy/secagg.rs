//! Secure aggregation (server side): the strategy wrapper pairing with
//! `client::masking::MaskedClient`.
//!
//! The server broadcasts the round's cohort (peer ids) and a shared base
//! seed; clients return pairwise-masked updates; the *unweighted mean*
//! over the cohort cancels every mask. Protocol consequences, all
//! enforced here:
//!
//! * aggregation must weight every client equally (weighted means would
//!   scale masks asymmetrically and leak), so `aggregate_fit` uses the
//!   plain mean — the classic SecAgg trade-off. The population engine's
//!   composition rule is the same: secagg folds carry weight exactly
//!   1.0, staleness discounts disabled (`sched::engine::fold_weights`);
//! * a dropped masker leaves un-cancelled mask terms in the sum. The
//!   server recovers by **residual unmasking**: it re-derives the
//!   dropped pairs' mask streams through the *same*
//!   [`crate::client::masking::pair_seed`] path the clients used (one
//!   shared derivation — a parallel server-side formula once disagreed
//!   with `client::masking::id_hash` for non-numeric ids, which is why
//!   the derivation now lives in exactly one place) and subtracts them.
//!   Grid arithmetic makes the recovery exact
//!   (see `client::masking` module docs);
//! * because the server holds the base seed, this core is a *systems
//!   cost model* of SecAgg (bytes, aggregation rules), not a
//!   cryptographic implementation — see `strategy/README.md`.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::client::keys;
use crate::client::masking::{encode_peer_list, for_each_mask_term, unmask_update};
use crate::error::{Error, Result};
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters, Scalar};

use super::fedavg::TrainingPlan;
use super::{
    weighted_eval_summary, AsyncStrategy, ClientHandle, EvalSummary, Strategy,
};

/// Wraps an inner strategy with SecAgg0 masking coordination.
pub struct SecAgg {
    inner: Box<dyn Strategy>,
    base_seed: u64,
    /// cohort ids announced in the current round's configure_fit
    current_cohort: BTreeSet<String>,
}

impl SecAgg {
    pub fn new(inner: Box<dyn Strategy>, base_seed: u64) -> Self {
        SecAgg { inner, base_seed, current_cohort: BTreeSet::new() }
    }
}

impl Strategy for SecAgg {
    fn name(&self) -> &'static str {
        "secagg"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let mut plan = self.inner.configure_fit(round, parameters, cohort);
        let peer_ids: Vec<String> = plan
            .iter()
            .map(|(idx, _)| cohort[*idx].id.clone())
            .collect();
        self.current_cohort = peer_ids.iter().cloned().collect();
        // Roster entries are percent-escaped, so externally-supplied ids
        // containing commas ride the CSV config value safely.
        let peers_csv = encode_peer_list(&peer_ids);
        for (_, ins) in &mut plan {
            ins.config
                .insert(keys::SECAGG_PEERS.into(), Scalar::Str(peers_csv.clone()));
            ins.config
                .insert(keys::SECAGG_SEED.into(), Scalar::I64(self.base_seed as i64));
        }
        plan
    }

    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        _failures: usize,
    ) -> Result<Parameters> {
        let usable: Vec<&(ClientHandle, FitRes)> = results
            .iter()
            .filter(|(_, res)| res.status.is_ok() && !res.parameters.is_empty())
            .collect();
        let reported: BTreeSet<String> =
            usable.iter().map(|(h, _)| h.id.clone()).collect();
        if !reported.is_subset(&self.current_cohort) {
            let unknown: Vec<&String> =
                reported.difference(&self.current_cohort).collect();
            return Err(Error::Aggregation(format!(
                "secagg: results from clients outside the announced cohort \
                 ({unknown:?}) — their masks were never announced"
            )));
        }
        if usable.is_empty() {
            return Err(Error::Aggregation("secagg: no results".into()));
        }
        // Unweighted sum, accumulated in f64. Every masked value is a
        // multiple of the 2^-10 mask grid, so the sum is exact and the
        // mask algebra below is bit-exact (client::masking module docs).
        let mut acc: Vec<f64> = Vec::new();
        for (_, res) in &usable {
            let flat = res.parameters.to_flat_vec()?;
            if acc.is_empty() {
                acc = vec![0f64; flat.len()];
            }
            if acc.len() != flat.len() {
                return Err(Error::Aggregation("secagg: parameter size mismatch".into()));
            }
            for (a, x) in acc.iter_mut().zip(&flat) {
                *a += *x as f64;
            }
        }
        // Dropout recovery: masks between two reporters cancelled in the
        // sum above; each (reporter, dropout) pair left one residual term
        // per element, re-derived and subtracted here.
        let missing: Vec<&String> = self.current_cohort.difference(&reported).collect();
        for s in &reported {
            for d in &missing {
                for_each_mask_term(s, d, round, self.base_seed, acc.len(), |i, m| {
                    acc[i] -= m as f64;
                });
            }
        }
        let n = usable.len() as f64;
        Ok(Parameters::from_flat(
            acc.into_iter().map(|x| (x / n) as f32).collect(),
        ))
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        self.inner.configure_evaluate(round, parameters, cohort)
    }

    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        self.inner.aggregate_evaluate(round, results)
    }
}

/// SecAgg for the buffered-asynchronous loop.
///
/// Async has no synchronous cohort to cancel masks over: clients are
/// dispatched one at a time and fold in arrival order. Each dispatch
/// therefore announces the *active mask group* — the last `K`
/// (= `buffer_size`, the flush quorum) distinct ids dispatched, self
/// included — and stamps the mask round with the dispatch-time model
/// version. Bounding the roster to the flush quorum keeps the live
/// announcement bytes in lock-step with the wire model's
/// `group = k_flush` charge ([`crate::strategy::wire`]); during warmup,
/// before `K` distinct clients have been seen, the roster is smaller
/// and the model is a slight over-charge. At each K-flush the server
/// fully unmasks every buffered update through the shared
/// [`crate::client::masking`] derivation and takes the unweighted mean.
/// Folds carry weight 1.0 — the engine's secagg composition rule — and
/// the unmasked individual updates are used for nothing but the mean
/// (honest-but-curious modeling; the full protocol replaces this with
/// secret-shared recovery).
pub struct SecAggAsync {
    plan: TrainingPlan,
    buffer_size: usize,
    base_seed: u64,
    /// The last `buffer_size` distinct dispatched ids, least recent
    /// first: the mask group announced to the next dispatch.
    active: VecDeque<String>,
    /// Per-client (mask round, announced peers) at its last dispatch —
    /// exactly what the client masked against, needed to invert it.
    announced: BTreeMap<String, (u64, Vec<String>)>,
    buffer: Vec<BufferedUpdate>,
}

/// One buffered masked result, carrying the (round, peers) announcement
/// snapshot taken when the result arrived. The live `announced` map is
/// overwritten when the streaming loop re-dispatches the same client
/// before the flush; unmasking from the snapshot — never the live map —
/// is what keeps the inversion aligned with the masks the client
/// actually applied.
struct BufferedUpdate {
    id: String,
    round: u64,
    peers: Vec<String>,
    res: FitRes,
}

impl SecAggAsync {
    pub fn new(plan: TrainingPlan, buffer_size: usize, base_seed: u64) -> Self {
        SecAggAsync {
            plan,
            buffer_size: buffer_size.max(1),
            base_seed,
            active: VecDeque::new(),
            announced: BTreeMap::new(),
            buffer: Vec::new(),
        }
    }

    /// Results currently waiting in the buffer.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    fn flush_buffer(&mut self) -> Result<Option<Parameters>> {
        if self.buffer.is_empty() {
            return Ok(None);
        }
        let mut acc: Vec<f64> = Vec::new();
        for BufferedUpdate { id, round, peers, res } in &self.buffer {
            let peer_refs: Vec<&str> = peers.iter().map(String::as_str).collect();
            let mut flat = res.parameters.to_flat_vec()?;
            // Exact inversion of the client's masking (grid arithmetic).
            unmask_update(&mut flat, id, &peer_refs, *round, self.base_seed);
            if acc.is_empty() {
                acc = vec![0f64; flat.len()];
            }
            if acc.len() != flat.len() {
                return Err(Error::Aggregation(
                    "secagg_async: parameter size mismatch".into(),
                ));
            }
            for (a, x) in acc.iter_mut().zip(&flat) {
                *a += *x as f64;
            }
        }
        let n = self.buffer.len() as f64;
        self.buffer.clear();
        Ok(Some(Parameters::from_flat(
            acc.into_iter().map(|x| (x / n) as f32).collect(),
        )))
    }
}

impl AsyncStrategy for SecAggAsync {
    fn name(&self) -> &'static str {
        "secagg_async"
    }

    fn buffer_size(&self) -> usize {
        self.buffer_size
    }

    fn configure_fit(
        &mut self,
        version: u64,
        parameters: &Parameters,
        handle: &ClientHandle,
    ) -> FitIns {
        // Move-to-back recency update, bounded by the flush quorum
        // (O(K) — the deque never exceeds `buffer_size` entries).
        if let Some(pos) = self.active.iter().position(|id| id == &handle.id) {
            self.active.remove(pos);
        }
        self.active.push_back(handle.id.clone());
        while self.active.len() > self.buffer_size {
            self.active.pop_front();
        }
        // Canonical (sorted) announcement order; the pairwise mask
        // algebra is order-independent, this just keeps the bytes on
        // the wire deterministic.
        let peers: Vec<String> = self.active.iter().cloned().collect::<BTreeSet<_>>()
            .into_iter()
            .collect();
        self.announced
            .insert(handle.id.clone(), (version, peers.clone()));
        let mut config = self.plan.to_config(version);
        config.insert(keys::SECAGG_PEERS.into(), Scalar::Str(encode_peer_list(&peers)));
        config.insert(keys::SECAGG_SEED.into(), Scalar::I64(self.base_seed as i64));
        FitIns { parameters: parameters.clone(), config }
    }

    fn on_fit_result(
        &mut self,
        handle: &ClientHandle,
        _staleness: u64,
        res: FitRes,
    ) -> Result<Option<Parameters>> {
        // Failed/empty results never carry masks (the client errored
        // before masking); folds are unweighted, so staleness is ignored.
        if !res.status.is_ok() || res.num_examples == 0 || res.parameters.is_empty() {
            return Ok(None);
        }
        // Snapshot the announcement *now*: by flush time the streaming
        // loop may have re-dispatched this client, overwriting the live
        // `announced` entry with a newer (round, peers) pair.
        let (round, peers) = self.announced.get(&handle.id).cloned().ok_or_else(|| {
            Error::Aggregation(format!(
                "secagg_async: result from {} without a dispatched mask set",
                handle.id
            ))
        })?;
        self.buffer
            .push(BufferedUpdate { id: handle.id.clone(), round, peers, res });
        if self.buffer.len() >= self.buffer_size {
            self.flush_buffer()
        } else {
            Ok(None)
        }
    }

    fn flush(&mut self) -> Result<Option<Parameters>> {
        self.flush_buffer()
    }

    fn configure_evaluate(
        &mut self,
        version: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        let config = crate::config! { keys::ROUND => version as i64 };
        (0..cohort.len())
            .map(|idx| {
                (
                    idx,
                    EvaluateIns { parameters: parameters.clone(), config: config.clone() },
                )
            })
            .collect()
    }

    fn aggregate_evaluate(
        &mut self,
        _version: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        weighted_eval_summary(results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::super::{Aggregator, FedAvg};
    use super::*;
    use crate::client::masking::{mask_update, quantize_to_grid};
    use crate::proto::scalar::ConfigExt;

    fn secagg() -> SecAgg {
        SecAgg::new(
            Box::new(FedAvg::new(TrainingPlan::default(), Aggregator::Rust)),
            777,
        )
    }

    /// The mean the server must reproduce: Σ quantized(update) / n,
    /// summed in f64 like the aggregator.
    fn grid_mean(rows: &[Vec<f32>]) -> Vec<f32> {
        let n = rows.len() as f64;
        (0..rows[0].len())
            .map(|j| {
                (rows
                    .iter()
                    .map(|v| quantize_to_grid(v[j]) as f64)
                    .sum::<f64>()
                    / n) as f32
            })
            .collect()
    }

    #[test]
    fn announces_cohort_and_seed() {
        let mut s = secagg();
        let cohort = handles(3);
        let plan = s.configure_fit(1, &Parameters::from_flat(vec![0.0]), &cohort);
        for (_, ins) in &plan {
            // plan order follows the inner strategy's sampling; compare as set
            let mut peers: Vec<&str> = ins
                .config
                .get_str(keys::SECAGG_PEERS)
                .unwrap()
                .split(',')
                .collect();
            peers.sort_unstable();
            assert_eq!(peers, vec!["c0", "c1", "c2"]);
            assert_eq!(ins.config.get_i64(keys::SECAGG_SEED).unwrap(), 777);
        }
    }

    #[test]
    fn masked_mean_equals_plain_mean_bit_exactly() {
        let mut s = secagg();
        let cohort = handles(3);
        let plan = s.configure_fit(4, &Parameters::from_flat(vec![0.0; 64]), &cohort);
        assert_eq!(plan.len(), 3);
        let peers: Vec<&str> = vec!["c0", "c1", "c2"];
        let plain: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..64).map(|j| (i + j) as f32 * 0.01).collect())
            .collect();
        let results: Vec<(ClientHandle, FitRes)> = (0..3)
            .map(|i| {
                let mut masked = plain[i].clone();
                mask_update(&mut masked, &cohort[i].id, &peers, 4, 777).unwrap();
                (cohort[i].clone(), fit_res(masked, 100, 1.0))
            })
            .collect();
        let agg = s.aggregate_fit(4, &results, 0).unwrap();
        let agg = agg.to_flat().unwrap();
        let want = grid_mean(&plain);
        for j in 0..64 {
            assert_eq!(
                agg[j].to_bits(),
                want[j].to_bits(),
                "j={j}: {} vs {}",
                agg[j],
                want[j]
            );
        }
    }

    #[test]
    fn dropout_recovers_via_residual_unmasking() {
        let mut s = secagg();
        let cohort = handles(3);
        let _ = s.configure_fit(2, &Parameters::from_flat(vec![0.0; 32]), &cohort);
        let peers: Vec<&str> = vec!["c0", "c1", "c2"];
        let plain: Vec<Vec<f32>> = (0..2)
            .map(|i| (0..32).map(|j| (j as f32 - i as f32) * 0.125).collect())
            .collect();
        // c2 was announced but never reports; c0 and c1 masked against it
        let results: Vec<(ClientHandle, FitRes)> = (0..2)
            .map(|i| {
                let mut masked = plain[i].clone();
                mask_update(&mut masked, &cohort[i].id, &peers, 2, 777).unwrap();
                (cohort[i].clone(), fit_res(masked, 100, 1.0))
            })
            .collect();
        let agg = s.aggregate_fit(2, &results, 1).unwrap();
        let agg = agg.to_flat().unwrap();
        let want = grid_mean(&plain); // mean over the 2 reporters only
        for j in 0..32 {
            assert_eq!(agg[j].to_bits(), want[j].to_bits(), "j={j}");
        }
    }

    /// Regression: the residual-unmask derivation must match
    /// `client::masking` for *arbitrary* string ids, not just the dense
    /// `c0`/`c1` test ids (a parallel server-side hash once diverged).
    #[test]
    fn dropout_recovery_with_arbitrary_string_ids() {
        use crate::device::profiles;
        let ids = ["edge node-π/7", "client:β", "Ω-unit_42"];
        let cohort: Vec<ClientHandle> = ids
            .iter()
            .map(|id| ClientHandle {
                id: id.to_string(),
                device: profiles::by_name("jetson_tx2_gpu").unwrap(),
                num_examples: 320,
            })
            .collect();
        let mut s = SecAgg::new(
            Box::new(FedAvg::new(TrainingPlan::default(), Aggregator::Rust)),
            0xDEAD_BEEF,
        );
        let _ = s.configure_fit(5, &Parameters::from_flat(vec![0.0; 16]), &cohort);
        let peers: Vec<&str> = ids.to_vec();
        let plain: Vec<Vec<f32>> = (0..2)
            .map(|i| (0..16).map(|j| (i * 16 + j) as f32 * 0.01).collect())
            .collect();
        let results: Vec<(ClientHandle, FitRes)> = (0..2)
            .map(|i| {
                let mut masked = plain[i].clone();
                mask_update(&mut masked, ids[i], &peers, 5, 0xDEAD_BEEF).unwrap();
                (cohort[i].clone(), fit_res(masked, 100, 1.0))
            })
            .collect();
        let agg = s.aggregate_fit(5, &results, 1).unwrap();
        let agg = agg.to_flat().unwrap();
        let want = grid_mean(&plain);
        for j in 0..16 {
            assert_eq!(agg[j].to_bits(), want[j].to_bits(), "j={j}");
        }
    }

    #[test]
    fn unknown_reporter_fails_the_round() {
        let mut s = secagg();
        let cohort = handles(3);
        let _ = s.configure_fit(1, &Parameters::from_flat(vec![0.0; 8]), &cohort[..2]);
        let results = vec![(cohort[2].clone(), fit_res(vec![0.0; 8], 10, 1.0))];
        let err = s.aggregate_fit(1, &results, 0).unwrap_err();
        assert!(err.to_string().contains("outside the announced cohort"), "{err}");
    }

    #[test]
    fn empty_round_errors() {
        let mut s = secagg();
        let cohort = handles(2);
        let _ = s.configure_fit(1, &Parameters::from_flat(vec![0.0; 8]), &cohort);
        assert!(s.aggregate_fit(1, &[], 2).is_err());
    }

    /// Ids containing commas (or percent signs) are externally supplied
    /// and must neither crash the server nor corrupt the roster: the
    /// CSV entries are percent-escaped end to end, and the masked mean
    /// still reproduces the plain mean bit-exactly through the real
    /// client-side decode path.
    #[test]
    fn comma_in_client_id_masks_and_aggregates_exactly() {
        use crate::client::masking::decode_peer_list;
        use crate::device::profiles;
        let ids = ["a,b", "50%", "plain"];
        let cohort: Vec<ClientHandle> = ids
            .iter()
            .map(|id| ClientHandle {
                id: id.to_string(),
                device: profiles::by_name("jetson_tx2_gpu").unwrap(),
                num_examples: 64,
            })
            .collect();
        let mut s = secagg();
        let plan = s.configure_fit(3, &Parameters::from_flat(vec![0.0; 16]), &cohort);
        assert_eq!(plan.len(), 3);
        let plain: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..16).map(|j| (i * 16 + j) as f32 * 0.02).collect())
            .collect();
        let results: Vec<(ClientHandle, FitRes)> = plan
            .iter()
            .map(|(idx, ins)| {
                // the client decodes the roster exactly as MaskedClient does
                let decoded =
                    decode_peer_list(ins.config.get_str(keys::SECAGG_PEERS).unwrap());
                let peers: Vec<&str> = decoded.iter().map(String::as_str).collect();
                assert_eq!(peers.len(), 3, "roster must frame comma ids safely");
                let mut masked = plain[*idx].clone();
                mask_update(&mut masked, ids[*idx], &peers, 3, 777).unwrap();
                (cohort[*idx].clone(), fit_res(masked, 100, 1.0))
            })
            .collect();
        let agg = s.aggregate_fit(3, &results, 0).unwrap();
        let agg = agg.to_flat().unwrap();
        let want = grid_mean(&plain);
        for j in 0..16 {
            assert_eq!(agg[j].to_bits(), want[j].to_bits(), "j={j}");
        }
    }

    #[test]
    fn async_flush_unmasks_and_averages_bit_exactly() {
        let mut s = SecAggAsync::new(TrainingPlan::default(), 2, 99);
        let h = handles(3);
        // dispatch all three (mask group grows as they are seen)
        let ins: Vec<FitIns> = (0..3)
            .map(|i| s.configure_fit(i as u64, &Parameters::from_flat(vec![0.0; 24]), &h[i]))
            .collect();
        let plain: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..24).map(|j| (i as f32 + 1.0) * 0.25 + j as f32 * 0.01).collect())
            .collect();
        // clients mask exactly as MaskedClient would: against the peers
        // and round each was *told* at dispatch time
        let masked: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let peers_csv = ins[i].config.get_str(keys::SECAGG_PEERS).unwrap();
                let peers: Vec<&str> = peers_csv.split(',').collect();
                let round = ins[i].config.get_i64(keys::ROUND).unwrap() as u64;
                let seed = ins[i].config.get_i64(keys::SECAGG_SEED).unwrap() as u64;
                let mut v = plain[i].clone();
                mask_update(&mut v, &h[i].id, &peers, round, seed).unwrap();
                v
            })
            .collect();
        assert!(s
            .on_fit_result(&h[0], 0, fit_res(masked[0].clone(), 10, 1.0))
            .unwrap()
            .is_none());
        let p = s
            .on_fit_result(&h[1], 1, fit_res(masked[1].clone(), 10, 1.0))
            .unwrap()
            .expect("second result fills the K=2 buffer");
        let got = p.to_flat().unwrap();
        let want = grid_mean(&plain[..2]);
        for j in 0..24 {
            assert_eq!(got[j].to_bits(), want[j].to_bits(), "j={j}");
        }
        // the third result starts the next buffer
        assert!(s
            .on_fit_result(&h[2], 0, fit_res(masked[2].clone(), 10, 1.0))
            .unwrap()
            .is_none());
        assert_eq!(s.buffered(), 1);
        let p = s.flush().unwrap().expect("partial buffer force-flushes");
        let got = p.to_flat().unwrap();
        let want = grid_mean(&plain[2..]);
        for j in 0..24 {
            assert_eq!(got[j].to_bits(), want[j].to_bits(), "j={j}");
        }
    }

    /// Regression for the stale-announcement bug: a result is buffered,
    /// then the streaming loop re-dispatches the same client (a later
    /// round, a different roster) before the flush. Unmasking must use
    /// the (round, peers) snapshot taken when the result was buffered —
    /// the live `announced` map now describes masks the buffered update
    /// never wore.
    #[test]
    fn flush_unmasks_buffered_result_despite_redispatch() {
        let mut s = SecAggAsync::new(TrainingPlan::default(), 2, 41);
        let h = handles(3);
        let p0 = Parameters::from_flat(vec![0.0; 24]);
        let mask_per = |ins: &FitIns, id: &str, plain: &[f32]| -> Vec<f32> {
            let decoded = crate::client::masking::decode_peer_list(
                ins.config.get_str(keys::SECAGG_PEERS).unwrap(),
            );
            let peers: Vec<&str> = decoded.iter().map(String::as_str).collect();
            let round = ins.config.get_i64(keys::ROUND).unwrap() as u64;
            let mut v = plain.to_vec();
            mask_update(&mut v, id, &peers, round, 41).unwrap();
            v
        };
        let ins0 = s.configure_fit(0, &p0, &h[0]);
        let ins1 = s.configure_fit(0, &p0, &h[1]); // roster {c0, c1}, round 0
        let plain: Vec<Vec<f32>> = (0..2)
            .map(|i| (0..24).map(|j| (i as f32 + 1.0) * 0.5 + j as f32 * 0.01).collect())
            .collect();
        // c1's result arrives first and is buffered (1 < K=2)
        let masked1 = mask_per(&ins1, &h[1].id, &plain[1]);
        assert!(s.on_fit_result(&h[1], 0, fit_res(masked1, 10, 1.0)).unwrap().is_none());
        // the loop re-dispatches c1 at a later version, and a new client
        // rotates the roster: announced[c1] is overwritten with
        // (round 5, {c1, c2}) — neither matches the buffered masks
        let _ins2 = s.configure_fit(3, &p0, &h[2]);
        let ins1b = s.configure_fit(5, &p0, &h[1]);
        assert_ne!(
            ins1b.config.get_str(keys::SECAGG_PEERS).unwrap(),
            ins1.config.get_str(keys::SECAGG_PEERS).unwrap(),
            "precondition: the re-dispatch must announce a different roster"
        );
        // c0's buffered result fills the quorum → flush must be bit-exact
        let masked0 = mask_per(&ins0, &h[0].id, &plain[0]);
        let p = s
            .on_fit_result(&h[0], 5, fit_res(masked0, 10, 1.0))
            .unwrap()
            .expect("second result fills the K=2 buffer");
        let got = p.to_flat().unwrap();
        let want = grid_mean(&plain);
        for j in 0..24 {
            assert_eq!(got[j].to_bits(), want[j].to_bits(), "j={j}");
        }
    }

    /// The announced roster is the *active* mask group: bounded by the
    /// flush quorum K, so live announcement bytes match the wire
    /// model's `group = k_flush` charge instead of growing with the
    /// whole population.
    #[test]
    fn async_roster_is_bounded_by_flush_quorum() {
        use crate::device::profiles;
        let k = 3;
        let mut s = SecAggAsync::new(TrainingPlan::default(), k, 7);
        let p0 = Parameters::from_flat(vec![0.0; 4]);
        for i in 0..20 {
            let h = ClientHandle {
                id: format!("dev-{i}"),
                device: profiles::by_name("jetson_tx2_gpu").unwrap(),
                num_examples: 32,
            };
            let ins = s.configure_fit(i, &p0, &h);
            let peers = ins.config.get_str(keys::SECAGG_PEERS).unwrap();
            let n = peers.split(',').count();
            assert!(n <= k, "dispatch {i}: roster has {n} entries > K={k}");
            assert!(
                peers.split(',').any(|p| p == h.id),
                "dispatch {i}: roster must include self"
            );
            if i as usize >= k {
                assert_eq!(n, k, "steady state announces exactly K entries");
            }
        }
    }

    #[test]
    fn async_result_without_dispatch_errors() {
        let mut s = SecAggAsync::new(TrainingPlan::default(), 2, 1);
        let h = handles(1);
        let err = s
            .on_fit_result(&h[0], 0, fit_res(vec![1.0], 10, 1.0))
            .unwrap_err();
        assert!(err.to_string().contains("without a dispatched mask set"), "{err}");
    }
}
