//! Bytes-on-wire model for the strategy zoo.
//!
//! The engine's cost model charges communication time/energy per
//! dispatched byte, but *how many* bytes a round moves depends on the
//! strategy: f16 compression halves the payload, secure aggregation
//! adds a mask-exchange handshake on top of the model, and the plain
//! averaging strategies ship the raw f32 tensor both ways. This module
//! is the single place that mapping lives, so the engine, the live
//! server, the obs ledger, and the Python differential port all agree
//! byte-for-byte.
//!
//! Framing constants are derived from `transport/PROTOCOL.md` (wire
//! v2): a frame is a `len:u32` prefix plus payload, and a v2 message
//! carries `magic:u16 version:u8 tag:u8 header_len:u32` before the
//! header. The *baseline* strategies deliberately count **payload
//! bytes only** (`model_bytes` each way) — that keeps the default
//! cost trajectory bit-identical to the pre-strategy engine and to the
//! committed golden traces. Only secagg's extra exchange is framed,
//! because it is genuinely extra traffic that the baseline never sends.
//!
//! Everything here is integer arithmetic: no floats, no rounding
//! ambiguity, trivially mirrored in `python/tools/trace_engine_port.py`.

use crate::config::SchedStrategyConfig;

/// Frame length prefix (`len:u32-LE`), per `transport/PROTOCOL.md`.
pub const FRAME_PREFIX_BYTES: u64 = 4;
/// Fixed v2 message overhead: `magic:u16 + version:u8 + tag:u8 + header_len:u32`.
pub const V2_MSG_OVERHEAD_BYTES: u64 = 8;
/// One peer entry in the secagg mask-exchange roster: an 8-byte id
/// hash plus a 1-byte liveness flag.
pub const SECAGG_PEER_ENTRY_BYTES: u64 = 9;
/// The per-round seed material the server ships down with the roster:
/// base seed (8) + round nonce (8) + grid scale (8).
pub const SECAGG_SEED_ENTRY_BYTES: u64 = 24;
/// The client's upload commitment (a 32-byte digest of its masked
/// update, checked server-side before unmasking).
pub const SECAGG_COMMIT_BYTES: u64 = 32;

/// f16 uplink/downlink payload: exactly half the f32 bytes, rounded up
/// (an odd f32 byte count cannot happen for whole tensors, but the
/// model stays total).
pub fn f16_payload_bytes(model_bytes: u64) -> u64 {
    model_bytes.div_ceil(2)
}

/// Extra downlink bytes secagg adds per dispatch: one framed v2
/// message carrying the seed material and the peer roster for the
/// mask-exchange group.
pub fn secagg_down_overhead_bytes(group: u64) -> u64 {
    FRAME_PREFIX_BYTES + V2_MSG_OVERHEAD_BYTES + SECAGG_SEED_ENTRY_BYTES + group * SECAGG_PEER_ENTRY_BYTES
}

/// Extra uplink bytes secagg adds per fold: one framed v2 message
/// carrying the masked-update commitment.
pub fn secagg_up_overhead_bytes() -> u64 {
    FRAME_PREFIX_BYTES + V2_MSG_OVERHEAD_BYTES + SECAGG_COMMIT_BYTES
}

/// Per-dispatch wire traffic for one strategy: bytes the server ships
/// to a client (`bytes_down`) and bytes the client ships back
/// (`bytes_up`). Derived once per run from the strategy config, the
/// model size, and the mask-exchange group size (the sync cohort or
/// the async flush quorum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireModel {
    pub bytes_down: u64,
    pub bytes_up: u64,
}

impl WireModel {
    /// The symmetric f32 baseline (FedAvg/FedBuff/qFedAvg/FedProx):
    /// the full model down, the full update up.
    pub fn baseline(model_bytes: u64) -> WireModel {
        WireModel { bytes_down: model_bytes, bytes_up: model_bytes }
    }

    /// Wire model for `strategy`. `group` is the number of peers in a
    /// secagg mask-exchange group — the cohort size in sync mode, the
    /// flush quorum (`k_flush`) in async mode; ignored by every other
    /// strategy. The live async protocol keeps these books honest:
    /// `SecAggAsync` bounds its announced roster to the flush quorum
    /// (most-recent `k_flush` distinct clients), so the modeled
    /// `group · SECAGG_PEER_ENTRY_BYTES` downlink charge matches the
    /// steady-state roster instead of underestimating an ever-growing
    /// one (during warmup the live roster is smaller; the model is a
    /// slight over-charge, never an under-charge).
    pub fn for_strategy(strategy: &SchedStrategyConfig, model_bytes: u64, group: u64) -> WireModel {
        match strategy {
            // Reweighting strategies change fold *weights*, not payloads.
            SchedStrategyConfig::FedAvg
            | SchedStrategyConfig::QFedAvg { .. }
            | SchedStrategyConfig::FedProx { .. } => WireModel::baseline(model_bytes),
            SchedStrategyConfig::Compressed => WireModel {
                bytes_down: f16_payload_bytes(model_bytes),
                bytes_up: f16_payload_bytes(model_bytes),
            },
            SchedStrategyConfig::SecAgg => WireModel {
                bytes_down: model_bytes + secagg_down_overhead_bytes(group),
                bytes_up: model_bytes + secagg_up_overhead_bytes(),
            },
        }
    }

    /// Total round-trip bytes for one dispatch+fold.
    pub fn round_trip(&self) -> u64 {
        self.bytes_down + self.bytes_up
    }

    /// The cloud↔edge leg of a two-tier topology (`--edges N`): the
    /// cloud ships the full f32 model to each edge once per version,
    /// and each edge ships one full f32 pre-aggregated delta upstream
    /// per merge/quorum ship. Strategy shaping (f16, secagg) applies to
    /// the *device* leg only — an edge aggregator folds decompressed
    /// updates and cannot forward masked ones, so its upstream leg is
    /// always the plain baseline. See `sched/TOPOLOGY.md`.
    pub fn edge_leg(model_bytes: u64) -> WireModel {
        WireModel::baseline(model_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 547_496; // the paper's 547 KB CIFAR-10 model

    #[test]
    fn baseline_is_symmetric_full_precision() {
        for s in [
            SchedStrategyConfig::FedAvg,
            SchedStrategyConfig::QFedAvg { q: 1.0 },
            SchedStrategyConfig::FedProx { mu: 0.01 },
        ] {
            let w = WireModel::for_strategy(&s, MB, 8);
            assert_eq!(w, WireModel::baseline(MB), "{s:?}");
            assert_eq!(w.round_trip(), 2 * MB);
        }
    }

    #[test]
    fn compressed_halves_both_directions() {
        let w = WireModel::for_strategy(&SchedStrategyConfig::Compressed, MB, 8);
        assert_eq!(w.bytes_down, MB / 2);
        assert_eq!(w.bytes_up, MB / 2);
        // odd payload rounds up, never truncates
        let odd = WireModel::for_strategy(&SchedStrategyConfig::Compressed, 7, 8);
        assert_eq!(odd.bytes_down, 4);
    }

    #[test]
    fn secagg_overhead_scales_with_group() {
        let w8 = WireModel::for_strategy(&SchedStrategyConfig::SecAgg, MB, 8);
        let w9 = WireModel::for_strategy(&SchedStrategyConfig::SecAgg, MB, 9);
        assert_eq!(w9.bytes_down - w8.bytes_down, SECAGG_PEER_ENTRY_BYTES);
        assert_eq!(w8.bytes_up, MB + FRAME_PREFIX_BYTES + V2_MSG_OVERHEAD_BYTES + SECAGG_COMMIT_BYTES);
        assert_eq!(
            w8.bytes_down,
            MB + FRAME_PREFIX_BYTES + V2_MSG_OVERHEAD_BYTES + SECAGG_SEED_ENTRY_BYTES + 8 * SECAGG_PEER_ENTRY_BYTES
        );
    }

    #[test]
    fn edge_leg_is_strategy_independent() {
        // The cloud↔edge leg is always the full f32 baseline, even when
        // the device leg is compressed or masked.
        assert_eq!(WireModel::edge_leg(MB), WireModel::baseline(MB));
        let device = WireModel::for_strategy(&SchedStrategyConfig::Compressed, MB, 8);
        assert!(WireModel::edge_leg(MB).round_trip() > device.round_trip());
    }

    #[test]
    fn constants_match_protocol_doc() {
        // PROTOCOL.md: frame = len:u32 prefix; v2 msg = magic u16 +
        // version u8 + tag u8 + header_len u32 = 8 bytes.
        assert_eq!(FRAME_PREFIX_BYTES, 4);
        assert_eq!(V2_MSG_OVERHEAD_BYTES, 8);
        assert_eq!(secagg_up_overhead_bytes(), 44);
    }
}
