//! Federated strategies — the pluggable server-side brain of Flower.
//!
//! The paper (§3): "The FL loop is at the heart of the FL process: it
//! orchestrates the learning process ... It does not, however, make
//! decisions about *how* to proceed, those decisions are delegated to the
//! currently configured *Strategy*."
//!
//! Implementations:
//! * [`fedavg::FedAvg`] — McMahan et al. 2017, the paper's baseline.
//! * [`fedavg_cutoff::FedAvgCutoff`] — the paper's contribution (Table 3):
//!   per-processor cutoff time τ after which a client must return partial
//!   results.
//! * [`fedprox::FedProx`] — Li et al. 2018, the related partial-work
//!   strategy the paper compares its idea to.
//! * [`fedavgm::FedAvgM`] — server momentum on the aggregated update.
//! * [`qfedavg::QFedAvg`] — fairness-reweighted aggregation (ablation).
//! * [`fedbuff::FedBuff`] — buffered *asynchronous* aggregation
//!   (Nguyen et al. 2022) behind the [`AsyncStrategy`] surface: no round
//!   barrier, staleness-discounted folds, a model version per flush.

pub mod aggregate;
pub mod compressed;
pub mod fedavg;
pub mod fedavg_cutoff;
pub mod fedavgm;
pub mod fedbuff;
pub mod fedprox;
pub mod qfedavg;
pub mod secagg;
pub mod wire;

pub use aggregate::Aggregator;
pub use compressed::{QuantizedComm, QuantizedCommAsync};
pub use fedavg::FedAvg;
pub use fedavg_cutoff::FedAvgCutoff;
pub use fedavgm::FedAvgM;
pub use fedbuff::FedBuff;
pub use fedprox::{FedProx, FedProxBuff};
pub use qfedavg::{QFedAvg, QFedAvgBuff};
pub use secagg::{SecAgg, SecAggAsync};

use crate::device::DeviceProfile;
use crate::error::Result;
use crate::proto::{EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters};

/// What a strategy knows about a connected client (identity + device
/// class + data size). Cheap to clone; derived from the Register message.
#[derive(Debug, Clone)]
pub struct ClientHandle {
    pub id: String,
    pub device: &'static DeviceProfile,
    pub num_examples: u64,
}

/// Aggregated federated-evaluation outcome for one round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalSummary {
    pub loss: f64,
    pub accuracy: f64,
    pub num_examples: u64,
}

/// The server delegates all *decisions* here; it owns only the mechanics.
///
/// `configure_*` returns `(cohort_index, instructions)` pairs — the subset
/// of clients to contact this round and what to tell each one.
pub trait Strategy: Send {
    fn name(&self) -> &'static str;

    /// Select and configure clients for a round of training.
    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)>;

    /// Fold successful fit results into new global parameters.
    fn aggregate_fit(
        &mut self,
        round: u64,
        results: &[(ClientHandle, FitRes)],
        failures: usize,
    ) -> Result<Parameters>;

    /// Select and configure clients for federated evaluation.
    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)>;

    /// Fold evaluation results into a round summary.
    fn aggregate_evaluate(
        &mut self,
        round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary>;
}

/// The server-side brain of an *asynchronous* FL loop.
///
/// Where [`Strategy`] thinks in barrier-synchronous rounds (configure a
/// cohort, wait for everyone, aggregate), an `AsyncStrategy` is fed fit
/// results **one at a time, as they arrive**. It buffers them and emits
/// new global parameters whenever its buffer fills — each emission is one
/// *model version*. The caller (the async server loop or the population
/// engine's async mode) tracks which version every in-flight client
/// started from and reports the *staleness* `current_version -
/// base_version` alongside each result.
pub trait AsyncStrategy: Send {
    fn name(&self) -> &'static str;

    /// Buffer size K: successful results folded per model-version flush.
    fn buffer_size(&self) -> usize;

    /// Instructions for one fit dispatch to `handle`, training from the
    /// `version`-th global parameters.
    fn configure_fit(
        &mut self,
        version: u64,
        parameters: &Parameters,
        handle: &ClientHandle,
    ) -> FitIns;

    /// Fold one arrived result. Returns `Some(new_parameters)` when this
    /// result filled the buffer (a flush — the model version advances),
    /// `None` while the buffer is still filling.
    fn on_fit_result(
        &mut self,
        handle: &ClientHandle,
        staleness: u64,
        res: FitRes,
    ) -> Result<Option<Parameters>>;

    /// Force-flush a partially full buffer. `None` if empty. The built-in
    /// loops never need this — they stop only at flush boundaries, where
    /// the buffer is empty by construction — it exists for callers that
    /// stop mid-window (checkpointing, preemption).
    fn flush(&mut self) -> Result<Option<Parameters>>;

    /// Select and configure clients for federated evaluation of a freshly
    /// flushed model version.
    fn configure_evaluate(
        &mut self,
        version: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)>;

    /// Fold evaluation results into a summary for one model version.
    fn aggregate_evaluate(
        &mut self,
        version: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary>;
}

/// Weighted mean of evaluation results (shared by every strategy here).
pub fn weighted_eval_summary(results: &[(ClientHandle, EvaluateRes)]) -> Result<EvalSummary> {
    use crate::client::keys;
    use crate::proto::scalar::ConfigExt;

    let mut loss = 0f64;
    let mut acc = 0f64;
    let mut n = 0u64;
    for (_, res) in results {
        if !res.status.is_ok() || res.num_examples == 0 {
            continue;
        }
        let w = res.num_examples as f64;
        loss += res.loss * w;
        acc += res.metrics.get_f64_or(keys::ACCURACY, 0.0) * w;
        n += res.num_examples;
    }
    if n == 0 {
        return Err(crate::Error::Aggregation(
            "no successful evaluation results".into(),
        ));
    }
    Ok(EvalSummary {
        loss: loss / n as f64,
        accuracy: acc / n as f64,
        num_examples: n,
    })
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::device::profiles;
    use crate::proto::{ConfigMap, Scalar, Status};

    pub fn handles(n: usize) -> Vec<ClientHandle> {
        (0..n)
            .map(|i| ClientHandle {
                id: format!("c{i}"),
                device: profiles::by_name("jetson_tx2_gpu").unwrap(),
                num_examples: 320,
            })
            .collect()
    }

    pub fn fit_res(params: Vec<f32>, num_examples: u64, train_loss: f64) -> FitRes {
        let mut metrics = ConfigMap::new();
        metrics.insert(
            crate::client::keys::TRAIN_LOSS.into(),
            Scalar::F64(train_loss),
        );
        FitRes {
            status: Status::ok(),
            parameters: Parameters::from_flat(params),
            num_examples,
            metrics,
        }
    }

    pub fn eval_res(loss: f64, accuracy: f64, num_examples: u64) -> EvaluateRes {
        let mut metrics = ConfigMap::new();
        metrics.insert(crate::client::keys::ACCURACY.into(), Scalar::F64(accuracy));
        EvaluateRes {
            status: Status::ok(),
            loss,
            num_examples,
            metrics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn eval_summary_weights_by_examples() {
        let h = handles(2);
        let results = vec![
            (h[0].clone(), eval_res(1.0, 0.5, 100)),
            (h[1].clone(), eval_res(3.0, 1.0, 300)),
        ];
        let s = weighted_eval_summary(&results).unwrap();
        assert!((s.loss - 2.5).abs() < 1e-9);
        assert!((s.accuracy - 0.875).abs() < 1e-9);
        assert_eq!(s.num_examples, 400);
    }

    #[test]
    fn eval_summary_skips_failures() {
        use crate::proto::{Status, StatusCode};
        let h = handles(2);
        let mut bad = eval_res(9.0, 0.0, 100);
        bad.status = Status { code: StatusCode::EvaluateError, message: "x".into() };
        let results = vec![
            (h[0].clone(), bad),
            (h[1].clone(), eval_res(1.0, 0.9, 100)),
        ];
        let s = weighted_eval_summary(&results).unwrap();
        assert!((s.loss - 1.0).abs() < 1e-9);
        assert_eq!(s.num_examples, 100);
    }

    #[test]
    fn eval_summary_errors_when_empty() {
        assert!(weighted_eval_summary(&[]).is_err());
    }
}
