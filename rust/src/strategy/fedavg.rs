//! FedAvg (McMahan et al. 2017): sample a fraction of clients, run E local
//! epochs each, average parameters weighted by examples processed. The
//! baseline strategy for all of the paper's experiments.

use crate::client::keys;
use crate::config;
use crate::error::Result;
use crate::proto::{ConfigMap, EvaluateIns, EvaluateRes, FitIns, FitRes, Parameters};
use crate::sched::policy::UniformRandom;

use super::{
    weighted_eval_summary, Aggregator, ClientHandle, EvalSummary, Strategy,
};

/// Per-round training hyper-parameters broadcast to clients.
#[derive(Debug, Clone)]
pub struct TrainingPlan {
    pub epochs: i64,
    pub lr: f64,
}

impl Default for TrainingPlan {
    fn default() -> Self {
        TrainingPlan { epochs: 1, lr: 0.05 }
    }
}

impl TrainingPlan {
    pub fn to_config(&self, round: u64) -> ConfigMap {
        config! {
            keys::EPOCHS => self.epochs,
            keys::LR => self.lr,
            keys::ROUND => round as i64,
        }
    }
}

/// The federated averaging strategy.
pub struct FedAvg {
    pub plan: TrainingPlan,
    /// Fraction of available clients trained per round (paper uses 1.0).
    pub fraction_fit: f64,
    /// Lower bound on per-round cohort size.
    pub min_fit_clients: usize,
    pub aggregator: Aggregator,
    /// The uniform cohort sampler, shared with the `sched` subsystem
    /// (`sched::policy::UniformRandom` is FedAvg's original sampling,
    /// extracted so server hooks and the population engine reuse it).
    sampler: UniformRandom,
}

impl FedAvg {
    pub fn new(plan: TrainingPlan, aggregator: Aggregator) -> Self {
        FedAvg {
            plan,
            fraction_fit: 1.0,
            min_fit_clients: 1,
            aggregator,
            // Same stream FedAvg drew from before the sampler was
            // extracted, so historical seeded cohorts reproduce exactly.
            sampler: UniformRandom::new(0x5A3D),
        }
    }

    pub fn with_fraction(mut self, fraction_fit: f64, min_fit_clients: usize) -> Self {
        self.fraction_fit = fraction_fit;
        self.min_fit_clients = min_fit_clients;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.sampler = UniformRandom::new(seed);
        self
    }

    /// Sample the round's cohort indices.
    fn sample(&mut self, n: usize) -> Vec<usize> {
        let want = ((n as f64 * self.fraction_fit).ceil() as usize)
            .clamp(self.min_fit_clients.min(n), n);
        self.sampler.pick(n, want)
    }

    /// Weighted parameter average over successful results — the shared
    /// heart of every FedAvg-family strategy in this crate.
    pub(crate) fn average(
        &self,
        results: &[(ClientHandle, FitRes)],
        weight_fn: impl Fn(&ClientHandle, &FitRes) -> f64,
    ) -> Result<Parameters> {
        weighted_parameter_average(
            &self.aggregator,
            results.iter().map(|(h, r)| (r, weight_fn(h, r))),
        )
    }
}

/// Weighted parameter average over `(result, weight)` pairs, skipping
/// failed/empty results and non-positive weights. Extracted from
/// [`FedAvg::average`] so the synchronous FedAvg family and the
/// [`crate::strategy::FedBuff`] flush share one arithmetic path —
/// FedBuff with zero staleness is bit-identical to FedAvg because both
/// funnel through here.
///
/// This is a thin wire-level adapter: the audited numeric kernel
/// underneath is [`Aggregator::weighted_average`], which is also what
/// the population engine's
/// [`crate::sim::population::RuntimeCohortTrainer`] calls directly on
/// raw parameter vectors. Every weighted mean in the crate — sync
/// round, async flush, engine cohort — reduces to that one kernel; no
/// parallel averaging arithmetic exists to drift.
pub(crate) fn weighted_parameter_average<'a>(
    aggregator: &Aggregator,
    results: impl IntoIterator<Item = (&'a FitRes, f64)>,
) -> Result<Parameters> {
    let mut inputs: Vec<(&[f32], f64)> = Vec::new();
    for (res, w) in results {
        if !res.status.is_ok() || res.num_examples == 0 {
            continue;
        }
        if w <= 0.0 {
            continue;
        }
        inputs.push((res.parameters.to_flat()?, w));
    }
    let flat = aggregator.weighted_average(&inputs)?;
    Ok(Parameters::from_flat(flat))
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn configure_fit(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, FitIns)> {
        let config = self.plan.to_config(round);
        self.sample(cohort.len())
            .into_iter()
            .map(|idx| {
                (
                    idx,
                    FitIns { parameters: parameters.clone(), config: config.clone() },
                )
            })
            .collect()
    }

    fn aggregate_fit(
        &mut self,
        _round: u64,
        results: &[(ClientHandle, FitRes)],
        _failures: usize,
    ) -> Result<Parameters> {
        self.average(results, |_, res| res.num_examples as f64)
    }

    fn configure_evaluate(
        &mut self,
        round: u64,
        parameters: &Parameters,
        cohort: &[ClientHandle],
    ) -> Vec<(usize, EvaluateIns)> {
        let config = config! { keys::ROUND => round as i64 };
        (0..cohort.len())
            .map(|idx| {
                (
                    idx,
                    EvaluateIns { parameters: parameters.clone(), config: config.clone() },
                )
            })
            .collect()
    }

    fn aggregate_evaluate(
        &mut self,
        _round: u64,
        results: &[(ClientHandle, EvaluateRes)],
    ) -> Result<EvalSummary> {
        weighted_eval_summary(results)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;
    use crate::proto::scalar::ConfigExt;

    fn strategy() -> FedAvg {
        FedAvg::new(TrainingPlan { epochs: 5, lr: 0.1 }, Aggregator::Rust)
    }

    #[test]
    fn configure_fit_selects_all_by_default() {
        let mut s = strategy();
        let cohort = handles(10);
        let plan = s.configure_fit(1, &Parameters::from_flat(vec![0.0; 4]), &cohort);
        assert_eq!(plan.len(), 10);
        let (_, ins) = &plan[0];
        assert_eq!(ins.config.get_i64(keys::EPOCHS).unwrap(), 5);
        assert_eq!(ins.config.get_f64(keys::LR).unwrap(), 0.1);
        assert_eq!(ins.config.get_i64(keys::ROUND).unwrap(), 1);
    }

    #[test]
    fn fraction_fit_subsamples() {
        let mut s = strategy().with_fraction(0.4, 2);
        let cohort = handles(10);
        let plan = s.configure_fit(1, &Parameters::from_flat(vec![0.0]), &cohort);
        assert_eq!(plan.len(), 4);
        let mut idxs: Vec<usize> = plan.iter().map(|(i, _)| *i).collect();
        idxs.sort_unstable();
        idxs.dedup();
        assert_eq!(idxs.len(), 4, "indices must be distinct");
    }

    #[test]
    fn aggregate_weights_by_examples() {
        let mut s = strategy();
        let h = handles(2);
        let results = vec![
            (h[0].clone(), fit_res(vec![0.0, 0.0], 100, 1.0)),
            (h[1].clone(), fit_res(vec![1.0, 2.0], 300, 1.0)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert_eq!(p.to_flat().unwrap(), &[0.75, 1.5]);
    }

    #[test]
    fn aggregate_skips_failed_and_empty() {
        use crate::proto::{Status, StatusCode};
        let mut s = strategy();
        let h = handles(3);
        let mut bad = fit_res(vec![9.0, 9.0], 100, 1.0);
        bad.status = Status { code: StatusCode::FitError, message: "oom".into() };
        let empty = fit_res(vec![5.0, 5.0], 0, 1.0);
        let results = vec![
            (h[0].clone(), bad),
            (h[1].clone(), empty),
            (h[2].clone(), fit_res(vec![1.0, 1.0], 10, 1.0)),
        ];
        let p = s.aggregate_fit(1, &results, 0).unwrap();
        assert_eq!(p.to_flat().unwrap(), &[1.0, 1.0]);
    }

    #[test]
    fn aggregate_errors_with_no_results() {
        let mut s = strategy();
        assert!(s.aggregate_fit(1, &[], 3).is_err());
    }

}
