//! Persistent checkpoint / resume for long-running federations.
//!
//! The paper's setting is flaky edge populations coordinated by one
//! server — and a coordinator that loses its global model state on a
//! restart is itself the weakest device in the federation. This
//! subsystem makes server-side state durable:
//!
//! * [`format`](self) — a versioned, CRC-guarded section container
//!   ([`CheckpointWriter`] / [`CheckpointReader`]) written atomically
//!   (temp file → fsync → rename), plus the [`CheckpointStore`]
//!   directory protocol that always resolves to the newest *valid*
//!   file. A truncation at any byte offset fails to load cleanly —
//!   property-tested in `rust/tests/persist_e2e.rs`. The byte layout is
//!   documented in `rust/src/persist/FORMAT.md`.
//! * [`EngineCheckpoint`] — a [`crate::sched::Engine`] snapshot at a
//!   flush boundary: per-device scheduler history, policy RNG position,
//!   trainer numerics, virtual clocks, the in-flight dispatch manifest
//!   and the exact availability-index state. Kill a sync or async
//!   engine run at round *k*, resume it, and the selection / accuracy
//!   trace is **bit-identical** to the uninterrupted run.
//! * [`ServerCheckpoint`] — the live server's durable state
//!   (parameters, [`crate::server::History`], whole-run
//!   [`crate::server::AsyncStats`], selection-hook observations and
//!   the selection policy's RNG position). In-flight exchanges are
//!   real threads, so a resumed server re-dispatches instead of
//!   restoring them; resume refuses a sync/async mode flip or a
//!   parameter-shape mismatch.
//!
//! Wiring: `checkpoint_dir` / `checkpoint_every_rounds` / `resume_from`
//! knobs on [`crate::config::ExperimentConfig`],
//! [`crate::config::ScheduleConfig`] and
//! [`crate::server::ServerConfig`]; `--checkpoint-dir` /
//! `--checkpoint-every` / `--resume` flags on `flowrs sim` and
//! `flowrs sched`; and `flowrs ckpt inspect <file|dir>` pretty-prints a
//! checkpoint's header and round summary.
#![deny(missing_docs)]

mod format;
mod state;

pub use format::{
    crc32, CheckpointKind, CheckpointReader, CheckpointStore, CheckpointWriter, EXTENSION,
    FOOTER, FORMAT_VERSION, MAGIC,
};
pub use state::{
    decode_population_rounds, decode_round_records, load_engine_checkpoint,
    load_server_checkpoint, resolve_checkpoint, ClientStatRecord, DeviceState, EdgeParkedFold,
    EdgeTierState, EngineCheckpoint, InFlightDispatch, ParamTensor, ServerCheckpoint, ShardSeeds,
};

pub(crate) use format::{Dec, Enc};
