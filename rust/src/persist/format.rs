//! The on-disk checkpoint container: a versioned, CRC-guarded section
//! file written atomically (temp file → fsync → rename), plus the
//! directory protocol ([`CheckpointStore`]) that always resolves to the
//! newest *valid* checkpoint.
//!
//! Layout (all integers little-endian; see `rust/src/persist/FORMAT.md`
//! for the normative description and the version-bump policy):
//!
//! ```text
//! file    := header section* footer
//! header  := magic[8]="FLWRCKPT" format_version:u32 kind[4]
//!            rounds_completed:u64 section_count:u32 header_crc32:u32
//! section := tag[4] payload_len:u64 crc32:u32 payload[payload_len]
//! footer  := "FLWREND1"
//! ```
//!
//! Every byte of the file is covered by a checksum or a sentinel: the
//! header by `header_crc32`, each section (tag + length + payload) by
//! its `crc32`, and the end of the byte stream by the footer. A
//! truncation at *any* offset therefore fails to load — either a short
//! read, a checksum mismatch, or a missing footer — which is exactly
//! the crash-window guarantee the resume path depends on (locked by a
//! property test in `rust/tests/persist_e2e.rs`).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::telemetry::log;
use crate::util::bytes::{LeReader, LeWriter};

/// Leading file magic: any file not starting with these 8 bytes is not
/// a flowrs checkpoint.
pub const MAGIC: [u8; 8] = *b"FLWRCKPT";

/// Trailing sentinel: a file that parses to the end but does not close
/// with these 8 bytes was truncated mid-write.
pub const FOOTER: [u8; 8] = *b"FLWREND1";

/// The container-format version this build writes (and the newest it
/// reads). Bump only on incompatible layout changes — adding a new
/// *section* is forward-compatible because readers ignore unknown tags.
pub const FORMAT_VERSION: u32 = 1;

/// File-name extension used by [`CheckpointStore`].
pub const EXTENSION: &str = "flwr";

/// What produced a checkpoint (and what can consume it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointKind {
    /// A population-scale [`crate::sched::Engine`] snapshot.
    Engine,
    /// A live-server [`crate::server::Server`] / [`crate::server::AsyncServer`]
    /// snapshot (written by their shared execution core).
    Server,
}

impl CheckpointKind {
    fn tag(self) -> [u8; 4] {
        match self {
            CheckpointKind::Engine => *b"ENGN",
            CheckpointKind::Server => *b"SRVR",
        }
    }

    fn from_tag(tag: &[u8]) -> Result<Self> {
        match tag {
            b"ENGN" => Ok(CheckpointKind::Engine),
            b"SRVR" => Ok(CheckpointKind::Server),
            other => Err(Error::Persist(format!(
                "unknown checkpoint kind tag {:?}",
                String::from_utf8_lossy(other)
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, the zlib polynomial)
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

const CRC_INIT: u32 = 0xFFFF_FFFF;

/// Fold `data` into a running CRC state (start from [`CRC_INIT`],
/// finish by xor-ing with it). The incremental form lets the writer
/// and reader checksum `tag ++ len ++ payload` without concatenating
/// them — multi-MB checkpoint sections are never copied just to be
/// checksummed.
fn crc32_fold(mut state: u32, data: &[u8]) -> u32 {
    for &b in data {
        state = CRC_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// IEEE CRC-32 over `data` (the zlib/PNG polynomial). Exposed so tests
/// and external tooling can verify section payloads independently.
pub fn crc32(data: &[u8]) -> u32 {
    crc32_fold(CRC_INIT, data) ^ CRC_INIT
}

// ---------------------------------------------------------------------------
// Byte-level encode / decode helpers (crate-internal)
// ---------------------------------------------------------------------------

/// Little-endian section-payload encoder: the shared
/// [`crate::util::bytes::LeWriter`] primitives plus the checkpoint
/// format's composites (u64-length strings/blobs, option tags, f32
/// vectors). All floats are stored as raw IEEE-754 bits so
/// round-tripping is exact (NaN payloads included).
#[derive(Default)]
pub(crate) struct Enc {
    w: LeWriter,
}

impl Enc {
    pub(crate) fn new() -> Self {
        Enc { w: LeWriter::new() }
    }

    pub(crate) fn into_bytes(self) -> Vec<u8> {
        self.w.into_bytes()
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.w.u8(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.w.u32(v);
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.w.u64(v);
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.w.f64(v);
    }

    pub(crate) fn f32(&mut self, v: f32) {
        self.w.f32(v);
    }

    pub(crate) fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    pub(crate) fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }

    pub(crate) fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.w.raw(s.as_bytes());
    }

    pub(crate) fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.w.raw(b);
    }

    pub(crate) fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        self.w.reserve(v.len() * 4);
        for &x in v {
            self.w.f32(x);
        }
    }
}

/// Bounds-checked little-endian decoder over a section payload: a
/// [`crate::util::bytes::LeReader`] with [`Error::Persist`] as its
/// error category, plus the checkpoint format's composite decoders.
/// Every accessor fails instead of panicking, so a corrupt payload
/// that somehow passed its CRC still degrades to a clean load error.
pub(crate) struct Dec<'a> {
    r: LeReader<'a>,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Dec { r: LeReader::new(buf, Error::Persist) }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.r.take(n)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        self.r.u8()
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        self.r.u32()
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        self.r.u64()
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        self.r.f64()
    }

    pub(crate) fn f32(&mut self) -> Result<f32> {
        self.r.f32()
    }

    pub(crate) fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(Error::Persist(format!("invalid bool byte {other}"))),
        }
    }

    pub(crate) fn opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    pub(crate) fn opt_u64(&mut self) -> Result<Option<u64>> {
        Ok(if self.bool()? { Some(self.u64()?) } else { None })
    }

    /// A `u64` that must fit a collection count (guards against a
    /// corrupt length field causing a huge allocation).
    pub(crate) fn count(&mut self, what: &str) -> Result<usize> {
        let n = self.u64()?;
        let remaining = self.r.remaining() as u64;
        if n > remaining {
            return Err(Error::Persist(format!(
                "{what} count {n} exceeds remaining payload ({remaining} bytes)"
            )));
        }
        Ok(n as usize)
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.count("string byte")?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| Error::Persist("invalid UTF-8 in checkpoint string".into()))
    }

    pub(crate) fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count("byte-blob")?;
        Ok(self.take(n)?.to_vec())
    }

    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()?;
        let remaining = (self.r.remaining() / 4) as u64;
        if n > remaining {
            return Err(Error::Persist(format!(
                "f32 vector count {n} exceeds remaining payload"
            )));
        }
        let mut out = Vec::with_capacity(n as usize);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub(crate) fn done(&self) -> Result<()> {
        self.r.expect_end("checkpoint payload")
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Builds one checkpoint file: a typed header plus tagged, checksummed
/// sections, written atomically so a crash at any instant leaves either
/// the previous checkpoint or a complete new one — never a torn file.
///
/// # Examples
///
/// ```
/// use flowrs::persist::{CheckpointKind, CheckpointReader, CheckpointWriter};
///
/// let path = std::env::temp_dir().join("flowrs-writer-doctest.flwr");
/// let mut w = CheckpointWriter::new(CheckpointKind::Engine, 3);
/// w.section("DEMO", b"hello".to_vec());
/// w.write_atomic(&path).unwrap();
///
/// let r = CheckpointReader::read(&path).unwrap();
/// assert_eq!(r.kind(), CheckpointKind::Engine);
/// assert_eq!(r.rounds_completed(), 3);
/// assert_eq!(r.section("DEMO").unwrap(), b"hello".as_slice());
/// # std::fs::remove_file(&path).ok();
/// ```
pub struct CheckpointWriter {
    kind: CheckpointKind,
    rounds_completed: u64,
    sections: Vec<(String, Vec<u8>)>,
}

impl CheckpointWriter {
    /// Start a checkpoint of `kind` taken after `rounds_completed`
    /// rounds / model versions.
    pub fn new(kind: CheckpointKind, rounds_completed: u64) -> Self {
        CheckpointWriter { kind, rounds_completed, sections: Vec::new() }
    }

    /// The `rounds_completed` this writer was created with (the
    /// [`CheckpointStore`] derives the file name from it).
    pub fn rounds_completed(&self) -> u64 {
        self.rounds_completed
    }

    /// Append one section. `tag` must be exactly 4 ASCII bytes (the
    /// format's fixed tag width); duplicate tags are a caller bug.
    pub fn section(&mut self, tag: &str, payload: Vec<u8>) {
        assert!(
            tag.len() == 4 && tag.is_ascii(),
            "section tag must be 4 ASCII bytes, got {tag:?}"
        );
        debug_assert!(
            self.sections.iter().all(|(t, _)| t != tag),
            "duplicate section tag {tag:?}"
        );
        self.sections.push((tag.to_string(), payload));
    }

    /// Serialize the complete file image (header + sections + footer).
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload_len: usize = self.sections.iter().map(|(_, p)| p.len() + 16).sum();
        let mut w = LeWriter::with_capacity(32 + payload_len + 8);
        w.raw(&MAGIC);
        w.u32(FORMAT_VERSION);
        w.raw(&self.kind.tag());
        w.u64(self.rounds_completed);
        w.u32(self.sections.len() as u32);
        let header_crc = crc32(w.as_slice());
        w.u32(header_crc);
        for (tag, payload) in &self.sections {
            let start = w.len();
            w.raw(tag.as_bytes());
            w.u64(payload.len() as u64);
            // CRC covers tag + length + payload so a flipped tag or
            // length byte is caught, not just payload corruption.
            let crc =
                crc32_fold(crc32_fold(CRC_INIT, &w.as_slice()[start..]), payload) ^ CRC_INIT;
            w.u32(crc);
            w.raw(payload);
        }
        w.raw(&FOOTER);
        w.into_bytes()
    }

    /// Write the checkpoint to `path` atomically: serialize to
    /// `path.tmp`, `fsync` the file, `rename` over `path`, then
    /// best-effort `fsync` the containing directory so the rename
    /// itself is durable. A crash at any point leaves `path` either
    /// absent, the previous complete file, or the new complete file.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes();
        let tmp = path.with_extension("tmp");
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp).map_err(|e| {
                Error::Persist(format!("cannot create {}: {e}", tmp.display()))
            })?;
            f.write_all(&bytes)
                .map_err(|e| Error::Persist(format!("write {}: {e}", tmp.display())))?;
            f.sync_all()
                .map_err(|e| Error::Persist(format!("fsync {}: {e}", tmp.display())))?;
        }
        std::fs::rename(&tmp, path).map_err(|e| {
            Error::Persist(format!(
                "rename {} -> {}: {e}",
                tmp.display(),
                path.display()
            ))
        })?;
        if let Some(parent) = path.parent() {
            if let Ok(dir) = std::fs::File::open(parent) {
                let _ = dir.sync_all();
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// Parses and validates one checkpoint file. Construction fails — it
/// never yields partial data — on bad magic, an unsupported format
/// version, any checksum mismatch, a short read, a missing footer, or
/// trailing garbage. Unknown section tags are kept (and listable via
/// [`CheckpointReader::sections`]) but otherwise ignored, which is what
/// makes adding sections a forward-compatible change.
///
/// # Examples
///
/// ```
/// use flowrs::persist::{CheckpointKind, CheckpointReader, CheckpointWriter};
///
/// let path = std::env::temp_dir().join("flowrs-reader-doctest.flwr");
/// let mut w = CheckpointWriter::new(CheckpointKind::Server, 7);
/// w.section("DATA", vec![1, 2, 3]);
/// w.write_atomic(&path).unwrap();
///
/// let r = CheckpointReader::read(&path).unwrap();
/// assert_eq!(r.rounds_completed(), 7);
/// assert_eq!(r.section("DATA").unwrap(), [1, 2, 3].as_slice());
/// assert!(r.section("GONE").is_err());
///
/// // corruption anywhere in the file is a clean load error
/// let mut bytes = std::fs::read(&path).unwrap();
/// bytes.truncate(bytes.len() - 1);
/// assert!(CheckpointReader::from_bytes(&bytes).is_err());
/// # std::fs::remove_file(&path).ok();
/// ```
pub struct CheckpointReader {
    kind: CheckpointKind,
    format_version: u32,
    rounds_completed: u64,
    sections: Vec<(String, Vec<u8>)>,
}

impl CheckpointReader {
    /// Read and validate the checkpoint at `path`.
    pub fn read(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Persist(format!("cannot read {}: {e}", path.display())))?;
        Self::from_bytes(&bytes)
            .map_err(|e| Error::Persist(format!("{}: {e}", path.display())))
    }

    /// Parse a checkpoint from an in-memory byte image.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut d = Dec::new(buf);
        if d.take(8)? != MAGIC.as_slice() {
            return Err(Error::Persist("not a flowrs checkpoint (bad magic)".into()));
        }
        let format_version = d.u32()?;
        if format_version == 0 || format_version > FORMAT_VERSION {
            return Err(Error::Persist(format!(
                "unsupported checkpoint format version {format_version} \
                 (this build reads versions 1..={FORMAT_VERSION})"
            )));
        }
        let kind = CheckpointKind::from_tag(d.take(4)?)?;
        let rounds_completed = d.u64()?;
        let section_count = d.u32()?;
        let header_crc = d.u32()?;
        if crc32(&buf[..28]) != header_crc {
            return Err(Error::Persist("header checksum mismatch".into()));
        }
        let mut sections = Vec::with_capacity((section_count as usize).min(64));
        for _ in 0..section_count {
            let tag_bytes = d.take(4)?;
            let tag = std::str::from_utf8(tag_bytes)
                .map_err(|_| Error::Persist("non-UTF-8 section tag".into()))?
                .to_string();
            let len_bytes = d.take(8)?;
            let len = u64::from_le_bytes([
                len_bytes[0],
                len_bytes[1],
                len_bytes[2],
                len_bytes[3],
                len_bytes[4],
                len_bytes[5],
                len_bytes[6],
                len_bytes[7],
            ]) as usize;
            let crc = d.u32()?;
            let payload = d.take(len)?;
            let state = crc32_fold(
                crc32_fold(crc32_fold(CRC_INIT, tag_bytes), len_bytes),
                payload,
            );
            if state ^ CRC_INIT != crc {
                return Err(Error::Persist(format!(
                    "section {tag:?} checksum mismatch"
                )));
            }
            sections.push((tag, payload.to_vec()));
        }
        if d.take(8)? != FOOTER.as_slice() {
            return Err(Error::Persist(
                "checkpoint footer missing (truncated write?)".into(),
            ));
        }
        d.done()?;
        Ok(CheckpointReader { kind, format_version, rounds_completed, sections })
    }

    /// What wrote this checkpoint (engine vs. live server).
    pub fn kind(&self) -> CheckpointKind {
        self.kind
    }

    /// The container-format version the file was written with.
    pub fn format_version(&self) -> u32 {
        self.format_version
    }

    /// Rounds / model versions completed when the checkpoint was taken.
    pub fn rounds_completed(&self) -> u64 {
        self.rounds_completed
    }

    /// A required section's payload; errors if the tag is absent.
    pub fn section(&self, tag: &str) -> Result<&[u8]> {
        self.opt_section(tag).ok_or_else(|| {
            Error::Persist(format!("checkpoint is missing section {tag:?}"))
        })
    }

    /// An optional section's payload.
    pub fn opt_section(&self, tag: &str) -> Option<&[u8]> {
        self.sections
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, p)| p.as_slice())
    }

    /// Every section tag with its payload size in bytes (in file
    /// order) — what `flowrs ckpt inspect` prints.
    pub fn sections(&self) -> impl Iterator<Item = (&str, usize)> {
        self.sections.iter().map(|(t, p)| (t.as_str(), p.len()))
    }
}

// ---------------------------------------------------------------------------
// Directory protocol
// ---------------------------------------------------------------------------

/// A directory of checkpoints, one file per checkpointed round
/// (`ckpt-<rounds, zero-padded>.flwr`). Writes go through
/// [`CheckpointWriter::write_atomic`]; reads resolve to the newest
/// *valid* file, skipping (with a warning) any file that fails
/// validation — so a crash mid-write degrades to the previous
/// checkpoint instead of a corrupt resume.
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating if necessary) the checkpoint directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(|e| {
            Error::Persist(format!("cannot create {}: {e}", dir.display()))
        })?;
        Ok(CheckpointStore { dir })
    }

    /// The directory this store reads and writes.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The canonical file path for a checkpoint taken after
    /// `rounds_completed` rounds (zero-padded so lexicographic order is
    /// numeric order).
    pub fn path_for(&self, rounds_completed: u64) -> PathBuf {
        self.dir
            .join(format!("ckpt-{rounds_completed:010}.{EXTENSION}"))
    }

    /// Atomically write `writer`'s checkpoint into the store; returns
    /// the final path. Telemetry goes to the metric registry and the
    /// **process-global** obs sink only — never a per-run stream:
    /// checkpoint cadence differs between a full run and a
    /// kill/resume pair, so a per-run `checkpoint_write` event would
    /// break the event stream's byte-identity guarantee.
    pub fn save(&self, writer: &CheckpointWriter) -> Result<PathBuf> {
        let path = self.path_for(writer.rounds_completed());
        writer.write_atomic(&path)?;
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        crate::obs::registry().counter("persist_checkpoints_total").inc();
        crate::obs::registry()
            .counter("persist_checkpoint_bytes_total")
            .add(bytes);
        crate::obs::emit_global(&crate::obs::Event::CheckpointWrite {
            t_s: crate::obs::wall_t_s(),
            version: writer.rounds_completed(),
            bytes,
        });
        Ok(path)
    }

    /// All checkpoint files currently in the store, oldest first.
    pub fn list(&self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(&self.dir).map_err(|e| {
            Error::Persist(format!("cannot list {}: {e}", self.dir.display()))
        })?;
        for entry in entries {
            let path = entry
                .map_err(|e| Error::Persist(format!("cannot list {}: {e}", self.dir.display())))?
                .path();
            let is_ckpt = path.extension().and_then(|e| e.to_str()) == Some(EXTENSION)
                && path
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("ckpt-"));
            if is_ckpt {
                out.push(path);
            }
        }
        out.sort();
        Ok(out)
    }

    /// The newest checkpoint that parses and validates, or `None` if
    /// the store holds no valid checkpoint. Invalid files (a crash
    /// window, bit rot) are skipped with a warning — never returned.
    pub fn latest_valid(&self) -> Result<Option<(PathBuf, CheckpointReader)>> {
        let mut files = self.list()?;
        files.reverse(); // newest first
        for path in files {
            match CheckpointReader::read(&path) {
                Ok(reader) => return Ok(Some((path, reader))),
                Err(e) => log::warn(&format!("skipping invalid checkpoint: {e}")),
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("flowrs-format-{tag}-{}", std::process::id()))
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // standard test vector for the IEEE polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    /// Golden vector for the section-payload encoder: the exact bytes
    /// the hand-rolled `Enc` produced before the `util::bytes`
    /// unification, pinned so no checkpoint on disk can silently
    /// change meaning under the port.
    #[test]
    fn enc_bytes_are_pinned() {
        let mut e = Enc::new();
        e.u8(0xAB);
        e.u32(0x0102_0304);
        e.u64(0x1122_3344_5566_7788);
        e.f64(1.5);
        e.f32(-2.0);
        e.bool(true);
        e.opt_f64(None);
        e.opt_u64(Some(3));
        e.str("hi");
        e.bytes(&[9]);
        e.f32s(&[1.0]);
        assert_eq!(
            e.into_bytes(),
            vec![
                0xAB, // u8
                0x04, 0x03, 0x02, 0x01, // u32 LE
                0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // u64 LE
                0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF8, 0x3F, // f64 1.5 bits
                0x00, 0x00, 0x00, 0xC0, // f32 -2.0 bits
                0x01, // bool
                0x00, // opt_f64 None
                0x01, 0x03, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // opt_u64 Some(3)
                0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, b'h', b'i', // str
                0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x09, // bytes
                0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // f32s len
                0x00, 0x00, 0x80, 0x3F, // 1.0f32 bits
            ]
        );
    }

    /// Differential check against the pre-unification encoder: a
    /// straight-line reimplementation of the old hand-rolled `Enc`
    /// must agree byte-for-byte with the `util::bytes`-backed one over
    /// a pseudo-random op sequence, and `Dec` must read it all back.
    #[test]
    fn enc_matches_handrolled_reference_and_dec_roundtrips() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from(0xB17E5);
        for _ in 0..50 {
            let mut e = Enc::new();
            let mut reference: Vec<u8> = Vec::new();
            let mut script: Vec<u32> = Vec::new();
            for _ in 0..rng.below(40) {
                let op = rng.below(8) as u32;
                script.push(op);
                match op {
                    0 => {
                        let v = rng.next_u64() as u8;
                        e.u8(v);
                        reference.push(v);
                    }
                    1 => {
                        let v = rng.next_u64() as u32;
                        e.u32(v);
                        reference.extend_from_slice(&v.to_le_bytes());
                    }
                    2 => {
                        let v = rng.next_u64();
                        e.u64(v);
                        reference.extend_from_slice(&v.to_le_bytes());
                    }
                    3 => {
                        let v = rng.normal() * 1e6;
                        e.f64(v);
                        reference.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                    4 => {
                        let v = rng.normal_f32();
                        e.f32(v);
                        reference.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                    5 => {
                        let v = rng.below(2) == 0;
                        e.bool(v);
                        reference.push(u8::from(v));
                    }
                    6 => {
                        let s: String =
                            (0..rng.below(12)).map(|_| 'a').collect();
                        e.str(&s);
                        reference.extend_from_slice(&(s.len() as u64).to_le_bytes());
                        reference.extend_from_slice(s.as_bytes());
                    }
                    _ => {
                        let v: Vec<f32> =
                            (0..rng.below(8)).map(|_| rng.normal_f32()).collect();
                        e.f32s(&v);
                        reference.extend_from_slice(&(v.len() as u64).to_le_bytes());
                        for &x in &v {
                            reference.extend_from_slice(&x.to_bits().to_le_bytes());
                        }
                    }
                }
            }
            let bytes = e.into_bytes();
            assert_eq!(bytes, reference, "encoder diverged on script {script:?}");
            // and the decoder consumes exactly what was written
            let mut d = Dec::new(&bytes);
            for &op in &script {
                match op {
                    0 => {
                        d.u8().unwrap();
                    }
                    1 => {
                        d.u32().unwrap();
                    }
                    2 => {
                        d.u64().unwrap();
                    }
                    3 => {
                        d.f64().unwrap();
                    }
                    4 => {
                        d.f32().unwrap();
                    }
                    5 => {
                        d.bool().unwrap();
                    }
                    6 => {
                        d.str().unwrap();
                    }
                    _ => {
                        d.f32s().unwrap();
                    }
                }
            }
            d.done().unwrap();
        }
    }

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = CheckpointWriter::new(CheckpointKind::Engine, 42);
        w.section("AAAA", vec![1, 2, 3]);
        w.section("BBBB", Vec::new());
        let bytes = w.to_bytes();
        let r = CheckpointReader::from_bytes(&bytes).unwrap();
        assert_eq!(r.kind(), CheckpointKind::Engine);
        assert_eq!(r.format_version(), FORMAT_VERSION);
        assert_eq!(r.rounds_completed(), 42);
        assert_eq!(r.section("AAAA").unwrap(), [1u8, 2, 3].as_slice());
        assert_eq!(r.section("BBBB").unwrap(), [].as_slice());
        assert!(r.section("CCCC").is_err());
        assert!(r.opt_section("CCCC").is_none());
        let listed: Vec<(String, usize)> = r
            .sections()
            .map(|(t, n)| (t.to_string(), n))
            .collect();
        assert_eq!(listed, vec![("AAAA".into(), 3), ("BBBB".into(), 0)]);
    }

    #[test]
    fn every_truncation_fails_cleanly() {
        let mut w = CheckpointWriter::new(CheckpointKind::Server, 5);
        w.section("DATA", (0..200u8).collect());
        let bytes = w.to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                CheckpointReader::from_bytes(&bytes[..cut]).is_err(),
                "truncation at byte {cut} of {} parsed as valid",
                bytes.len()
            );
        }
        assert!(CheckpointReader::from_bytes(&bytes).is_ok());
    }

    #[test]
    fn every_single_byte_flip_fails_cleanly() {
        let mut w = CheckpointWriter::new(CheckpointKind::Engine, 9);
        w.section("DATA", vec![7; 64]);
        let bytes = w.to_bytes();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x5A;
            assert!(
                CheckpointReader::from_bytes(&bad).is_err(),
                "flip at byte {i} parsed as valid"
            );
        }
    }

    #[test]
    fn atomic_write_leaves_no_temp_file() {
        let dir = tmp("atomic");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt-0000000001.flwr");
        let mut w = CheckpointWriter::new(CheckpointKind::Engine, 1);
        w.section("DATA", vec![1]);
        w.write_atomic(&path).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists());
        CheckpointReader::read(&path).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_resolves_newest_valid_and_skips_corrupt() {
        let dir = tmp("store");
        let _ = std::fs::remove_dir_all(&dir);
        let store = CheckpointStore::open(&dir).unwrap();
        for round in [1u64, 2, 3] {
            let mut w = CheckpointWriter::new(CheckpointKind::Engine, round);
            w.section("DATA", vec![round as u8]);
            store.save(&w).unwrap();
        }
        assert_eq!(store.list().unwrap().len(), 3);
        let (path, r) = store.latest_valid().unwrap().unwrap();
        assert_eq!(r.rounds_completed(), 3);
        // corrupt the newest: the store must fall back to round 2
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let (_, r) = store.latest_valid().unwrap().unwrap();
        assert_eq!(r.rounds_completed(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_store_is_none() {
        let dir = tmp("empty");
        let _ = std::fs::remove_dir_all(&dir);
        let store = CheckpointStore::open(&dir).unwrap();
        assert!(store.latest_valid().unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
