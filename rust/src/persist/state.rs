//! What a checkpoint *contains*: typed snapshots of the engine and the
//! live server, with their section codecs.
//!
//! Two checkpoint kinds exist (see [`super::CheckpointKind`]):
//!
//! * [`EngineCheckpoint`] — a [`crate::sched::Engine`] at a flush
//!   boundary. Captures everything the engine's trajectory depends on
//!   beyond the (re-synthesizable) config: the per-device scheduler
//!   history (loss / last-selected / fairness counters), the selection
//!   policy's RNG position, the trainer's numeric state, the virtual
//!   clocks, the in-flight dispatch manifest, and the availability
//!   index's exact internal state. Restoring it replays the
//!   uninterrupted run bit-identically (locked by e2e tests).
//! * [`ServerCheckpoint`] — the live server's durable state: global
//!   [`Parameters`], the full round [`History`], whole-run
//!   [`AsyncStats`], the selection hook's per-client observations and
//!   its RNG position (so cohort selection continues its stream).
//!   In-flight fit exchanges are real threads and cannot be persisted;
//!   a resumed server re-dispatches instead (their results were counted
//!   as `drained` when the original run stopped, so the accounting
//!   identity still holds across the kill).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::proto::{Parameters, Tensor};
use crate::sched::availability::IndexState;
use crate::sched::engine::PopulationRound;
use crate::server::{AsyncStats, History, RoundRecord};
use crate::util::rng::RngState;

use super::format::{
    CheckpointKind, CheckpointReader, CheckpointStore, CheckpointWriter, Dec, Enc,
};

// Section tags (4 ASCII bytes each; see FORMAT.md).
const SEC_META: &str = "META";
const SEC_DEVICES: &str = "POPS";
const SEC_RNG: &str = "PRNG";
const SEC_TRAINER: &str = "TRNR";
const SEC_IN_FLIGHT: &str = "INFL";
const SEC_INDEX: &str = "INDX";
const SEC_ENGINE_ROUNDS: &str = "ERND";
const SEC_ENGINE_WIRE: &str = "EWIR";
const SEC_SHARDS: &str = "SHRD";
const SEC_EDGE: &str = "EDGE";
const SEC_PARAMS: &str = "PARM";
const SEC_SERVER_META: &str = "SMET";
const SEC_SERVER_ROUNDS: &str = "SRND";
const SEC_STATS: &str = "STAT";
const SEC_CLIENTS: &str = "CLST";

// ---------------------------------------------------------------------------
// Engine checkpoint
// ---------------------------------------------------------------------------

/// One virtual device's mutable scheduler state (everything else about
/// a device — profile, data size, availability cycle — re-synthesizes
/// deterministically from the config).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeviceState {
    /// Most recent train loss the device reported.
    pub last_loss: Option<f64>,
    /// Round / version in which the device was last selected.
    pub last_selected_round: Option<u64>,
    /// Lifetime selection count (fairness policies cap this).
    pub times_selected: u64,
}

/// One dispatch still in flight when the checkpoint was taken: the
/// modeled resolution event, verbatim. Restoring re-queues it, so a
/// resumed streaming run *re-settles* the outstanding work instead of
/// losing it — and settles it at exactly the virtual times the
/// uninterrupted run would have.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InFlightDispatch {
    /// Virtual time at which the dispatch resolves.
    pub resolve_s: f64,
    /// Index of the device in the synthesized population.
    pub device: u64,
    /// Energy already prorated to the resolve point.
    pub energy_j: f64,
    /// Model version the dispatch was issued against.
    pub base_version: u64,
    /// Modeled fate: 0 = fold, 1 = deadline drop, 2 = churn drop.
    pub outcome: u8,
}

/// The parallel-synthesis audit record (`SHRD` section, optional —
/// absent in checkpoints written before sharded execution existed).
///
/// Population synthesis shards across `workers` threads by
/// fast-forwarding the *one* canonical RNG stream to each shard's start
/// device; these are those stream positions. On resume the engine
/// recomputes them from the config for the recorded worker count and
/// refuses to run on a mismatch — catching any drift in the
/// shard-derivation contract (the population a resumed run synthesizes
/// must be the population the checkpointed run scheduled). The worker
/// count itself is an execution knob: a checkpoint written under
/// `--workers 1` resumes under `--workers 8` and vice versa.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSeeds {
    /// Worker count the writing run used (recorded for the audit
    /// recomputation; not an identity constraint).
    pub workers: u64,
    /// Canonical synthesis-stream state at each shard's first device.
    pub starts: Vec<RngState>,
}

/// One device fold parked at an edge aggregator when the checkpoint was
/// taken (async two-tier mode: edge buffers may be non-empty at a cloud
/// flush boundary). Mirrors the engine's in-memory entry verbatim so a
/// resumed run ships it at exactly the quorum the uninterrupted run
/// would have.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeParkedFold {
    /// Index of the device in the synthesized population.
    pub device: u64,
    /// Model version the fold was dispatched against (staleness is
    /// computed at ship time, so the raw base version is what persists).
    pub base_version: u64,
    /// Virtual time the fold arrived at its edge.
    pub resolve_s: f64,
}

/// Edge-aggregator tier state (`EDGE` section, optional — absent in
/// flat runs and in checkpoints written before the tier existed). See
/// `rust/src/sched/TOPOLOGY.md` for the tier semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeTierState {
    /// Edge count the writing run used (sanity-checked on resume; the
    /// config fingerprint already pins it).
    pub edges: u64,
    /// Liveness per edge (`false` = an applied `--edge-fail` — stays
    /// dead across resume).
    pub alive: Vec<bool>,
    /// Parked folds per edge, arrival order.
    pub buffers: Vec<Vec<EdgeParkedFold>>,
}

/// A complete [`crate::sched::Engine`] snapshot at a flush boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineCheckpoint {
    /// Fingerprint of the determinism-relevant config
    /// ([`crate::config::ScheduleConfig::fingerprint`]); resume refuses
    /// a mismatch instead of silently diverging.
    pub fingerprint: String,
    /// Model versions flushed (== rounds completed).
    pub version: u64,
    /// Report clock (cumulative virtual time).
    pub clock_s: f64,
    /// Event-loop virtual time.
    pub now_s: f64,
    /// Virtual time of the previous streaming flush.
    pub last_flush_s: f64,
    /// Devices online at the last availability observation.
    pub avail_count: u64,
    /// Per-device mutable scheduler state, population order.
    pub devices: Vec<DeviceState>,
    /// The selection policy's RNG position (`None` for policies that
    /// carry no RNG — they are assumed stateless-deterministic).
    pub policy_rng: Option<RngState>,
    /// Opaque trainer state
    /// ([`crate::sched::engine::CohortTrainer::checkpoint_state`]).
    pub trainer: Vec<u8>,
    /// Dispatches in flight (streaming mode; empty at a sync barrier).
    pub in_flight: Vec<InFlightDispatch>,
    /// Exact availability-index state (streaming mode only).
    pub index: Option<IndexState>,
    /// Every round record produced so far — the resumed report prepends
    /// these, so a spliced trace is byte-identical to an uninterrupted
    /// run's.
    pub rounds: Vec<PopulationRound>,
    /// Parallel-synthesis audit record (`None` for pre-`SHRD`
    /// checkpoints, which resume fine — the audit is then skipped).
    pub shards: Option<ShardSeeds>,
    /// Edge-aggregator tier state (`None` for flat runs; required by
    /// resume when the config says `edges > 1`).
    pub edge: Option<EdgeTierState>,
}

impl EngineCheckpoint {
    /// Serialize into a [`CheckpointWriter`] ready for
    /// [`CheckpointWriter::write_atomic`] / [`CheckpointStore::save`].
    pub fn to_writer(&self) -> CheckpointWriter {
        let mut w = CheckpointWriter::new(CheckpointKind::Engine, self.version);

        let mut meta = Enc::new();
        meta.str(&self.fingerprint);
        meta.u64(self.version);
        meta.f64(self.clock_s);
        meta.f64(self.now_s);
        meta.f64(self.last_flush_s);
        meta.u64(self.avail_count);
        w.section(SEC_META, meta.into_bytes());

        let mut devs = Enc::new();
        devs.u64(self.devices.len() as u64);
        for d in &self.devices {
            devs.opt_f64(d.last_loss);
            devs.opt_u64(d.last_selected_round);
            devs.u64(d.times_selected);
        }
        w.section(SEC_DEVICES, devs.into_bytes());

        let mut rng = Enc::new();
        match &self.policy_rng {
            Some(s) => {
                rng.bool(true);
                for word in s.s {
                    rng.u64(word);
                }
                rng.opt_f64(s.spare_normal);
            }
            None => rng.bool(false),
        }
        w.section(SEC_RNG, rng.into_bytes());

        let mut trainer = Enc::new();
        trainer.bytes(&self.trainer);
        w.section(SEC_TRAINER, trainer.into_bytes());

        let mut infl = Enc::new();
        infl.u64(self.in_flight.len() as u64);
        for f in &self.in_flight {
            infl.f64(f.resolve_s);
            infl.u64(f.device);
            infl.f64(f.energy_j);
            infl.u64(f.base_version);
            infl.u8(f.outcome);
        }
        w.section(SEC_IN_FLIGHT, infl.into_bytes());

        if let Some(ix) = &self.index {
            w.section(SEC_INDEX, encode_index_state(ix));
        }

        w.section(SEC_ENGINE_ROUNDS, encode_population_rounds(&self.rounds));

        // Per-round wire-byte books ride in their own section: `ERND`'s
        // 17-field layout shipped and is frozen (FORMAT.md — extend with
        // a new tag, never by changing a shipped layout). A pre-`EWIR`
        // checkpoint decodes with zeroed byte books.
        let mut wire = Enc::new();
        wire.u64(self.rounds.len() as u64);
        for r in &self.rounds {
            wire.u64(r.bytes_down);
            wire.u64(r.bytes_up);
        }
        w.section(SEC_ENGINE_WIRE, wire.into_bytes());

        if let Some(sh) = &self.shards {
            let mut e = Enc::new();
            e.u64(sh.workers);
            e.u64(sh.starts.len() as u64);
            for s in &sh.starts {
                for word in s.s {
                    e.u64(word);
                }
                e.opt_f64(s.spare_normal);
            }
            w.section(SEC_SHARDS, e.into_bytes());
        }
        if let Some(edge) = &self.edge {
            let mut e = Enc::new();
            e.u64(edge.edges);
            e.u64(edge.alive.len() as u64);
            for &a in &edge.alive {
                e.bool(a);
            }
            e.u64(edge.buffers.len() as u64);
            for buf in &edge.buffers {
                e.u64(buf.len() as u64);
                for f in buf {
                    e.u64(f.device);
                    e.u64(f.base_version);
                    e.f64(f.resolve_s);
                }
            }
            w.section(SEC_EDGE, e.into_bytes());
        }
        w
    }

    /// Decode from a validated [`CheckpointReader`] (kind must be
    /// [`CheckpointKind::Engine`]).
    pub fn from_reader(r: &CheckpointReader) -> Result<Self> {
        if r.kind() != CheckpointKind::Engine {
            return Err(Error::Persist(format!(
                "expected an engine checkpoint, found {:?}",
                r.kind()
            )));
        }
        let mut meta = Dec::new(r.section(SEC_META)?);
        let fingerprint = meta.str()?;
        let version = meta.u64()?;
        let clock_s = meta.f64()?;
        let now_s = meta.f64()?;
        let last_flush_s = meta.f64()?;
        let avail_count = meta.u64()?;
        meta.done()?;

        let mut devs = Dec::new(r.section(SEC_DEVICES)?);
        let n = devs.count("device")?;
        let mut devices = Vec::with_capacity(n);
        for _ in 0..n {
            devices.push(DeviceState {
                last_loss: devs.opt_f64()?,
                last_selected_round: devs.opt_u64()?,
                times_selected: devs.u64()?,
            });
        }
        devs.done()?;

        let mut rng = Dec::new(r.section(SEC_RNG)?);
        let policy_rng = if rng.bool()? {
            let mut s = [0u64; 4];
            for word in &mut s {
                *word = rng.u64()?;
            }
            Some(RngState { s, spare_normal: rng.opt_f64()? })
        } else {
            None
        };
        rng.done()?;

        let mut tr = Dec::new(r.section(SEC_TRAINER)?);
        let trainer = tr.bytes()?;
        tr.done()?;

        let mut infl = Dec::new(r.section(SEC_IN_FLIGHT)?);
        let n = infl.count("in-flight dispatch")?;
        let mut in_flight = Vec::with_capacity(n);
        for _ in 0..n {
            in_flight.push(InFlightDispatch {
                resolve_s: infl.f64()?,
                device: infl.u64()?,
                energy_j: infl.f64()?,
                base_version: infl.u64()?,
                outcome: infl.u8()?,
            });
        }
        infl.done()?;

        let index = match r.opt_section(SEC_INDEX) {
            Some(buf) => Some(decode_index_state(buf)?),
            None => None,
        };
        let mut rounds = decode_population_rounds(r.section(SEC_ENGINE_ROUNDS)?)?;
        if let Some(buf) = r.opt_section(SEC_ENGINE_WIRE) {
            let mut d = Dec::new(buf);
            let n = d.count("wire-byte round record")?;
            if n != rounds.len() {
                return Err(Error::Persist(format!(
                    "EWIR carries {n} records for {} rounds",
                    rounds.len()
                )));
            }
            for rec in &mut rounds {
                rec.bytes_down = d.u64()?;
                rec.bytes_up = d.u64()?;
            }
            d.done()?;
        }
        let shards = match r.opt_section(SEC_SHARDS) {
            Some(buf) => {
                let mut d = Dec::new(buf);
                let workers = d.u64()?;
                let n = d.count("shard RNG start")?;
                let mut starts = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut s = [0u64; 4];
                    for word in &mut s {
                        *word = d.u64()?;
                    }
                    starts.push(RngState { s, spare_normal: d.opt_f64()? });
                }
                d.done()?;
                Some(ShardSeeds { workers, starts })
            }
            None => None,
        };
        let edge = match r.opt_section(SEC_EDGE) {
            Some(buf) => {
                let mut d = Dec::new(buf);
                let edges = d.u64()?;
                let n = d.count("edge liveness flag")?;
                let mut alive = Vec::with_capacity(n);
                for _ in 0..n {
                    alive.push(d.bool()?);
                }
                let n = d.count("edge buffer")?;
                let mut buffers = Vec::with_capacity(n);
                for _ in 0..n {
                    let m = d.count("edge parked fold")?;
                    let mut buf = Vec::with_capacity(m);
                    for _ in 0..m {
                        buf.push(EdgeParkedFold {
                            device: d.u64()?,
                            base_version: d.u64()?,
                            resolve_s: d.f64()?,
                        });
                    }
                    buffers.push(buf);
                }
                d.done()?;
                Some(EdgeTierState { edges, alive, buffers })
            }
            None => None,
        };
        Ok(EngineCheckpoint {
            fingerprint,
            version,
            clock_s,
            now_s,
            last_flush_s,
            avail_count,
            devices,
            policy_rng,
            trainer,
            in_flight,
            index,
            rounds,
            shards,
            edge,
        })
    }
}

fn encode_index_state(ix: &IndexState) -> Vec<u8> {
    let mut e = Enc::new();
    e.f64(ix.now_s);
    e.u64(ix.online.len() as u64);
    for &b in &ix.online {
        e.bool(b);
    }
    e.u64(ix.busy.len() as u64);
    for &b in &ix.busy {
        e.bool(b);
    }
    e.u64(ix.idle_online.len() as u64);
    for &d in &ix.idle_online {
        e.u32(d);
    }
    e.f64(ix.wheel_width_s);
    e.u64(ix.wheel_cursor_window);
    e.u64(ix.wheel_buckets.len() as u64);
    for bucket in &ix.wheel_buckets {
        e.u64(bucket.len() as u64);
        for &(t, d) in bucket {
            e.f64(t);
            e.u32(d);
        }
    }
    e.into_bytes()
}

fn decode_index_state(buf: &[u8]) -> Result<IndexState> {
    let mut d = Dec::new(buf);
    let now_s = d.f64()?;
    let n = d.count("index online flag")?;
    let mut online = Vec::with_capacity(n);
    for _ in 0..n {
        online.push(d.bool()?);
    }
    let n = d.count("index busy flag")?;
    let mut busy = Vec::with_capacity(n);
    for _ in 0..n {
        busy.push(d.bool()?);
    }
    let n = d.count("index free-list entry")?;
    let mut idle_online = Vec::with_capacity(n);
    for _ in 0..n {
        idle_online.push(d.u32()?);
    }
    let wheel_width_s = d.f64()?;
    let wheel_cursor_window = d.u64()?;
    let n = d.count("wheel bucket")?;
    let mut wheel_buckets = Vec::with_capacity(n);
    for _ in 0..n {
        let m = d.count("wheel entry")?;
        let mut bucket = Vec::with_capacity(m);
        for _ in 0..m {
            let t = d.f64()?;
            let dev = d.u32()?;
            bucket.push((t, dev));
        }
        wheel_buckets.push(bucket);
    }
    d.done()?;
    Ok(IndexState {
        now_s,
        online,
        busy,
        idle_online,
        wheel_width_s,
        wheel_cursor_window,
        wheel_buckets,
    })
}

fn encode_population_rounds(rounds: &[PopulationRound]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(rounds.len() as u64);
    for r in rounds {
        e.u64(r.round);
        e.u64(r.available as u64);
        e.u64(r.selected as u64);
        e.u64(r.completed as u64);
        e.u64(r.dropped_deadline as u64);
        e.u64(r.dropped_churn as u64);
        e.f64(r.train_loss);
        e.f64(r.eval_loss);
        e.f64(r.accuracy);
        e.u64(r.steps);
        e.f64(r.round_time_s);
        e.f64(r.cum_time_s);
        e.f64(r.round_energy_j);
        e.f64(r.wasted_energy_j);
        e.f64(r.mean_staleness);
        e.u64(r.max_staleness);
        e.u64(r.in_flight as u64);
    }
    e.into_bytes()
}

/// Decode the engine round-trace section (also used by
/// `flowrs ckpt inspect` to pretty-print a checkpoint's history).
pub fn decode_population_rounds(buf: &[u8]) -> Result<Vec<PopulationRound>> {
    let mut d = Dec::new(buf);
    let n = d.count("population round")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(PopulationRound {
            round: d.u64()?,
            available: d.u64()? as usize,
            selected: d.u64()? as usize,
            completed: d.u64()? as usize,
            dropped_deadline: d.u64()? as usize,
            dropped_churn: d.u64()? as usize,
            train_loss: d.f64()?,
            eval_loss: d.f64()?,
            accuracy: d.f64()?,
            steps: d.u64()?,
            round_time_s: d.f64()?,
            cum_time_s: d.f64()?,
            round_energy_j: d.f64()?,
            wasted_energy_j: d.f64()?,
            mean_staleness: d.f64()?,
            max_staleness: d.u64()?,
            in_flight: d.u64()? as usize,
            // Byte books live in the EWIR section (merged by the caller);
            // a pre-EWIR checkpoint leaves them zeroed.
            bytes_down: 0,
            bytes_up: 0,
        });
    }
    d.done()?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Server checkpoint
// ---------------------------------------------------------------------------

/// One parameter tensor, flattened for storage (f32 only — the server
/// always holds full-precision parameters; f16 exists on the wire
/// only).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamTensor {
    /// Row-major shape.
    pub shape: Vec<u64>,
    /// Flat f32 payload.
    pub data: Vec<f32>,
}

/// The selection hook's per-client observations, keyed by client id.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientStatRecord {
    /// Client id (stable across reconnects).
    pub id: String,
    /// Most recent finite train loss.
    pub last_loss: Option<f64>,
    /// Round in which the client was last selected.
    pub last_selected_round: Option<u64>,
    /// Lifetime selection count.
    pub times_selected: u64,
}

/// A live-server snapshot at a flush boundary (see the module docs for
/// what is and is not captured).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerCheckpoint {
    /// Which loop wrote the checkpoint: `true` = streaming (FedBuff
    /// versions), `false` = barrier rounds. Resume refuses a mode flip
    /// — continuing an async version history with barrier rounds (or
    /// vice versa) would silently change the records' semantics.
    pub streaming: bool,
    /// The selection hook's RNG position, when a policy is installed
    /// and carries one — restored on resume so the cohort-selection
    /// stream continues instead of replaying from its seed.
    pub policy_rng: Option<RngState>,
    /// Global model parameters at the checkpointed version.
    pub params: Vec<ParamTensor>,
    /// Every round / version record produced so far.
    pub history: Vec<RoundRecord>,
    /// Whole-run accounting at the checkpoint instant.
    pub stats: AsyncStats,
    /// Per-client selection observations, sorted by id (so identical
    /// state always serializes to identical bytes).
    pub clients: Vec<ClientStatRecord>,
}

impl ServerCheckpoint {
    /// Capture a checkpoint from the execution core's live state.
    /// Fails if any parameter tensor is not f32 (the server never holds
    /// quantized parameters; the wire compressor is a strategy wrapper).
    pub fn capture(
        streaming: bool,
        policy_rng: Option<RngState>,
        params: &Parameters,
        history: &History,
        stats: AsyncStats,
        mut clients: Vec<ClientStatRecord>,
    ) -> Result<Self> {
        let mut tensors = Vec::with_capacity(params.tensors.len());
        for t in &params.tensors {
            tensors.push(ParamTensor {
                shape: t.shape.iter().map(|&d| d as u64).collect(),
                data: t.as_f32()?.to_vec(),
            });
        }
        clients.sort_by(|a, b| a.id.cmp(&b.id));
        Ok(ServerCheckpoint {
            streaming,
            policy_rng,
            params: tensors,
            history: history.rounds.clone(),
            stats,
            clients,
        })
    }

    /// Rebuild the [`Parameters`] container.
    pub fn parameters(&self) -> Result<Parameters> {
        let mut tensors = Vec::with_capacity(self.params.len());
        for t in &self.params {
            tensors.push(Tensor::f32(
                t.shape.iter().map(|&d| d as usize).collect(),
                t.data.clone(),
            )?);
        }
        Ok(Parameters { tensors })
    }

    /// Serialize into a [`CheckpointWriter`].
    pub fn to_writer(&self) -> CheckpointWriter {
        let mut w = CheckpointWriter::new(CheckpointKind::Server, self.history.len() as u64);

        let mut meta = Enc::new();
        meta.bool(self.streaming);
        match &self.policy_rng {
            Some(s) => {
                meta.bool(true);
                for word in s.s {
                    meta.u64(word);
                }
                meta.opt_f64(s.spare_normal);
            }
            None => meta.bool(false),
        }
        w.section(SEC_SERVER_META, meta.into_bytes());

        let mut parm = Enc::new();
        parm.u64(self.params.len() as u64);
        for t in &self.params {
            parm.u64(t.shape.len() as u64);
            for &d in &t.shape {
                parm.u64(d);
            }
            parm.f32s(&t.data);
        }
        w.section(SEC_PARAMS, parm.into_bytes());

        w.section(SEC_SERVER_ROUNDS, encode_round_records(&self.history));

        let mut stat = Enc::new();
        stat.u64(self.stats.dispatched);
        stat.u64(self.stats.folded);
        stat.u64(self.stats.flushed);
        stat.u64(self.stats.failures);
        stat.u64(self.stats.discarded);
        stat.u64(self.stats.drained);
        w.section(SEC_STATS, stat.into_bytes());

        let mut cl = Enc::new();
        cl.u64(self.clients.len() as u64);
        for c in &self.clients {
            cl.str(&c.id);
            cl.opt_f64(c.last_loss);
            cl.opt_u64(c.last_selected_round);
            cl.u64(c.times_selected);
        }
        w.section(SEC_CLIENTS, cl.into_bytes());
        w
    }

    /// Decode from a validated [`CheckpointReader`] (kind must be
    /// [`CheckpointKind::Server`]).
    pub fn from_reader(r: &CheckpointReader) -> Result<Self> {
        if r.kind() != CheckpointKind::Server {
            return Err(Error::Persist(format!(
                "expected a server checkpoint, found {:?}",
                r.kind()
            )));
        }
        let mut meta = Dec::new(r.section(SEC_SERVER_META)?);
        let streaming = meta.bool()?;
        let policy_rng = if meta.bool()? {
            let mut s = [0u64; 4];
            for word in &mut s {
                *word = meta.u64()?;
            }
            Some(RngState { s, spare_normal: meta.opt_f64()? })
        } else {
            None
        };
        meta.done()?;

        let mut parm = Dec::new(r.section(SEC_PARAMS)?);
        let n = parm.count("parameter tensor")?;
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let rank = parm.count("tensor dim")?;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(parm.u64()?);
            }
            params.push(ParamTensor { shape, data: parm.f32s()? });
        }
        parm.done()?;

        let history = decode_round_records(r.section(SEC_SERVER_ROUNDS)?)?;

        let mut stat = Dec::new(r.section(SEC_STATS)?);
        let stats = AsyncStats {
            dispatched: stat.u64()?,
            folded: stat.u64()?,
            flushed: stat.u64()?,
            failures: stat.u64()?,
            discarded: stat.u64()?,
            drained: stat.u64()?,
        };
        stat.done()?;

        let mut cl = Dec::new(r.section(SEC_CLIENTS)?);
        let n = cl.count("client stat")?;
        let mut clients = Vec::with_capacity(n);
        for _ in 0..n {
            clients.push(ClientStatRecord {
                id: cl.str()?,
                last_loss: cl.opt_f64()?,
                last_selected_round: cl.opt_u64()?,
                times_selected: cl.u64()?,
            });
        }
        cl.done()?;
        Ok(ServerCheckpoint { streaming, policy_rng, params, history, stats, clients })
    }
}

fn encode_round_records(records: &[RoundRecord]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(records.len() as u64);
    for r in records {
        e.u64(r.round);
        e.u64(r.fit_selected as u64);
        e.u64(r.fit_completed as u64);
        e.u64(r.fit_failures as u64);
        e.f64(r.train_loss);
        e.f64(r.eval_loss);
        e.f64(r.accuracy);
        e.f64(r.round_time_s);
        e.f64(r.cum_time_s);
        e.f64(r.round_energy_j);
        e.f64(r.cum_energy_j);
        e.u64(r.steps);
        e.u64(r.truncated_clients as u64);
        e.u64(r.down_bytes as u64);
        e.u64(r.up_bytes as u64);
        e.f64(r.mean_staleness);
        e.u64(r.max_staleness);
        e.u64(r.concurrency as u64);
        e.u64(r.fit_discarded as u64);
    }
    e.into_bytes()
}

/// Decode the server round-trace section (also used by
/// `flowrs ckpt inspect`).
pub fn decode_round_records(buf: &[u8]) -> Result<Vec<RoundRecord>> {
    let mut d = Dec::new(buf);
    let n = d.count("round record")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(RoundRecord {
            round: d.u64()?,
            fit_selected: d.u64()? as usize,
            fit_completed: d.u64()? as usize,
            fit_failures: d.u64()? as usize,
            train_loss: d.f64()?,
            eval_loss: d.f64()?,
            accuracy: d.f64()?,
            round_time_s: d.f64()?,
            cum_time_s: d.f64()?,
            round_energy_j: d.f64()?,
            cum_energy_j: d.f64()?,
            steps: d.u64()?,
            truncated_clients: d.u64()? as usize,
            down_bytes: d.u64()? as usize,
            up_bytes: d.u64()? as usize,
            mean_staleness: d.f64()?,
            max_staleness: d.u64()?,
            concurrency: d.u64()? as usize,
            fit_discarded: d.u64()? as usize,
        });
    }
    d.done()?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Resolution helpers (file-or-directory arguments)
// ---------------------------------------------------------------------------

/// Resolve a checkpoint argument: a file path loads that exact file; a
/// directory loads its newest valid checkpoint via [`CheckpointStore`].
pub fn resolve_checkpoint(path: &Path) -> Result<(PathBuf, CheckpointReader)> {
    if path.is_dir() {
        CheckpointStore::open(path)?.latest_valid()?.ok_or_else(|| {
            Error::Persist(format!(
                "no valid checkpoint found in {}",
                path.display()
            ))
        })
    } else {
        Ok((path.to_path_buf(), CheckpointReader::read(path)?))
    }
}

/// Load an [`EngineCheckpoint`] from a file or directory argument.
pub fn load_engine_checkpoint(path: &Path) -> Result<EngineCheckpoint> {
    let (resolved, reader) = resolve_checkpoint(path)?;
    EngineCheckpoint::from_reader(&reader)
        .map_err(|e| Error::Persist(format!("{}: {e}", resolved.display())))
}

/// Load a [`ServerCheckpoint`] from a file or directory argument.
pub fn load_server_checkpoint(path: &Path) -> Result<ServerCheckpoint> {
    let (resolved, reader) = resolve_checkpoint(path)?;
    ServerCheckpoint::from_reader(&reader)
        .map_err(|e| Error::Persist(format!("{}: {e}", resolved.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine_ckpt() -> EngineCheckpoint {
        EngineCheckpoint {
            fingerprint: "schedule-v1:test".into(),
            version: 4,
            clock_s: 123.5,
            now_s: 125.25,
            last_flush_s: 120.0,
            avail_count: 37,
            devices: vec![
                DeviceState { last_loss: Some(1.5), last_selected_round: Some(3), times_selected: 2 },
                DeviceState::default(),
            ],
            policy_rng: Some(RngState { s: [1, 2, 3, 4], spare_normal: Some(-0.75) }),
            trainer: vec![9, 8, 7],
            in_flight: vec![InFlightDispatch {
                resolve_s: 130.0,
                device: 1,
                energy_j: 42.0,
                base_version: 4,
                outcome: 0,
            }],
            index: Some(IndexState {
                now_s: 125.25,
                online: vec![true, false],
                busy: vec![false, true],
                idle_online: vec![0],
                wheel_width_s: 10.0,
                wheel_cursor_window: 12,
                wheel_buckets: vec![vec![(131.0, 1)], Vec::new()],
            }),
            rounds: vec![PopulationRound {
                round: 4,
                available: 37,
                selected: 8,
                completed: 8,
                train_loss: 1.25,
                eval_loss: 2.0,
                accuracy: 0.25,
                steps: 64,
                round_time_s: 30.0,
                cum_time_s: 123.5,
                round_energy_j: 500.0,
                mean_staleness: 0.5,
                max_staleness: 2,
                in_flight: 1,
                bytes_down: 4_379_968,
                bytes_up: 2_189_984,
                ..Default::default()
            }],
            shards: Some(ShardSeeds {
                workers: 4,
                starts: vec![
                    RngState { s: [11, 12, 13, 14], spare_normal: None },
                    RngState { s: [21, 22, 23, 24], spare_normal: Some(0.5) },
                ],
            }),
            edge: Some(EdgeTierState {
                edges: 2,
                alive: vec![true, false],
                buffers: vec![
                    vec![EdgeParkedFold { device: 0, base_version: 3, resolve_s: 124.5 }],
                    Vec::new(),
                ],
            }),
        }
    }

    #[test]
    fn engine_checkpoint_roundtrips_exactly() {
        let ck = engine_ckpt();
        let bytes = ck.to_writer().to_bytes();
        let reader = CheckpointReader::from_bytes(&bytes).unwrap();
        assert_eq!(reader.kind(), CheckpointKind::Engine);
        assert_eq!(reader.rounds_completed(), 4);
        let back = EngineCheckpoint::from_reader(&reader).unwrap();
        assert_eq!(back.fingerprint, ck.fingerprint);
        assert_eq!(back.devices, ck.devices);
        assert_eq!(back.policy_rng, ck.policy_rng);
        assert_eq!(back.in_flight, ck.in_flight);
        assert_eq!(back.index, ck.index);
        assert_eq!(back.trainer, ck.trainer);
        // f64 fields round-trip bit-exactly
        assert_eq!(back.clock_s.to_bits(), ck.clock_s.to_bits());
        assert_eq!(back.rounds[0].accuracy.to_bits(), ck.rounds[0].accuracy.to_bits());
        assert_eq!(back.shards, ck.shards);
        assert_eq!(back, ck);
    }

    /// The `SHRD` section is optional: a checkpoint written without it
    /// (any pre-sharding file) still decodes, with `shards: None` — the
    /// forward-compatible-section policy from FORMAT.md.
    #[test]
    fn engine_checkpoint_without_shards_section_decodes() {
        let mut ck = engine_ckpt();
        ck.shards = None;
        let bytes = ck.to_writer().to_bytes();
        let back =
            EngineCheckpoint::from_reader(&CheckpointReader::from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(back.shards, None);
        assert_eq!(back, ck);
    }

    /// The `EDGE` section follows the same forward-compatible policy:
    /// flat runs (and pre-tier checkpoints) simply omit it.
    #[test]
    fn engine_checkpoint_without_edge_section_decodes() {
        let mut ck = engine_ckpt();
        ck.edge = None;
        let bytes = ck.to_writer().to_bytes();
        let back =
            EngineCheckpoint::from_reader(&CheckpointReader::from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(back.edge, None);
        assert_eq!(back, ck);
    }

    #[test]
    fn engine_checkpoint_nan_losses_survive() {
        let mut ck = engine_ckpt();
        ck.rounds[0].train_loss = f64::NAN;
        let bytes = ck.to_writer().to_bytes();
        let back =
            EngineCheckpoint::from_reader(&CheckpointReader::from_bytes(&bytes).unwrap()).unwrap();
        assert!(back.rounds[0].train_loss.is_nan());
        assert_eq!(
            back.rounds[0].train_loss.to_bits(),
            ck.rounds[0].train_loss.to_bits()
        );
    }

    #[test]
    fn server_checkpoint_roundtrips_exactly() {
        let params = Parameters::from_flat(vec![1.0, -2.5, 3.25]);
        let mut history = History::default();
        history.push(RoundRecord {
            round: 1,
            fit_selected: 4,
            fit_completed: 3,
            fit_failures: 1,
            accuracy: 0.1,
            round_time_s: 13.0,
            round_energy_j: 400.0,
            ..Default::default()
        });
        let stats = AsyncStats { dispatched: 4, folded: 3, flushed: 3, failures: 1, ..Default::default() };
        let clients = vec![
            ClientStatRecord {
                id: "b".into(),
                last_loss: Some(0.5),
                last_selected_round: Some(1),
                times_selected: 1,
            },
            ClientStatRecord { id: "a".into(), last_loss: None, last_selected_round: None, times_selected: 0 },
        ];
        let rng = Some(RngState { s: [9, 8, 7, 6], spare_normal: None });
        let ck = ServerCheckpoint::capture(true, rng, &params, &history, stats, clients).unwrap();
        // capture sorts clients by id for deterministic bytes
        assert_eq!(ck.clients[0].id, "a");
        let bytes = ck.to_writer().to_bytes();
        let reader = CheckpointReader::from_bytes(&bytes).unwrap();
        assert_eq!(reader.kind(), CheckpointKind::Server);
        let back = ServerCheckpoint::from_reader(&reader).unwrap();
        assert_eq!(back, ck);
        assert!(back.streaming, "mode tag must round-trip");
        assert_eq!(back.policy_rng, rng, "selection RNG position must round-trip");
        assert_eq!(back.parameters().unwrap(), params);
    }

    #[test]
    fn kind_mismatch_is_rejected() {
        let ck = engine_ckpt();
        let bytes = ck.to_writer().to_bytes();
        let reader = CheckpointReader::from_bytes(&bytes).unwrap();
        assert!(ServerCheckpoint::from_reader(&reader).is_err());
    }

    #[test]
    fn capture_rejects_non_f32_parameters() {
        let params = Parameters::from_flat(vec![1.0]).quantize_f16().unwrap();
        assert!(ServerCheckpoint::capture(
            false,
            None,
            &params,
            &History::default(),
            AsyncStats::default(),
            Vec::new()
        )
        .is_err());
    }
}
