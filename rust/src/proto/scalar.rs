//! Scalar config/metric values and the string-keyed maps that carry
//! per-round hyper-parameters and client-reported metrics.
//!
//! The paper (§3): "Each message contains additional user-customizable
//! metadata that allows the server to control on-device hyper-parameters,
//! for example, the number of on-device training epochs." `ConfigMap` is
//! that metadata channel; the τ-cutoff strategy also rides on it.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A single scalar config/metric value (mirrors Flower's `Scalar` proto).
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    Bytes(Vec<u8>),
}

impl From<bool> for Scalar {
    fn from(v: bool) -> Self {
        Scalar::Bool(v)
    }
}
impl From<i64> for Scalar {
    fn from(v: i64) -> Self {
        Scalar::I64(v)
    }
}
impl From<f64> for Scalar {
    fn from(v: f64) -> Self {
        Scalar::F64(v)
    }
}
impl From<&str> for Scalar {
    fn from(v: &str) -> Self {
        Scalar::Str(v.to_string())
    }
}
impl From<String> for Scalar {
    fn from(v: String) -> Self {
        Scalar::Str(v)
    }
}

/// Ordered string-keyed map of scalars (BTreeMap for deterministic wire
/// encoding — important for reproducible message hashes in tests).
pub type ConfigMap = BTreeMap<String, Scalar>;

/// Typed accessors with protocol-grade errors.
pub trait ConfigExt {
    fn get_i64(&self, key: &str) -> Result<i64>;
    fn get_f64(&self, key: &str) -> Result<f64>;
    fn get_str(&self, key: &str) -> Result<&str>;
    fn get_i64_or(&self, key: &str, default: i64) -> i64;
    fn get_f64_or(&self, key: &str, default: f64) -> f64;
    fn get_bool_or(&self, key: &str, default: bool) -> bool;
}

impl ConfigExt for ConfigMap {
    fn get_i64(&self, key: &str) -> Result<i64> {
        match self.get(key) {
            Some(Scalar::I64(v)) => Ok(*v),
            Some(other) => Err(Error::Protocol(format!(
                "config key {key:?}: expected i64, got {other:?}"
            ))),
            None => Err(Error::Protocol(format!("missing config key {key:?}"))),
        }
    }

    fn get_f64(&self, key: &str) -> Result<f64> {
        match self.get(key) {
            Some(Scalar::F64(v)) => Ok(*v),
            // be liberal: accept i64 where f64 is expected
            Some(Scalar::I64(v)) => Ok(*v as f64),
            Some(other) => Err(Error::Protocol(format!(
                "config key {key:?}: expected f64, got {other:?}"
            ))),
            None => Err(Error::Protocol(format!("missing config key {key:?}"))),
        }
    }

    fn get_str(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(Scalar::Str(v)) => Ok(v),
            Some(other) => Err(Error::Protocol(format!(
                "config key {key:?}: expected str, got {other:?}"
            ))),
            None => Err(Error::Protocol(format!("missing config key {key:?}"))),
        }
    }

    fn get_i64_or(&self, key: &str, default: i64) -> i64 {
        self.get_i64(key).unwrap_or(default)
    }

    fn get_f64_or(&self, key: &str, default: f64) -> f64 {
        self.get_f64(key).unwrap_or(default)
    }

    fn get_bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(Scalar::Bool(v)) => *v,
            _ => default,
        }
    }
}

/// Convenience constructor: `config!{ "epochs" => 5i64, "lr" => 0.05f64 }`.
#[macro_export]
macro_rules! config {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut m = $crate::proto::ConfigMap::new();
        $( m.insert($k.to_string(), $crate::proto::Scalar::from($v)); )*
        m
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_accessors() {
        let m = crate::config! { "epochs" => 5i64, "lr" => 0.05f64, "model" => "cifar_cnn" };
        assert_eq!(m.get_i64("epochs").unwrap(), 5);
        assert_eq!(m.get_f64("lr").unwrap(), 0.05);
        assert_eq!(m.get_str("model").unwrap(), "cifar_cnn");
        assert!(m.get_i64("nope").is_err());
        assert!(m.get_str("epochs").is_err());
    }

    #[test]
    fn f64_accepts_i64() {
        let m = crate::config! { "x" => 3i64 };
        assert_eq!(m.get_f64("x").unwrap(), 3.0);
    }

    #[test]
    fn defaults() {
        let m = ConfigMap::new();
        assert_eq!(m.get_i64_or("epochs", 1), 1);
        assert_eq!(m.get_f64_or("lr", 0.1), 0.1);
        assert!(m.get_bool_or("flag", true));
    }
}
