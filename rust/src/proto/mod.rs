//! The Flower Protocol: typed messages, parameter containers, and the
//! language-agnostic binary wire format.
//!
//! The paper's server is deliberately unaware of the nature of connected
//! clients (§3): it only speaks serialized tensors and scalar config maps.
//! This module mirrors that contract — [`Parameters`] is an opaque list of
//! shaped tensors, [`ConfigMap`] carries per-round hyper-parameters (e.g.
//! the number of local epochs, or the τ cutoff in seconds), and the
//! [`codec`] defines a byte-exact framing that a Java/Swift/C++ client
//! could implement independently.

pub mod codec;
pub mod message;
pub mod scalar;
pub mod tensor;

pub use codec::{decode_client_frame, decode_client_message, decode_server_frame,
                decode_server_message, encode_client_message, encode_client_message_v,
                encode_server_message, encode_server_message_v, negotiate_version, v2_f32_views,
                wire_version, BroadcastFrame, TensorView, MAX_WIRE_VERSION, VERSION_V2};
pub use message::{ClientInfo, ClientMessage, EvaluateIns, EvaluateRes, FitIns, FitRes,
                  GetParametersIns, GetParametersRes, ServerMessage, Status, StatusCode};
pub use scalar::{ConfigMap, Scalar};
pub use tensor::{Parameters, SharedF32, Tensor, TensorData};
