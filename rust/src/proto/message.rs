//! The Flower Protocol message set.
//!
//! Server→client: `GetParametersIns`, `FitIns`, `EvaluateIns`, `Reconnect`.
//! Client→server: `Register` (hello + device info), `GetParametersRes`,
//! `FitRes`, `EvaluateRes`, `Disconnect`.
//!
//! `FitRes.metrics` is the system-cost side channel the paper's evaluation
//! is built on: clients report modeled compute time, energy, steps executed
//! and whether a τ cutoff truncated their local epochs.

use super::scalar::ConfigMap;
use super::tensor::Parameters;

/// Outcome status attached to client responses (mirrors Flower's `Status`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatusCode {
    Ok,
    /// Client had no data / declined to participate.
    FitNotImplemented,
    /// Local training failed.
    FitError,
    /// Evaluation failed.
    EvaluateError,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Status {
    pub code: StatusCode,
    pub message: String,
}

impl Status {
    pub fn ok() -> Self {
        Status { code: StatusCode::Ok, message: String::new() }
    }

    pub fn is_ok(&self) -> bool {
        self.code == StatusCode::Ok
    }
}

/// Client self-description sent at registration. The server uses the
/// device name to look up the profile for comm-cost accounting, and the
/// strategy uses it to assign per-processor cutoffs (Table 3).
#[derive(Debug, Clone, PartialEq)]
pub struct ClientInfo {
    /// Stable client identifier (e.g. "tx2-03", "pixel4-aws-1").
    pub client_id: String,
    /// Device profile name, resolvable via `device::profiles::by_name`.
    pub device: String,
    /// Operating system string (informational, Table 1 flavor).
    pub os: String,
    /// Number of local training examples the client holds.
    pub num_examples: u64,
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct GetParametersIns {
    pub config: ConfigMap,
}

#[derive(Debug, Clone, PartialEq)]
pub struct GetParametersRes {
    pub status: Status,
    pub parameters: Parameters,
}

/// Server→client: train locally starting from `parameters`.
#[derive(Debug, Clone, PartialEq)]
pub struct FitIns {
    pub parameters: Parameters,
    pub config: ConfigMap,
}

/// Client→server: the locally updated parameters + metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct FitRes {
    pub status: Status,
    pub parameters: Parameters,
    pub num_examples: u64,
    pub metrics: ConfigMap,
}

/// Server→client: evaluate `parameters` on the local test split.
#[derive(Debug, Clone, PartialEq)]
pub struct EvaluateIns {
    pub parameters: Parameters,
    pub config: ConfigMap,
}

/// Client→server: local test loss (+ accuracy etc. in metrics).
#[derive(Debug, Clone, PartialEq)]
pub struct EvaluateRes {
    pub status: Status,
    pub loss: f64,
    pub num_examples: u64,
    pub metrics: ConfigMap,
}

/// All messages the server can send.
#[derive(Debug, Clone, PartialEq)]
pub enum ServerMessage {
    GetParametersIns(GetParametersIns),
    FitIns(FitIns),
    EvaluateIns(EvaluateIns),
    /// Ask the client to disconnect and reconnect after `seconds`.
    Reconnect { seconds: u64 },
    /// Version-negotiation reply: the highest wire version the server
    /// and the greeting client mutually support. Always encoded as a
    /// v1 frame so any peer can read it (see `transport/PROTOCOL.md`).
    HelloAck { version: u8 },
}

/// All messages a client can send.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMessage {
    /// Optional version-negotiation greeting, sent *before* `Register`
    /// by v2-capable clients: the highest wire version the client
    /// speaks. Always encoded as a v1 frame. Legacy peers skip straight
    /// to `Register` and stay on wire v1.
    Hello { max_version: u8 },
    /// First message on a fresh connection (after the optional
    /// `Hello`/`HelloAck` exchange).
    Register(ClientInfo),
    GetParametersRes(GetParametersRes),
    FitRes(FitRes),
    EvaluateRes(EvaluateRes),
    Disconnect { reason: String },
}

impl ServerMessage {
    /// Bytes of model parameters carried (for comm-cost accounting).
    pub fn parameter_bytes(&self) -> usize {
        match self {
            ServerMessage::FitIns(ins) => ins.parameters.byte_len(),
            ServerMessage::EvaluateIns(ins) => ins.parameters.byte_len(),
            _ => 0,
        }
    }
}

impl ClientMessage {
    /// Bytes of model parameters carried (for comm-cost accounting).
    pub fn parameter_bytes(&self) -> usize {
        match self {
            ClientMessage::FitRes(res) => res.parameters.byte_len(),
            ClientMessage::GetParametersRes(res) => res.parameters.byte_len(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_ok() {
        assert!(Status::ok().is_ok());
        let bad = Status { code: StatusCode::FitError, message: "x".into() };
        assert!(!bad.is_ok());
    }

    #[test]
    fn parameter_bytes_accounting() {
        let p = Parameters::from_flat(vec![0.0; 100]);
        let msg = ServerMessage::FitIns(FitIns { parameters: p.clone(), config: ConfigMap::new() });
        assert_eq!(msg.parameter_bytes(), 400);
        let msg = ServerMessage::Reconnect { seconds: 5 };
        assert_eq!(msg.parameter_bytes(), 0);
        let res = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: p,
            num_examples: 10,
            metrics: ConfigMap::new(),
        });
        assert_eq!(res.parameter_bytes(), 400);
    }
}
