//! Shaped tensors and the opaque `Parameters` container shipped between
//! server and clients.

use std::sync::Arc;

use crate::error::{Error, Result};

/// An f32 tensor payload *borrowed* out of a shared receive buffer —
/// the zero-copy wire-protocol-v2 decode form (see
/// `transport/PROTOCOL.md`).
///
/// Invariants, established by [`SharedF32::new`] and relied on by the
/// unsafe cast in [`SharedF32::as_slice`]:
/// * the region `[off, off + 4 * count)` is in bounds of `buf`;
/// * the region's actual address is 4-byte aligned (or `count == 0`);
/// * the target is little-endian, so the raw LE wire bytes *are* the
///   in-memory `f32` representation. On big-endian targets `new`
///   refuses and the decoder falls back to the copying path.
///
/// Cloning bumps the `Arc` refcount; the frame allocation lives until
/// the last view drops.
#[derive(Debug, Clone)]
pub struct SharedF32 {
    buf: Arc<Vec<u8>>,
    /// Byte offset of the first element within `buf`.
    off: usize,
    /// Element count.
    count: usize,
}

impl SharedF32 {
    /// Wrap `count` f32 elements at `byte_off` in `buf`, or `None` when
    /// the region is out of bounds, misaligned, or the target is
    /// big-endian (callers then copy instead — correctness never
    /// depends on taking the zero-copy path).
    pub fn new(buf: Arc<Vec<u8>>, byte_off: usize, count: usize) -> Option<Self> {
        if cfg!(target_endian = "big") {
            return None;
        }
        let len_bytes = count.checked_mul(4)?;
        let end = byte_off.checked_add(len_bytes)?;
        if end > buf.len() {
            return None;
        }
        if count > 0
            && buf[byte_off..].as_ptr().align_offset(std::mem::align_of::<f32>()) != 0
        {
            return None;
        }
        Some(SharedF32 { buf, off: byte_off, count })
    }

    /// The elements, borrowed straight from the shared buffer.
    pub fn as_slice(&self) -> &[f32] {
        if self.count == 0 {
            return &[];
        }
        // SAFETY: bounds, alignment and endianness guaranteed by `new`;
        // f32 accepts every bit pattern; the Arc'd buffer outlives the
        // borrow of self.
        unsafe {
            std::slice::from_raw_parts(
                self.buf.as_ptr().add(self.off) as *const f32,
                self.count,
            )
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.count
    }

    /// Whether the view is empty.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

/// Element storage for a [`Tensor`]. The FL payloads in this system are
/// f32 parameters and i32 labels; `F16` is the quantized wire form used
/// by the communication-compression path (half the bytes per round). The
/// enum keeps the wire format honest about dtypes instead of punning
/// everything through bytes. `F32Shared` is float32 data borrowed from
/// a shared receive buffer (the protocol-v2 zero-copy decode form) —
/// semantically identical to `F32`, so equality compares the two
/// variants by element values.
#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// IEEE binary16 bit patterns (see `util::f16`).
    F16(Vec<u16>),
    /// float32 elements borrowed from a shared receive buffer.
    F32Shared(SharedF32),
}

impl TensorData {
    /// The float32 view, if this is float32 data in either storage form.
    fn f32_slice(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            TensorData::F32Shared(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::F16(v) => v.len(),
            TensorData::F32Shared(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            TensorData::F32(_) | TensorData::F32Shared(_) => "float32",
            TensorData::I32(_) => "int32",
            TensorData::F16(_) => "float16",
        }
    }

    /// Bytes per element on the wire.
    pub fn element_bytes(&self) -> usize {
        match self {
            TensorData::F32(_) | TensorData::I32(_) | TensorData::F32Shared(_) => 4,
            TensorData::F16(_) => 2,
        }
    }
}

/// `F32` and `F32Shared` are the same logical dtype in two storage
/// forms, so they compare equal by element values — a v2 zero-copy
/// decode of an encoded message equals the original owned message.
impl PartialEq for TensorData {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (TensorData::I32(a), TensorData::I32(b)) => a == b,
            (TensorData::F16(a), TensorData::F16(b)) => a == b,
            (a, b) => match (a.f32_slice(), b.f32_slice()) {
                (Some(x), Some(y)) => x == y,
                _ => false,
            },
        }
    }
}

/// A dense, row-major tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    /// Build an f32 tensor, validating that the shape matches the data.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if expect != data.len() {
            return Err(Error::Protocol(format!(
                "tensor shape {shape:?} wants {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data: TensorData::F32(data) })
    }

    /// Build an i32 tensor, validating that the shape matches the data.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if expect != data.len() {
            return Err(Error::Protocol(format!(
                "tensor shape {shape:?} wants {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data: TensorData::I32(data) })
    }

    /// A scalar (rank-0) f32 tensor.
    pub fn scalar_f32(v: f32) -> Self {
        Tensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Payload size on the wire (element bytes only). f16 tensors carry
    /// half the bytes — this is what the comm-cost model sees.
    pub fn byte_len(&self) -> usize {
        self.data.element_bytes() * self.data.len()
    }

    /// Quantize an f32 tensor to f16 (no-op on already-f16 data).
    pub fn quantize_f16(&self) -> Result<Tensor> {
        match &self.data {
            TensorData::F32(v) => Ok(Tensor {
                shape: self.shape.clone(),
                data: TensorData::F16(crate::util::f16::quantize(v)),
            }),
            TensorData::F32Shared(v) => Ok(Tensor {
                shape: self.shape.clone(),
                data: TensorData::F16(crate::util::f16::quantize(v.as_slice())),
            }),
            TensorData::F16(_) => Ok(self.clone()),
            other => Err(Error::Protocol(format!(
                "cannot f16-quantize {} tensor",
                other.dtype_name()
            ))),
        }
    }

    /// Materialize as f32 values (dequantizing f16 if needed).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match &self.data {
            TensorData::F32(v) => Ok(v.clone()),
            TensorData::F32Shared(v) => Ok(v.as_slice().to_vec()),
            TensorData::F16(v) => Ok(crate::util::f16::dequantize(v)),
            other => Err(Error::Protocol(format!(
                "expected float tensor, got {}",
                other.dtype_name()
            ))),
        }
    }

    /// Borrow the f32 payload or fail with a protocol error. For
    /// `F32Shared` tensors the borrow points straight into the shared
    /// receive buffer — no copy.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::F32Shared(v) => Ok(v.as_slice()),
            other => Err(Error::Protocol(format!(
                "expected float32 tensor, got {}",
                other.dtype_name()
            ))),
        }
    }

    /// Borrow the i32 payload or fail with a protocol error.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => Err(Error::Protocol(format!(
                "expected int32 tensor, got {}",
                other.dtype_name()
            ))),
        }
    }

    /// Consume into the f32 payload or fail with a protocol error.
    /// `F32Shared` tensors materialize here (this is the one owned-exit
    /// point; the fold path stays on [`Tensor::as_f32`]).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::F32Shared(v) => Ok(v.as_slice().to_vec()),
            other => Err(Error::Protocol(format!(
                "expected float32 tensor, got {}",
                other.dtype_name()
            ))),
        }
    }
}

/// The opaque model-parameter container of the Flower Protocol.
///
/// For both paper workloads this is a single flat f32 vector (the Rust
/// coordinator never needs the pytree layout — that lives in the artifact
/// manifest), but the container is a list so multi-tensor models work too.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Parameters {
    pub tensors: Vec<Tensor>,
}

impl Parameters {
    /// Wrap a single flat f32 parameter vector.
    pub fn from_flat(flat: Vec<f32>) -> Self {
        let n = flat.len();
        Parameters {
            tensors: vec![Tensor { shape: vec![n], data: TensorData::F32(flat) }],
        }
    }

    /// Unwrap a single flat f32 parameter vector.
    pub fn to_flat(&self) -> Result<&[f32]> {
        match self.tensors.as_slice() {
            [t] => t.as_f32(),
            other => Err(Error::Protocol(format!(
                "expected 1 parameter tensor, got {}",
                other.len()
            ))),
        }
    }

    /// Total wire payload in bytes — drives the communication cost model.
    pub fn byte_len(&self) -> usize {
        self.tensors.iter().map(Tensor::byte_len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Quantize every tensor to f16 (the compressed wire form).
    pub fn quantize_f16(&self) -> Result<Parameters> {
        Ok(Parameters {
            tensors: self
                .tensors
                .iter()
                .map(Tensor::quantize_f16)
                .collect::<Result<_>>()?,
        })
    }

    /// Materialize a single flat f32 vector, dequantizing f16 if needed.
    pub fn to_flat_vec(&self) -> Result<Vec<f32>> {
        match self.tensors.as_slice() {
            [t] => t.to_f32_vec(),
            other => Err(Error::Protocol(format!(
                "expected 1 parameter tensor, got {}",
                other.len()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
        assert!(Tensor::i32(vec![4], vec![1]).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar_f32(0.5);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.as_f32().unwrap(), &[0.5]);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn parameters_flat_roundtrip() {
        let p = Parameters::from_flat(vec![1.0, 2.0, 3.0]);
        assert_eq!(p.to_flat().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(p.byte_len(), 12);
    }

    #[test]
    fn parameters_multi_tensor_to_flat_fails() {
        let p = Parameters {
            tensors: vec![Tensor::scalar_f32(1.0), Tensor::scalar_f32(2.0)],
        };
        assert!(p.to_flat().is_err());
    }

    #[test]
    fn f16_quantization_halves_bytes() {
        let p = Parameters::from_flat(vec![0.5; 1000]);
        assert_eq!(p.byte_len(), 4000);
        let q = p.quantize_f16().unwrap();
        assert_eq!(q.byte_len(), 2000);
        // exact roundtrip for values representable in f16
        assert_eq!(q.to_flat_vec().unwrap(), vec![0.5; 1000]);
        // and q.to_flat (strict f32 view) must refuse
        assert!(q.to_flat().is_err());
    }

    #[test]
    fn quantize_rejects_int_tensors() {
        let t = Tensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.quantize_f16().is_err());
        assert!(t.to_f32_vec().is_err());
    }

    /// LE bytes of `vals` wrapped as a SharedF32 view (skips on the
    /// unlikely misaligned allocation — the copy-fallback case).
    fn shared(vals: &[f32]) -> Option<SharedF32> {
        let mut bytes = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        SharedF32::new(Arc::new(bytes), 0, vals.len())
    }

    #[test]
    fn shared_f32_view_borrows_without_copy() {
        let Some(s) = shared(&[1.0, -2.5, 3.25]) else { return };
        assert_eq!(s.as_slice(), &[1.0, -2.5, 3.25]);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
        // the view aliases the buffer, it does not own a copy
        let c = s.clone();
        assert_eq!(c.as_slice().as_ptr(), s.as_slice().as_ptr());
    }

    #[test]
    fn shared_f32_rejects_bad_regions() {
        let buf = Arc::new(vec![0u8; 16]);
        // out of bounds
        assert!(SharedF32::new(Arc::clone(&buf), 4, 4).is_none());
        assert!(SharedF32::new(Arc::clone(&buf), usize::MAX, 1).is_none());
        // count overflow
        assert!(SharedF32::new(Arc::clone(&buf), 0, usize::MAX / 2).is_none());
        // empty views are always fine, any offset in bounds
        let empty = SharedF32::new(Arc::clone(&buf), 16, 0).unwrap();
        assert_eq!(empty.as_slice(), &[] as &[f32]);
    }

    #[test]
    fn owned_and_shared_f32_compare_equal_by_value() {
        let Some(s) = shared(&[1.0, 2.0]) else { return };
        let owned = TensorData::F32(vec![1.0, 2.0]);
        let view = TensorData::F32Shared(s);
        assert_eq!(owned, view);
        assert_eq!(view, owned);
        assert_eq!(view.dtype_name(), "float32");
        assert_eq!(view.element_bytes(), 4);
        assert_ne!(TensorData::F32(vec![1.0, 2.5]), view);
        assert_ne!(TensorData::I32(vec![1, 2]), view);
        // full-tensor surface: as_f32 / to_f32_vec / into_f32 / quantize
        let t = Tensor { shape: vec![2], data: view };
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(t.to_f32_vec().unwrap(), vec![1.0, 2.0]);
        assert_eq!(t.byte_len(), 8);
        assert!(t.quantize_f16().is_ok());
        assert_eq!(t.clone().into_f32().unwrap(), vec![1.0, 2.0]);
        // and the Parameters fold entry point sees the borrowed slice
        let p = Parameters { tensors: vec![t] };
        assert_eq!(p.to_flat().unwrap(), &[1.0, 2.0]);
    }
}
