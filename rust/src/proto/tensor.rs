//! Shaped tensors and the opaque `Parameters` container shipped between
//! server and clients.

use crate::error::{Error, Result};

/// Element storage for a [`Tensor`]. The FL payloads in this system are
/// f32 parameters and i32 labels; `F16` is the quantized wire form used
/// by the communication-compression path (half the bytes per round). The
/// enum keeps the wire format honest about dtypes instead of punning
/// everything through bytes.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// IEEE binary16 bit patterns (see `util::f16`).
    F16(Vec<u16>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            TensorData::F32(_) => "float32",
            TensorData::I32(_) => "int32",
            TensorData::F16(_) => "float16",
        }
    }

    /// Bytes per element on the wire.
    pub fn element_bytes(&self) -> usize {
        match self {
            TensorData::F32(_) | TensorData::I32(_) => 4,
            TensorData::F16(_) => 2,
        }
    }
}

/// A dense, row-major tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    /// Build an f32 tensor, validating that the shape matches the data.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if expect != data.len() {
            return Err(Error::Protocol(format!(
                "tensor shape {shape:?} wants {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data: TensorData::F32(data) })
    }

    /// Build an i32 tensor, validating that the shape matches the data.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if expect != data.len() {
            return Err(Error::Protocol(format!(
                "tensor shape {shape:?} wants {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data: TensorData::I32(data) })
    }

    /// A scalar (rank-0) f32 tensor.
    pub fn scalar_f32(v: f32) -> Self {
        Tensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Payload size on the wire (element bytes only). f16 tensors carry
    /// half the bytes — this is what the comm-cost model sees.
    pub fn byte_len(&self) -> usize {
        self.data.element_bytes() * self.data.len()
    }

    /// Quantize an f32 tensor to f16 (no-op on already-f16 data).
    pub fn quantize_f16(&self) -> Result<Tensor> {
        match &self.data {
            TensorData::F32(v) => Ok(Tensor {
                shape: self.shape.clone(),
                data: TensorData::F16(crate::util::f16::quantize(v)),
            }),
            TensorData::F16(_) => Ok(self.clone()),
            other => Err(Error::Protocol(format!(
                "cannot f16-quantize {} tensor",
                other.dtype_name()
            ))),
        }
    }

    /// Materialize as f32 values (dequantizing f16 if needed).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match &self.data {
            TensorData::F32(v) => Ok(v.clone()),
            TensorData::F16(v) => Ok(crate::util::f16::dequantize(v)),
            other => Err(Error::Protocol(format!(
                "expected float tensor, got {}",
                other.dtype_name()
            ))),
        }
    }

    /// Borrow the f32 payload or fail with a protocol error.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => Err(Error::Protocol(format!(
                "expected float32 tensor, got {}",
                other.dtype_name()
            ))),
        }
    }

    /// Borrow the i32 payload or fail with a protocol error.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => Err(Error::Protocol(format!(
                "expected int32 tensor, got {}",
                other.dtype_name()
            ))),
        }
    }

    /// Consume into the f32 payload or fail with a protocol error.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            other => Err(Error::Protocol(format!(
                "expected float32 tensor, got {}",
                other.dtype_name()
            ))),
        }
    }
}

/// The opaque model-parameter container of the Flower Protocol.
///
/// For both paper workloads this is a single flat f32 vector (the Rust
/// coordinator never needs the pytree layout — that lives in the artifact
/// manifest), but the container is a list so multi-tensor models work too.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Parameters {
    pub tensors: Vec<Tensor>,
}

impl Parameters {
    /// Wrap a single flat f32 parameter vector.
    pub fn from_flat(flat: Vec<f32>) -> Self {
        let n = flat.len();
        Parameters {
            tensors: vec![Tensor { shape: vec![n], data: TensorData::F32(flat) }],
        }
    }

    /// Unwrap a single flat f32 parameter vector.
    pub fn to_flat(&self) -> Result<&[f32]> {
        match self.tensors.as_slice() {
            [t] => t.as_f32(),
            other => Err(Error::Protocol(format!(
                "expected 1 parameter tensor, got {}",
                other.len()
            ))),
        }
    }

    /// Total wire payload in bytes — drives the communication cost model.
    pub fn byte_len(&self) -> usize {
        self.tensors.iter().map(Tensor::byte_len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Quantize every tensor to f16 (the compressed wire form).
    pub fn quantize_f16(&self) -> Result<Parameters> {
        Ok(Parameters {
            tensors: self
                .tensors
                .iter()
                .map(Tensor::quantize_f16)
                .collect::<Result<_>>()?,
        })
    }

    /// Materialize a single flat f32 vector, dequantizing f16 if needed.
    pub fn to_flat_vec(&self) -> Result<Vec<f32>> {
        match self.tensors.as_slice() {
            [t] => t.to_f32_vec(),
            other => Err(Error::Protocol(format!(
                "expected 1 parameter tensor, got {}",
                other.len()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
        assert!(Tensor::i32(vec![4], vec![1]).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar_f32(0.5);
        assert_eq!(t.shape, Vec::<usize>::new());
        assert_eq!(t.element_count(), 1);
        assert_eq!(t.as_f32().unwrap(), &[0.5]);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn parameters_flat_roundtrip() {
        let p = Parameters::from_flat(vec![1.0, 2.0, 3.0]);
        assert_eq!(p.to_flat().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(p.byte_len(), 12);
    }

    #[test]
    fn parameters_multi_tensor_to_flat_fails() {
        let p = Parameters {
            tensors: vec![Tensor::scalar_f32(1.0), Tensor::scalar_f32(2.0)],
        };
        assert!(p.to_flat().is_err());
    }

    #[test]
    fn f16_quantization_halves_bytes() {
        let p = Parameters::from_flat(vec![0.5; 1000]);
        assert_eq!(p.byte_len(), 4000);
        let q = p.quantize_f16().unwrap();
        assert_eq!(q.byte_len(), 2000);
        // exact roundtrip for values representable in f16
        assert_eq!(q.to_flat_vec().unwrap(), vec![0.5; 1000]);
        // and q.to_flat (strict f32 view) must refuse
        assert!(q.to_flat().is_err());
    }

    #[test]
    fn quantize_rejects_int_tensors() {
        let t = Tensor::i32(vec![2], vec![1, 2]).unwrap();
        assert!(t.quantize_f16().is_err());
        assert!(t.to_f32_vec().is_err());
    }
}
