//! Hand-rolled binary wire format for the Flower Protocol.
//!
//! The paper's framework achieves language-agnosticism "by offering
//! protocol-level integration" (§3): any client that can speak the byte
//! format participates, regardless of language or ML framework. This codec
//! is that byte format, pinned precisely enough that a Java/Swift/C++
//! implementation could be written from this file alone:
//!
//! ```text
//! message   := magic:u16(0xF10E) version:u8(1) tag:u8 body
//! ints      := little-endian
//! bytes     := len:u32 data[len]
//! string    := bytes (UTF-8)
//! tensor    := dtype:u8 (0=f32, 1=i32) rank:u8 dims:u32[rank] raw-LE data
//! params    := count:u16 tensor[count]
//! scalar    := tag:u8 (0=bool,1=i64,2=f64,3=str,4=bytes) value
//! configmap := count:u32 (string scalar)[count]
//! status    := code:u8 string
//! ```
//!
//! Framing (length prefix) is the transport's job — see `transport::frame`.
//!
//! **Wire version 2** (negotiated via `Hello`/`HelloAck`, see
//! `transport/PROTOCOL.md` for the normative spec) moves the four
//! tensor-bearing messages to a hybrid *header + raw body* layout so
//! tensor payloads decode **zero-copy** out of the receive buffer:
//!
//! ```text
//! v2 message := magic:u16 version:u8(2) tag:u8 header_len:u32
//!               header[header_len] pad[0..3](zero) body
//! manifest   := count:u16 entry[count]
//! entry      := dtype:u8 rank:u8 dims:u32[rank] byte_off:u32 byte_len:u32
//! ```
//!
//! The header carries the v1 composite fields with `params` replaced by
//! the manifest; `byte_off` is relative to the body start, every tensor
//! start is 4-byte aligned (the body itself starts 4-aligned relative
//! to the message start), and the body is raw little-endian element
//! bytes. Decoding borrows f32 tensors straight from the shared frame
//! buffer ([`SharedF32`] / [`TensorView`]); misalignment or a
//! big-endian host falls back to copying — the *bytes* are identical
//! either way. All other messages stay v1 on every connection.
//!
//! The little-endian primitives live in [`crate::util::bytes`] (shared
//! with the checkpoint container and transport framing); this module
//! owns only the protocol's composite encodings. The wire bytes are
//! pinned by golden vectors and a differential property test against
//! the pre-refactor hand-rolled encoder (`rust/tests/proptests.rs`),
//! with the v2 layout pinned in `rust/tests/wire_v2.rs`.

use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};
use crate::util::bytes::{FrameBuf, LeReader, LeWriter};

use super::message::*;
use super::scalar::{ConfigMap, Scalar};
use super::tensor::{Parameters, SharedF32, Tensor, TensorData};

pub const MAGIC: u16 = 0xF10E;
pub const VERSION: u8 = 1;
/// The zero-copy header+body wire version.
pub const VERSION_V2: u8 = 2;
/// Highest wire version this build speaks (what `HelloAck` caps at).
pub const MAX_WIRE_VERSION: u8 = VERSION_V2;

// Server message tags.
const TAG_GET_PARAMETERS_INS: u8 = 0x01;
const TAG_FIT_INS: u8 = 0x02;
const TAG_EVALUATE_INS: u8 = 0x03;
const TAG_RECONNECT: u8 = 0x04;
const TAG_HELLO_ACK: u8 = 0x05;
// Client message tags.
const TAG_REGISTER: u8 = 0x81;
const TAG_GET_PARAMETERS_RES: u8 = 0x82;
const TAG_FIT_RES: u8 = 0x83;
const TAG_EVALUATE_RES: u8 = 0x84;
const TAG_DISCONNECT: u8 = 0x85;
const TAG_HELLO: u8 = 0x86;

/// The negotiation rule (server side): answer a client's `Hello` with
/// the highest mutually-supported wire version, never below v1. A v1
/// peer that skips the `Hello` entirely simply stays on v1.
pub fn negotiate_version(client_max: u8) -> u8 {
    client_max.clamp(VERSION, MAX_WIRE_VERSION)
}

/// Peek the wire version of an encoded message (validates the magic).
pub fn wire_version(payload: &[u8]) -> Result<u8> {
    if payload.len() < 4 {
        return Err(Error::Codec(format!(
            "message too short for a header: {} bytes",
            payload.len()
        )));
    }
    let magic = u16::from_le_bytes([payload[0], payload[1]]);
    if magic != MAGIC {
        return Err(Error::Codec(format!("bad magic {magic:#06x}")));
    }
    Ok(payload[2])
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Wire-format writer: the shared [`LeWriter`] primitives plus the
/// protocol's composite encodings (length-prefixed bytes, tensors,
/// scalars, config maps).
struct Writer {
    w: LeWriter,
}

impl Writer {
    fn with_header(tag: u8, capacity: usize) -> Self {
        let mut w = Writer { w: LeWriter::with_capacity(capacity + 4) };
        w.w.u16(MAGIC);
        w.w.u8(VERSION);
        w.w.u8(tag);
        w
    }

    fn finish(self) -> Vec<u8> {
        self.w.into_bytes()
    }

    fn u8(&mut self, v: u8) {
        self.w.u8(v);
    }
    fn u16(&mut self, v: u16) {
        self.w.u16(v);
    }
    fn u32(&mut self, v: u32) {
        self.w.u32(v);
    }
    fn u64(&mut self, v: u64) {
        self.w.u64(v);
    }
    fn i64(&mut self, v: i64) {
        self.w.i64(v);
    }
    fn f64(&mut self, v: f64) {
        self.w.f64(v);
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.w.raw(v);
    }

    fn string(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    fn f32_tensor(&mut self, shape: &[usize], v: &[f32]) {
        self.u8(0);
        self.u8(shape.len() as u8);
        for &d in shape {
            self.u32(d as u32);
        }
        self.u32(v.len() as u32);
        // bulk copy: f32 LE
        self.w.reserve(v.len() * 4);
        for &x in v {
            self.w.f32(x);
        }
    }

    fn tensor(&mut self, t: &Tensor) {
        match &t.data {
            TensorData::F32(v) => self.f32_tensor(&t.shape, v),
            // same logical dtype, same v1 bytes
            TensorData::F32Shared(v) => self.f32_tensor(&t.shape, v.as_slice()),
            TensorData::I32(v) => {
                self.u8(1);
                self.u8(t.shape.len() as u8);
                for &d in &t.shape {
                    self.u32(d as u32);
                }
                self.u32(v.len() as u32);
                self.w.reserve(v.len() * 4);
                for &x in v {
                    self.w.raw(&x.to_le_bytes());
                }
            }
            TensorData::F16(v) => {
                self.u8(2);
                self.u8(t.shape.len() as u8);
                for &d in &t.shape {
                    self.u32(d as u32);
                }
                self.u32(v.len() as u32);
                self.w.reserve(v.len() * 2);
                for &x in v {
                    self.w.u16(x);
                }
            }
        }
    }

    fn parameters(&mut self, p: &Parameters) {
        self.u16(p.tensors.len() as u16);
        for t in &p.tensors {
            self.tensor(t);
        }
    }

    fn scalar(&mut self, s: &Scalar) {
        match s {
            Scalar::Bool(v) => {
                self.u8(0);
                self.u8(u8::from(*v));
            }
            Scalar::I64(v) => {
                self.u8(1);
                self.i64(*v);
            }
            Scalar::F64(v) => {
                self.u8(2);
                self.f64(*v);
            }
            Scalar::Str(v) => {
                self.u8(3);
                self.string(v);
            }
            Scalar::Bytes(v) => {
                self.u8(4);
                self.bytes(v);
            }
        }
    }

    fn config(&mut self, m: &ConfigMap) {
        self.u32(m.len() as u32);
        for (k, v) in m {
            self.string(k);
            self.scalar(v);
        }
    }

    fn status(&mut self, s: &Status) {
        let code = match s.code {
            StatusCode::Ok => 0u8,
            StatusCode::FitNotImplemented => 1,
            StatusCode::FitError => 2,
            StatusCode::EvaluateError => 3,
        };
        self.u8(code);
        self.string(&s.message);
    }
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// Wire-format reader: a [`LeReader`] with `Error::Codec` as its error
/// category, plus the protocol's composite decoders.
struct Reader<'a> {
    r: LeReader<'a>,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { r: LeReader::new(buf, Error::Codec) }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.r.take(n)
    }

    fn u8(&mut self) -> Result<u8> {
        self.r.u8()
    }
    fn u16(&mut self) -> Result<u16> {
        self.r.u16()
    }
    fn u32(&mut self) -> Result<u32> {
        self.r.u32()
    }
    fn u64(&mut self) -> Result<u64> {
        self.r.u64()
    }
    fn i64(&mut self) -> Result<i64> {
        self.r.i64()
    }
    fn f64(&mut self) -> Result<f64> {
        self.r.f64()
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b).map_err(|e| Error::Codec(format!("bad utf8 string: {e}")))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let dtype = self.u8()?;
        let rank = self.u8()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        let n = self.u32()? as usize;
        let expect: usize = shape.iter().product();
        if expect != n {
            return Err(Error::Codec(format!(
                "tensor shape {shape:?} wants {expect} elements, wire says {n}"
            )));
        }
        let data = match dtype {
            0 => {
                let raw = self.take(n * 4)?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                TensorData::F32(v)
            }
            1 => {
                let raw = self.take(n * 4)?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(i32::from_le_bytes(c.try_into().unwrap()));
                }
                TensorData::I32(v)
            }
            2 => {
                let raw = self.take(n * 2)?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(2) {
                    v.push(u16::from_le_bytes(c.try_into().unwrap()));
                }
                TensorData::F16(v)
            }
            other => return Err(Error::Codec(format!("unknown tensor dtype {other}"))),
        };
        Ok(Tensor { shape, data })
    }

    fn parameters(&mut self) -> Result<Parameters> {
        let count = self.u16()? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            tensors.push(self.tensor()?);
        }
        Ok(Parameters { tensors })
    }

    fn scalar(&mut self) -> Result<Scalar> {
        match self.u8()? {
            0 => Ok(Scalar::Bool(self.u8()? != 0)),
            1 => Ok(Scalar::I64(self.i64()?)),
            2 => Ok(Scalar::F64(self.f64()?)),
            3 => Ok(Scalar::Str(self.string()?)),
            4 => Ok(Scalar::Bytes(self.bytes()?)),
            other => Err(Error::Codec(format!("unknown scalar tag {other}"))),
        }
    }

    fn config(&mut self) -> Result<ConfigMap> {
        let count = self.u32()? as usize;
        let mut m = ConfigMap::new();
        for _ in 0..count {
            let k = self.string()?;
            let v = self.scalar()?;
            m.insert(k, v);
        }
        Ok(m)
    }

    fn status(&mut self) -> Result<Status> {
        let code = match self.u8()? {
            0 => StatusCode::Ok,
            1 => StatusCode::FitNotImplemented,
            2 => StatusCode::FitError,
            3 => StatusCode::EvaluateError,
            other => return Err(Error::Codec(format!("unknown status code {other}"))),
        };
        Ok(Status { code, message: self.string()? })
    }

    fn finish(&self) -> Result<()> {
        self.r.expect_end("message")
    }
}

fn read_header(r: &mut Reader) -> Result<u8> {
    let magic = r.u16()?;
    if magic != MAGIC {
        return Err(Error::Codec(format!("bad magic {magic:#06x}")));
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(Error::Codec(format!("unsupported protocol version {version}")));
    }
    r.u8()
}

// ---------------------------------------------------------------------------
// Public encode/decode
// ---------------------------------------------------------------------------

/// Encode a server→client message to bytes.
pub fn encode_server_message(msg: &ServerMessage) -> Vec<u8> {
    match msg {
        ServerMessage::GetParametersIns(ins) => {
            let mut w = Writer::with_header(TAG_GET_PARAMETERS_INS, 64);
            w.config(&ins.config);
            w.finish()
        }
        ServerMessage::FitIns(ins) => {
            let mut w = Writer::with_header(TAG_FIT_INS, ins.parameters.byte_len() + 256);
            w.parameters(&ins.parameters);
            w.config(&ins.config);
            w.finish()
        }
        ServerMessage::EvaluateIns(ins) => {
            let mut w = Writer::with_header(TAG_EVALUATE_INS, ins.parameters.byte_len() + 256);
            w.parameters(&ins.parameters);
            w.config(&ins.config);
            w.finish()
        }
        ServerMessage::Reconnect { seconds } => {
            let mut w = Writer::with_header(TAG_RECONNECT, 8);
            w.u64(*seconds);
            w.finish()
        }
        ServerMessage::HelloAck { version } => {
            let mut w = Writer::with_header(TAG_HELLO_ACK, 1);
            w.u8(*version);
            w.finish()
        }
    }
}

/// Decode a server→client message.
pub fn decode_server_message(buf: &[u8]) -> Result<ServerMessage> {
    let mut r = Reader::new(buf);
    let tag = read_header(&mut r)?;
    let msg = match tag {
        TAG_GET_PARAMETERS_INS => {
            ServerMessage::GetParametersIns(GetParametersIns { config: r.config()? })
        }
        TAG_FIT_INS => ServerMessage::FitIns(FitIns {
            parameters: r.parameters()?,
            config: r.config()?,
        }),
        TAG_EVALUATE_INS => ServerMessage::EvaluateIns(EvaluateIns {
            parameters: r.parameters()?,
            config: r.config()?,
        }),
        TAG_RECONNECT => ServerMessage::Reconnect { seconds: r.u64()? },
        TAG_HELLO_ACK => ServerMessage::HelloAck { version: r.u8()? },
        other => return Err(Error::Codec(format!("unknown server message tag {other:#04x}"))),
    };
    r.finish()?;
    Ok(msg)
}

/// Encode a client→server message to bytes.
pub fn encode_client_message(msg: &ClientMessage) -> Vec<u8> {
    match msg {
        ClientMessage::Hello { max_version } => {
            let mut w = Writer::with_header(TAG_HELLO, 1);
            w.u8(*max_version);
            w.finish()
        }
        ClientMessage::Register(info) => {
            let mut w = Writer::with_header(TAG_REGISTER, 128);
            w.string(&info.client_id);
            w.string(&info.device);
            w.string(&info.os);
            w.u64(info.num_examples);
            w.finish()
        }
        ClientMessage::GetParametersRes(res) => {
            let mut w = Writer::with_header(TAG_GET_PARAMETERS_RES, res.parameters.byte_len() + 64);
            w.status(&res.status);
            w.parameters(&res.parameters);
            w.finish()
        }
        ClientMessage::FitRes(res) => {
            let mut w = Writer::with_header(TAG_FIT_RES, res.parameters.byte_len() + 256);
            w.status(&res.status);
            w.parameters(&res.parameters);
            w.u64(res.num_examples);
            w.config(&res.metrics);
            w.finish()
        }
        ClientMessage::EvaluateRes(res) => {
            let mut w = Writer::with_header(TAG_EVALUATE_RES, 256);
            w.status(&res.status);
            w.f64(res.loss);
            w.u64(res.num_examples);
            w.config(&res.metrics);
            w.finish()
        }
        ClientMessage::Disconnect { reason } => {
            let mut w = Writer::with_header(TAG_DISCONNECT, reason.len() + 8);
            w.string(reason);
            w.finish()
        }
    }
}

/// Decode a client→server message.
pub fn decode_client_message(buf: &[u8]) -> Result<ClientMessage> {
    let mut r = Reader::new(buf);
    let tag = read_header(&mut r)?;
    let msg = match tag {
        TAG_REGISTER => ClientMessage::Register(ClientInfo {
            client_id: r.string()?,
            device: r.string()?,
            os: r.string()?,
            num_examples: r.u64()?,
        }),
        TAG_GET_PARAMETERS_RES => ClientMessage::GetParametersRes(GetParametersRes {
            status: r.status()?,
            parameters: r.parameters()?,
        }),
        TAG_FIT_RES => ClientMessage::FitRes(FitRes {
            status: r.status()?,
            parameters: r.parameters()?,
            num_examples: r.u64()?,
            metrics: r.config()?,
        }),
        TAG_EVALUATE_RES => ClientMessage::EvaluateRes(EvaluateRes {
            status: r.status()?,
            loss: r.f64()?,
            num_examples: r.u64()?,
            metrics: r.config()?,
        }),
        TAG_DISCONNECT => ClientMessage::Disconnect { reason: r.string()? },
        TAG_HELLO => ClientMessage::Hello { max_version: r.u8()? },
        other => return Err(Error::Codec(format!("unknown client message tag {other:#04x}"))),
    };
    r.finish()?;
    Ok(msg)
}

// ---------------------------------------------------------------------------
// Wire v2: structured header + raw tensor body (zero-copy decode)
// ---------------------------------------------------------------------------

fn dtype_code(t: &Tensor) -> u8 {
    match &t.data {
        TensorData::F32(_) | TensorData::F32Shared(_) => 0,
        TensorData::I32(_) => 1,
        TensorData::F16(_) => 2,
    }
}

/// Per-tensor `(byte_off, byte_len)` body layout: tensors packed in
/// order, every tensor start 4-byte aligned (so a 4-aligned frame
/// buffer makes every f32 region castable in place). Returns the
/// layout and the total body length.
fn body_layout(p: &Parameters) -> (Vec<(u32, u32)>, usize) {
    let mut layout = Vec::with_capacity(p.tensors.len());
    let mut off = 0usize;
    for t in &p.tensors {
        off = (off + 3) & !3;
        let len = t.byte_len();
        layout.push((off as u32, len as u32));
        off += len;
    }
    (layout, off)
}

#[cfg(target_endian = "little")]
fn f32_le_bytes(v: &[f32]) -> std::borrow::Cow<'_, [u8]> {
    // SAFETY: u8 has alignment 1, every byte of an f32 is initialized,
    // and on a little-endian target the in-memory bytes are exactly the
    // wire bytes — this is the single bulk write that replaces the v1
    // per-element encode loop.
    std::borrow::Cow::Borrowed(unsafe {
        std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4)
    })
}

#[cfg(target_endian = "big")]
fn f32_le_bytes(v: &[f32]) -> std::borrow::Cow<'_, [u8]> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    std::borrow::Cow::Owned(out)
}

fn write_tensor_body(w: &mut LeWriter, t: &Tensor) {
    match &t.data {
        TensorData::F32(v) => w.raw(&f32_le_bytes(v)),
        TensorData::F32Shared(v) => w.raw(&f32_le_bytes(v.as_slice())),
        TensorData::I32(v) => {
            w.reserve(v.len() * 4);
            for x in v {
                w.raw(&x.to_le_bytes());
            }
        }
        TensorData::F16(v) => {
            w.reserve(v.len() * 2);
            for &x in v {
                w.u16(x);
            }
        }
    }
}

/// Assemble a v2 message: `pre` writes the header fields that come
/// before the tensor manifest (e.g. a response status), `post` the ones
/// after it (configs, counters) — same field order as the v1 body, with
/// `params` swapped for the manifest.
fn encode_v2(
    tag: u8,
    params: &Parameters,
    pre: impl FnOnce(&mut Writer),
    post: impl FnOnce(&mut Writer),
) -> Vec<u8> {
    let (layout, body_len) = body_layout(params);
    let mut h = Writer { w: LeWriter::with_capacity(128) };
    pre(&mut h);
    h.u16(params.tensors.len() as u16);
    for (t, &(off, len)) in params.tensors.iter().zip(&layout) {
        h.u8(dtype_code(t));
        h.u8(t.shape.len() as u8);
        for &d in &t.shape {
            h.u32(d as u32);
        }
        h.u32(off);
        h.u32(len);
    }
    post(&mut h);
    let header = h.finish();

    let pad = (4 - header.len() % 4) % 4;
    let mut w = LeWriter::with_capacity(8 + header.len() + pad + body_len);
    w.u16(MAGIC);
    w.u8(VERSION_V2);
    w.u8(tag);
    w.u32(header.len() as u32);
    w.raw(&header);
    w.raw(&[0u8; 3][..pad]);
    let mut cursor = 0usize;
    for (t, &(off, _)) in params.tensors.iter().zip(&layout) {
        w.raw(&[0u8; 3][..off as usize - cursor]);
        write_tensor_body(&mut w, t);
        cursor = off as usize + t.byte_len();
    }
    w.into_bytes()
}

/// Encode a server→client message for a negotiated wire version. On v2
/// connections the tensor-bearing messages (`FitIns`, `EvaluateIns`)
/// use the header+body layout; everything else — and every message on a
/// v1 connection — goes through the v1 codec unchanged.
pub fn encode_server_message_v(msg: &ServerMessage, wire: u8) -> Vec<u8> {
    if wire >= VERSION_V2 {
        match msg {
            ServerMessage::FitIns(ins) => {
                return encode_v2(TAG_FIT_INS, &ins.parameters, |_| {}, |h| {
                    h.config(&ins.config)
                });
            }
            ServerMessage::EvaluateIns(ins) => {
                return encode_v2(TAG_EVALUATE_INS, &ins.parameters, |_| {}, |h| {
                    h.config(&ins.config)
                });
            }
            _ => {}
        }
    }
    encode_server_message(msg)
}

/// Client→server counterpart of [`encode_server_message_v`]: `FitRes`
/// and `GetParametersRes` take the v2 layout on v2 connections.
pub fn encode_client_message_v(msg: &ClientMessage, wire: u8) -> Vec<u8> {
    if wire >= VERSION_V2 {
        match msg {
            ClientMessage::GetParametersRes(res) => {
                return encode_v2(
                    TAG_GET_PARAMETERS_RES,
                    &res.parameters,
                    |h| h.status(&res.status),
                    |_| {},
                );
            }
            ClientMessage::FitRes(res) => {
                return encode_v2(TAG_FIT_RES, &res.parameters, |h| h.status(&res.status), |h| {
                    h.u64(res.num_examples);
                    h.config(&res.metrics);
                });
            }
            _ => {}
        }
    }
    encode_client_message(msg)
}

struct V2Parts<'a> {
    tag: u8,
    header: &'a [u8],
    body: &'a [u8],
    /// Absolute byte offset of the body within the message payload.
    body_off: usize,
}

fn split_v2(payload: &[u8]) -> Result<V2Parts<'_>> {
    if payload.len() < 8 {
        return Err(Error::Codec(format!(
            "v2 message too short: {} bytes",
            payload.len()
        )));
    }
    let magic = u16::from_le_bytes([payload[0], payload[1]]);
    if magic != MAGIC {
        return Err(Error::Codec(format!("bad magic {magic:#06x}")));
    }
    if payload[2] != VERSION_V2 {
        return Err(Error::Codec(format!(
            "unsupported protocol version {}",
            payload[2]
        )));
    }
    let tag = payload[3];
    let header_len = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    let header_end = 8usize
        .checked_add(header_len)
        .filter(|&end| end <= payload.len())
        .ok_or_else(|| Error::Codec(format!("v2 header ({header_len} bytes) overruns message")))?;
    let body_off = (header_end + 3) & !3;
    if body_off > payload.len() {
        return Err(Error::Codec("v2 body padding overruns message".into()));
    }
    if payload[header_end..body_off].iter().any(|&b| b != 0) {
        return Err(Error::Codec("nonzero v2 header padding".into()));
    }
    Ok(V2Parts { tag, header: &payload[8..header_end], body: &payload[body_off..], body_off })
}

struct ManifestEntry {
    dtype: u8,
    shape: Vec<usize>,
    byte_off: usize,
    byte_len: usize,
    count: usize,
}

/// Parse and validate the tensor manifest against the body bounds:
/// every region must be in bounds, 4-aligned, an exact multiple of the
/// element size, and consistent with its declared shape — and the
/// regions must cover the body exactly (no trailing garbage).
fn manifest(r: &mut Reader, body_len: usize) -> Result<Vec<ManifestEntry>> {
    let count = r.u16()? as usize;
    let mut entries = Vec::with_capacity(count);
    let mut max_end = 0usize;
    for _ in 0..count {
        let dtype = r.u8()?;
        let rank = r.u8()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        let byte_off = r.u32()? as usize;
        let byte_len = r.u32()? as usize;
        let elem = match dtype {
            0 | 1 => 4,
            2 => 2,
            other => return Err(Error::Codec(format!("unknown tensor dtype {other}"))),
        };
        if byte_len % elem != 0 {
            return Err(Error::Codec(format!(
                "tensor byte length {byte_len} not a multiple of element size {elem}"
            )));
        }
        let n = byte_len / elem;
        let expect: usize = shape.iter().product();
        if expect != n {
            return Err(Error::Codec(format!(
                "tensor shape {shape:?} wants {expect} elements, manifest says {n}"
            )));
        }
        if byte_off % 4 != 0 {
            return Err(Error::Codec(format!("misaligned tensor offset {byte_off}")));
        }
        let end = byte_off
            .checked_add(byte_len)
            .filter(|&end| end <= body_len)
            .ok_or_else(|| {
                Error::Codec(format!(
                    "tensor region {byte_off}+{byte_len} out of body bounds ({body_len} bytes)"
                ))
            })?;
        max_end = max_end.max(end);
        entries.push(ManifestEntry { dtype, shape, byte_off, byte_len, count: n });
    }
    if max_end != body_len {
        return Err(Error::Codec(format!(
            "v2 body has {body_len} bytes but the manifest covers {max_end}"
        )));
    }
    Ok(entries)
}

/// Materialize validated manifest entries into `Parameters`, borrowing
/// f32 regions straight out of the shared frame buffer (copy fallback
/// on misalignment or a big-endian host; i32/f16 always copy).
fn v2_parameters(frame: &FrameBuf, body_off: usize, entries: Vec<ManifestEntry>) -> Parameters {
    let bytes = frame.as_slice();
    let tensors = entries
        .into_iter()
        .map(|e| {
            let abs = body_off + e.byte_off;
            let raw = &bytes[abs..abs + e.byte_len];
            let data = match e.dtype {
                0 => match SharedF32::new(frame.shared(), abs, e.count) {
                    Some(v) => TensorData::F32Shared(v),
                    None => TensorData::F32(
                        raw.chunks_exact(4)
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    ),
                },
                1 => TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
                _ => TensorData::F16(
                    raw.chunks_exact(2)
                        .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ),
            };
            Tensor { shape: e.shape, data }
        })
        .collect();
    Parameters { tensors }
}

fn decode_server_message_v2(frame: &FrameBuf) -> Result<ServerMessage> {
    let parts = split_v2(frame.as_slice())?;
    let mut r = Reader::new(parts.header);
    let msg = match parts.tag {
        TAG_FIT_INS => {
            let entries = manifest(&mut r, parts.body.len())?;
            let config = r.config()?;
            ServerMessage::FitIns(FitIns {
                parameters: v2_parameters(frame, parts.body_off, entries),
                config,
            })
        }
        TAG_EVALUATE_INS => {
            let entries = manifest(&mut r, parts.body.len())?;
            let config = r.config()?;
            ServerMessage::EvaluateIns(EvaluateIns {
                parameters: v2_parameters(frame, parts.body_off, entries),
                config,
            })
        }
        other => {
            return Err(Error::Codec(format!(
                "unexpected v2 server message tag {other:#04x}"
            )))
        }
    };
    r.r.expect_end("v2 header")?;
    Ok(msg)
}

fn decode_client_message_v2(frame: &FrameBuf) -> Result<ClientMessage> {
    let parts = split_v2(frame.as_slice())?;
    let mut r = Reader::new(parts.header);
    let msg = match parts.tag {
        TAG_GET_PARAMETERS_RES => {
            let status = r.status()?;
            let entries = manifest(&mut r, parts.body.len())?;
            ClientMessage::GetParametersRes(GetParametersRes {
                status,
                parameters: v2_parameters(frame, parts.body_off, entries),
            })
        }
        TAG_FIT_RES => {
            let status = r.status()?;
            let entries = manifest(&mut r, parts.body.len())?;
            let num_examples = r.u64()?;
            let metrics = r.config()?;
            ClientMessage::FitRes(FitRes {
                status,
                parameters: v2_parameters(frame, parts.body_off, entries),
                num_examples,
                metrics,
            })
        }
        other => {
            return Err(Error::Codec(format!(
                "unexpected v2 client message tag {other:#04x}"
            )))
        }
    };
    r.r.expect_end("v2 header")?;
    Ok(msg)
}

/// Decode a server→client message from a received frame, dispatching on
/// the wire version byte: v1 frames take the owned decode path, v2
/// frames decode zero-copy against the shared buffer.
pub fn decode_server_frame(frame: &FrameBuf) -> Result<ServerMessage> {
    match wire_version(frame.as_slice())? {
        VERSION => decode_server_message(frame.as_slice()),
        VERSION_V2 => decode_server_message_v2(frame),
        other => Err(Error::Codec(format!("unsupported protocol version {other}"))),
    }
}

/// Client→server counterpart of [`decode_server_frame`].
pub fn decode_client_frame(frame: &FrameBuf) -> Result<ClientMessage> {
    match wire_version(frame.as_slice())? {
        VERSION => decode_client_message(frame.as_slice()),
        VERSION_V2 => decode_client_message_v2(frame),
        other => Err(Error::Codec(format!("unsupported protocol version {other}"))),
    }
}

/// Alignment-checked zero-copy `&[u8]` → `&[f32]` cast. `None` on a
/// misaligned region, a ragged length, or a big-endian host.
fn f32_cast(region: &[u8]) -> Option<&[f32]> {
    if cfg!(target_endian = "big") || region.len() % 4 != 0 {
        return None;
    }
    if region.is_empty() {
        return Some(&[]);
    }
    if region.as_ptr().align_offset(std::mem::align_of::<f32>()) != 0 {
        return None;
    }
    // SAFETY: length, alignment and endianness checked above; f32
    // accepts every bit pattern; the borrow keeps the bytes alive.
    Some(unsafe { std::slice::from_raw_parts(region.as_ptr().cast::<f32>(), region.len() / 4) })
}

/// A borrowed f32 tensor: shape plus a `&[f32]` aliasing the encoded
/// payload it was parsed from — no allocation, no copy.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorView<'a> {
    /// Tensor dimensions (row-major, like [`Tensor::shape`]).
    pub shape: Vec<usize>,
    /// Elements borrowed straight from the encoded payload.
    pub data: &'a [f32],
}

/// Borrow every f32 tensor of a v2 tensor-bearing message straight out
/// of `payload` — the allocation-free fast path used by the benches and
/// the zero-copy proof tests. Errors on non-f32 entries or when the
/// cast is impossible (misaligned buffer, big-endian host); real decode
/// paths use [`decode_client_frame`], which falls back to copying.
pub fn v2_f32_views(payload: &[u8]) -> Result<Vec<TensorView<'_>>> {
    let parts = split_v2(payload)?;
    let mut r = Reader::new(parts.header);
    if parts.tag == TAG_FIT_RES || parts.tag == TAG_GET_PARAMETERS_RES {
        let _ = r.status()?;
    }
    let entries = manifest(&mut r, parts.body.len())?;
    entries
        .into_iter()
        .map(|e| {
            if e.dtype != 0 {
                return Err(Error::Codec(format!(
                    "v2 view requires f32 tensors, got dtype {}",
                    e.dtype
                )));
            }
            let raw = &parts.body[e.byte_off..e.byte_off + e.byte_len];
            let data = f32_cast(raw).ok_or_else(|| {
                Error::Codec("frame buffer not 4-byte aligned for a zero-copy view".into())
            })?;
            Ok(TensorView { shape: e.shape, data })
        })
        .collect()
}

/// A round's broadcast message (the global-parameter `FitIns`) encoded
/// **once per wire version** and shared across every dispatch as an
/// `Arc` — the server-side half of the zero-copy story: N clients, one
/// encode instead of N.
#[derive(Debug)]
pub struct BroadcastFrame {
    msg: ServerMessage,
    v1: OnceLock<Arc<Vec<u8>>>,
    v2: OnceLock<Arc<Vec<u8>>>,
}

impl BroadcastFrame {
    /// Wrap a message for shared dispatch (nothing is encoded yet).
    pub fn new(msg: ServerMessage) -> Self {
        BroadcastFrame { msg, v1: OnceLock::new(), v2: OnceLock::new() }
    }

    /// The wrapped message.
    pub fn message(&self) -> &ServerMessage {
        &self.msg
    }

    /// Encoded bytes for a negotiated wire version, encoded lazily on
    /// first use and `Arc`-shared afterwards.
    pub fn bytes(&self, wire: u8) -> Arc<Vec<u8>> {
        let cell = if wire >= VERSION_V2 { &self.v2 } else { &self.v1 };
        Arc::clone(cell.get_or_init(|| Arc::new(encode_server_message_v(&self.msg, wire))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn params(n: usize) -> Parameters {
        Parameters::from_flat((0..n).map(|i| i as f32 * 0.5).collect())
    }

    #[test]
    fn fit_ins_roundtrip() {
        let msg = ServerMessage::FitIns(FitIns {
            parameters: params(1000),
            config: config! { "epochs" => 5i64, "lr" => 0.05f64, "model" => "cifar_cnn" },
        });
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn evaluate_ins_roundtrip() {
        let msg = ServerMessage::EvaluateIns(EvaluateIns {
            parameters: params(7),
            config: config! { "batches" => 2i64 },
        });
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn reconnect_roundtrip() {
        let msg = ServerMessage::Reconnect { seconds: 30 };
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn fit_res_roundtrip() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(64),
            num_examples: 320,
            metrics: config! {
                "compute_time_s" => 12.5f64,
                "energy_j" => 88.0f64,
                "steps" => 80i64,
                "truncated" => false,
            },
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    #[test]
    fn evaluate_res_roundtrip() {
        let msg = ClientMessage::EvaluateRes(EvaluateRes {
            status: Status { code: StatusCode::EvaluateError, message: "oom".into() },
            loss: 2.3,
            num_examples: 100,
            metrics: config! { "accuracy" => 0.67f64 },
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    #[test]
    fn register_roundtrip() {
        let msg = ClientMessage::Register(ClientInfo {
            client_id: "tx2-07".into(),
            device: "jetson_tx2_gpu".into(),
            os: "Linux tegra".into(),
            num_examples: 320,
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    #[test]
    fn f16_tensor_roundtrip() {
        let p = Parameters::from_flat(vec![0.5, -1.25, 3.0])
            .quantize_f16()
            .unwrap();
        let msg = ServerMessage::FitIns(FitIns { parameters: p, config: ConfigMap::new() });
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn int_tensor_roundtrip() {
        let msg = ClientMessage::GetParametersRes(GetParametersRes {
            status: Status::ok(),
            parameters: Parameters {
                tensors: vec![Tensor::i32(vec![2, 2], vec![1, -2, 3, -4]).unwrap()],
            },
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    /// Golden wire vectors: these exact bytes are the protocol — a
    /// foreign-language client implements against them, so they must
    /// never drift (they pinned the hand-rolled encoder before the
    /// `util::bytes` unification and pin the unified one now).
    #[test]
    fn wire_bytes_are_pinned() {
        let buf = encode_server_message(&ServerMessage::Reconnect {
            seconds: 0x0102_0304_0506_0708,
        });
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, // magic 0xF10E LE
                0x01, // version
                0x04, // TAG_RECONNECT
                0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // seconds LE
            ]
        );

        let buf = encode_client_message(&ClientMessage::Disconnect {
            reason: "ok".into(),
        });
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, 0x01, 0x85, // header, TAG_DISCONNECT
                0x02, 0x00, 0x00, 0x00, // string length u32 LE
                b'o', b'k',
            ]
        );

        // one tensor-bearing message: f32 raw-bit LE payload
        let msg = ServerMessage::FitIns(FitIns {
            parameters: Parameters::from_flat(vec![1.0]),
            config: ConfigMap::new(),
        });
        let buf = encode_server_message(&msg);
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, 0x01, 0x02, // header, TAG_FIT_INS
                0x01, 0x00, // tensor count u16
                0x00, // dtype f32
                0x01, // rank 1
                0x01, 0x00, 0x00, 0x00, // dim 1
                0x01, 0x00, 0x00, 0x00, // element count
                0x00, 0x00, 0x80, 0x3F, // 1.0f32 bits LE
                0x00, 0x00, 0x00, 0x00, // empty config map
            ]
        );
    }

    #[test]
    fn bad_magic_rejected() {
        let msg = ServerMessage::Reconnect { seconds: 1 };
        let mut buf = encode_server_message(&msg);
        buf[0] ^= 0xFF;
        assert!(matches!(decode_server_message(&buf), Err(Error::Codec(_))));
    }

    #[test]
    fn bad_version_rejected() {
        let msg = ServerMessage::Reconnect { seconds: 1 };
        let mut buf = encode_server_message(&msg);
        buf[2] = 99;
        assert!(decode_server_message(&buf).is_err());
    }

    #[test]
    fn truncation_rejected_everywhere() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(32),
            num_examples: 1,
            metrics: config! { "a" => 1i64 },
        });
        let buf = encode_client_message(&msg);
        for cut in 1..buf.len() {
            assert!(
                decode_client_message(&buf[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let msg = ServerMessage::Reconnect { seconds: 1 };
        let mut buf = encode_server_message(&msg);
        buf.push(0);
        assert!(decode_server_message(&buf).is_err());
    }

    #[test]
    fn client_server_tags_disjoint() {
        // A client message must never decode as a server message.
        let msg = ClientMessage::Disconnect { reason: "done".into() };
        let buf = encode_client_message(&msg);
        assert!(decode_server_message(&buf).is_err());
    }

    // -- wire v2 ------------------------------------------------------------

    fn frame(bytes: Vec<u8>) -> FrameBuf {
        FrameBuf::new(bytes)
    }

    #[test]
    fn hello_handshake_roundtrip_and_pinned() {
        let hello = ClientMessage::Hello { max_version: 2 };
        let buf = encode_client_message(&hello);
        // always a v1 frame so any peer can read it
        assert_eq!(buf, vec![0x0E, 0xF1, 0x01, 0x86, 0x02]);
        assert_eq!(decode_client_message(&buf).unwrap(), hello);

        let ack = ServerMessage::HelloAck { version: 2 };
        let buf = encode_server_message(&ack);
        assert_eq!(buf, vec![0x0E, 0xF1, 0x01, 0x05, 0x02]);
        assert_eq!(decode_server_message(&buf).unwrap(), ack);
    }

    #[test]
    fn negotiation_rule() {
        assert_eq!(negotiate_version(0), 1); // nonsense greeting → v1
        assert_eq!(negotiate_version(1), 1);
        assert_eq!(negotiate_version(2), 2);
        assert_eq!(negotiate_version(9), 2); // future client capped at ours
    }

    #[test]
    fn v2_roundtrips_all_tensor_bearing_messages() {
        let p = params(257); // odd count exercises inter-field alignment
        let fit_ins = ServerMessage::FitIns(FitIns {
            parameters: p.clone(),
            config: config! { "epochs" => 2i64, "lr" => 0.05f64 },
        });
        let eval_ins = ServerMessage::EvaluateIns(EvaluateIns {
            parameters: p.clone(),
            config: config! { "batches" => 3i64 },
        });
        for msg in [fit_ins, eval_ins] {
            let buf = encode_server_message_v(&msg, VERSION_V2);
            assert_eq!(buf[2], VERSION_V2);
            assert_eq!(decode_server_frame(&frame(buf)).unwrap(), msg);
        }

        let fit_res = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: p.clone(),
            num_examples: 320,
            metrics: config! { "steps" => 80i64, "truncated" => true },
        });
        let get_res = ClientMessage::GetParametersRes(GetParametersRes {
            status: Status { code: StatusCode::FitError, message: "x".into() },
            parameters: p,
        });
        for msg in [fit_res, get_res] {
            let buf = encode_client_message_v(&msg, VERSION_V2);
            assert_eq!(buf[2], VERSION_V2);
            assert_eq!(decode_client_frame(&frame(buf)).unwrap(), msg);
        }
    }

    #[test]
    fn v2_roundtrips_mixed_dtypes_and_padding() {
        // f16 tensor with odd byte length forces an alignment gap in the
        // body; two scalars force nonzero header padding.
        let parameters = Parameters {
            tensors: vec![
                Parameters::from_flat(vec![0.5, -1.0, 2.0])
                    .quantize_f16()
                    .unwrap()
                    .tensors
                    .remove(0),
                Tensor::i32(vec![2], vec![7, -8]).unwrap(),
                Tensor::scalar_f32(1.5),
                Tensor::scalar_f32(-2.5),
            ],
        };
        let msg = ClientMessage::GetParametersRes(GetParametersRes {
            status: Status::ok(),
            parameters,
        });
        let buf = encode_client_message_v(&msg, VERSION_V2);
        assert_eq!(decode_client_frame(&frame(buf)).unwrap(), msg);
    }

    #[test]
    fn v2_empty_parameters_roundtrip() {
        let msg = ClientMessage::GetParametersRes(GetParametersRes {
            status: Status::ok(),
            parameters: Parameters::default(),
        });
        let buf = encode_client_message_v(&msg, VERSION_V2);
        assert_eq!(decode_client_frame(&frame(buf)).unwrap(), msg);
    }

    #[test]
    fn non_tensor_messages_stay_v1_on_v2_connections() {
        let reconnect = ServerMessage::Reconnect { seconds: 3 };
        assert_eq!(
            encode_server_message_v(&reconnect, VERSION_V2),
            encode_server_message(&reconnect)
        );
        let register = ClientMessage::Register(ClientInfo {
            client_id: "c".into(),
            device: "d".into(),
            os: "o".into(),
            num_examples: 1,
        });
        assert_eq!(
            encode_client_message_v(&register, VERSION_V2),
            encode_client_message(&register)
        );
        let eval_res = ClientMessage::EvaluateRes(EvaluateRes {
            status: Status::ok(),
            loss: 0.5,
            num_examples: 10,
            metrics: ConfigMap::new(),
        });
        assert_eq!(
            encode_client_message_v(&eval_res, VERSION_V2),
            encode_client_message(&eval_res)
        );
    }

    #[test]
    fn v1_wire_version_encodes_v1() {
        let msg = ServerMessage::FitIns(FitIns {
            parameters: params(4),
            config: ConfigMap::new(),
        });
        let buf = encode_server_message_v(&msg, VERSION);
        assert_eq!(buf, encode_server_message(&msg));
        // and v1 frames still decode through the frame dispatcher
        assert_eq!(decode_server_frame(&frame(buf)).unwrap(), msg);
    }

    /// The v2 golden vector: like `wire_bytes_are_pinned`, these exact
    /// bytes are the protocol (see `transport/PROTOCOL.md`).
    #[test]
    fn v2_wire_bytes_are_pinned() {
        let msg = ServerMessage::FitIns(FitIns {
            parameters: Parameters::from_flat(vec![1.0]),
            config: ConfigMap::new(),
        });
        let buf = encode_server_message_v(&msg, VERSION_V2);
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, // magic 0xF10E LE
                0x02, // version 2
                0x02, // TAG_FIT_INS
                0x14, 0x00, 0x00, 0x00, // header_len = 20
                // header: manifest
                0x01, 0x00, // tensor count u16
                0x00, // dtype f32
                0x01, // rank 1
                0x01, 0x00, 0x00, 0x00, // dim 1
                0x00, 0x00, 0x00, 0x00, // byte_off 0
                0x04, 0x00, 0x00, 0x00, // byte_len 4
                // header: empty config map
                0x00, 0x00, 0x00, 0x00,
                // (header_len % 4 == 0 → no padding)
                // body: raw f32 LE
                0x00, 0x00, 0x80, 0x3F, // 1.0f32
            ]
        );
    }

    #[test]
    fn v2_decode_borrows_frame_buffer() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(64),
            num_examples: 10,
            metrics: ConfigMap::new(),
        });
        let f = frame(encode_client_message_v(&msg, VERSION_V2));
        let base = f.as_slice().as_ptr() as usize;
        let decoded = match decode_client_frame(&f).unwrap() {
            ClientMessage::FitRes(res) => res,
            other => panic!("wrong message: {other:?}"),
        };
        let view = decoded.parameters.to_flat().unwrap();
        let addr = view.as_ptr() as usize;
        // On an aligned buffer (Vec allocations are ≥ 8-aligned in
        // practice) the decoded slice aliases the frame bytes. If the
        // allocator ever hands back a misaligned buffer the decoder
        // copies instead — then this test is vacuous, not wrong.
        if base % 4 == 0 {
            assert!(
                addr >= base && addr + view.len() * 4 <= base + f.len(),
                "decoded f32 slice must alias the frame buffer"
            );
        }
    }

    #[test]
    fn v2_views_borrow_payload() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(32),
            num_examples: 1,
            metrics: ConfigMap::new(),
        });
        let buf = encode_client_message_v(&msg, VERSION_V2);
        if buf.as_ptr() as usize % 4 != 0 {
            return; // misaligned allocation: cast path unavailable
        }
        let views = v2_f32_views(&buf).unwrap();
        assert_eq!(views.len(), 1);
        assert_eq!(views[0].shape, vec![32]);
        let expect: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        assert_eq!(views[0].data, expect.as_slice());
        let base = buf.as_ptr() as usize;
        let addr = views[0].data.as_ptr() as usize;
        assert!(addr >= base && addr + 32 * 4 <= base + buf.len());
    }

    #[test]
    fn v2_malformed_frames_rejected() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(8),
            num_examples: 1,
            metrics: ConfigMap::new(),
        });
        let good = encode_client_message_v(&msg, VERSION_V2);
        assert!(decode_client_frame(&frame(good.clone())).is_ok());

        // bad version byte
        let mut b = good.clone();
        b[2] = 3;
        assert!(decode_client_frame(&frame(b)).is_err());

        // header_len overruns the message
        let mut b = good.clone();
        b[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_client_frame(&frame(b)).is_err());

        // truncation anywhere must fail
        for cut in 1..good.len() {
            assert!(
                decode_client_frame(&frame(good[..cut].to_vec())).is_err(),
                "cut at {cut} must fail"
            );
        }

        // trailing body bytes the manifest does not cover
        let mut b = good.clone();
        b.extend_from_slice(&[0, 0, 0, 0]);
        assert!(decode_client_frame(&frame(b)).is_err());

        // manifest region pushed out of body bounds: the status is
        // "" → status(1B code + 4B len) = 5 bytes, then count u16,
        // then entry dtype(1) rank(1) dims(4) byte_off at +13..+17.
        let mut b = good.clone();
        let off_pos = 8 + 5 + 2 + 1 + 1 + 4;
        b[off_pos..off_pos + 4].copy_from_slice(&1024u32.to_le_bytes());
        assert!(decode_client_frame(&frame(b)).is_err());

        // misaligned tensor offset
        let mut b = good.clone();
        b[off_pos..off_pos + 4].copy_from_slice(&2u32.to_le_bytes());
        assert!(decode_client_frame(&frame(b)).is_err());

        // unknown dtype
        let mut b = good;
        b[8 + 5 + 2] = 9;
        assert!(decode_client_frame(&frame(b)).is_err());

        // nonzero header padding: craft a frame with 2 scalar tensors
        // (header_len = 5 + 2 + 2*10 + 8 + 4 = 39 → 1 pad byte)
        let two = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: Parameters {
                tensors: vec![Tensor::scalar_f32(1.0), Tensor::scalar_f32(2.0)],
            },
            num_examples: 1,
            metrics: ConfigMap::new(),
        });
        let buf = encode_client_message_v(&two, VERSION_V2);
        let header_len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
        let pad = (4 - header_len % 4) % 4;
        assert!(pad > 0, "test frame must actually have header padding");
        assert_eq!(decode_client_frame(&frame(buf.clone())).unwrap(), two);
        let mut b = buf;
        b[8 + header_len] = 0xFF;
        assert!(decode_client_frame(&frame(b)).is_err());
    }

    #[test]
    fn v2_server_client_tags_disjoint() {
        let msg = ServerMessage::FitIns(FitIns {
            parameters: params(4),
            config: ConfigMap::new(),
        });
        let buf = encode_server_message_v(&msg, VERSION_V2);
        assert!(decode_client_frame(&frame(buf)).is_err());
    }

    #[test]
    fn broadcast_frame_encodes_once_per_version() {
        let msg = ServerMessage::FitIns(FitIns {
            parameters: params(128),
            config: config! { "epochs" => 1i64 },
        });
        let bc = BroadcastFrame::new(msg.clone());
        let a = bc.bytes(VERSION_V2);
        let b = bc.bytes(VERSION_V2);
        assert!(Arc::ptr_eq(&a, &b), "same Arc, one encode");
        assert_eq!(*a, encode_server_message_v(&msg, VERSION_V2));
        let v1 = bc.bytes(VERSION);
        assert_eq!(*v1, encode_server_message(&msg));
        assert_eq!(decode_server_frame(&frame((*a).clone())).unwrap(), msg);
        assert_eq!(bc.message(), &msg);
    }
}
