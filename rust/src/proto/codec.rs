//! Hand-rolled binary wire format for the Flower Protocol.
//!
//! The paper's framework achieves language-agnosticism "by offering
//! protocol-level integration" (§3): any client that can speak the byte
//! format participates, regardless of language or ML framework. This codec
//! is that byte format, pinned precisely enough that a Java/Swift/C++
//! implementation could be written from this file alone:
//!
//! ```text
//! message   := magic:u16(0xF10E) version:u8(1) tag:u8 body
//! ints      := little-endian
//! bytes     := len:u32 data[len]
//! string    := bytes (UTF-8)
//! tensor    := dtype:u8 (0=f32, 1=i32) rank:u8 dims:u32[rank] raw-LE data
//! params    := count:u16 tensor[count]
//! scalar    := tag:u8 (0=bool,1=i64,2=f64,3=str,4=bytes) value
//! configmap := count:u32 (string scalar)[count]
//! status    := code:u8 string
//! ```
//!
//! Framing (length prefix) is the transport's job — see `transport::frame`.
//!
//! The little-endian primitives live in [`crate::util::bytes`] (shared
//! with the checkpoint container and transport framing); this module
//! owns only the protocol's composite encodings. The wire bytes are
//! pinned by golden vectors and a differential property test against
//! the pre-refactor hand-rolled encoder (`rust/tests/proptests.rs`).

use crate::error::{Error, Result};
use crate::util::bytes::{LeReader, LeWriter};

use super::message::*;
use super::scalar::{ConfigMap, Scalar};
use super::tensor::{Parameters, Tensor, TensorData};

pub const MAGIC: u16 = 0xF10E;
pub const VERSION: u8 = 1;

// Server message tags.
const TAG_GET_PARAMETERS_INS: u8 = 0x01;
const TAG_FIT_INS: u8 = 0x02;
const TAG_EVALUATE_INS: u8 = 0x03;
const TAG_RECONNECT: u8 = 0x04;
// Client message tags.
const TAG_REGISTER: u8 = 0x81;
const TAG_GET_PARAMETERS_RES: u8 = 0x82;
const TAG_FIT_RES: u8 = 0x83;
const TAG_EVALUATE_RES: u8 = 0x84;
const TAG_DISCONNECT: u8 = 0x85;

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Wire-format writer: the shared [`LeWriter`] primitives plus the
/// protocol's composite encodings (length-prefixed bytes, tensors,
/// scalars, config maps).
struct Writer {
    w: LeWriter,
}

impl Writer {
    fn with_header(tag: u8, capacity: usize) -> Self {
        let mut w = Writer { w: LeWriter::with_capacity(capacity + 4) };
        w.w.u16(MAGIC);
        w.w.u8(VERSION);
        w.w.u8(tag);
        w
    }

    fn finish(self) -> Vec<u8> {
        self.w.into_bytes()
    }

    fn u8(&mut self, v: u8) {
        self.w.u8(v);
    }
    fn u16(&mut self, v: u16) {
        self.w.u16(v);
    }
    fn u32(&mut self, v: u32) {
        self.w.u32(v);
    }
    fn u64(&mut self, v: u64) {
        self.w.u64(v);
    }
    fn i64(&mut self, v: i64) {
        self.w.i64(v);
    }
    fn f64(&mut self, v: f64) {
        self.w.f64(v);
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.w.raw(v);
    }

    fn string(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    fn tensor(&mut self, t: &Tensor) {
        match &t.data {
            TensorData::F32(v) => {
                self.u8(0);
                self.u8(t.shape.len() as u8);
                for &d in &t.shape {
                    self.u32(d as u32);
                }
                self.u32(v.len() as u32);
                // bulk copy: f32 LE
                self.w.reserve(v.len() * 4);
                for &x in v {
                    self.w.f32(x);
                }
            }
            TensorData::I32(v) => {
                self.u8(1);
                self.u8(t.shape.len() as u8);
                for &d in &t.shape {
                    self.u32(d as u32);
                }
                self.u32(v.len() as u32);
                self.w.reserve(v.len() * 4);
                for &x in v {
                    self.w.raw(&x.to_le_bytes());
                }
            }
            TensorData::F16(v) => {
                self.u8(2);
                self.u8(t.shape.len() as u8);
                for &d in &t.shape {
                    self.u32(d as u32);
                }
                self.u32(v.len() as u32);
                self.w.reserve(v.len() * 2);
                for &x in v {
                    self.w.u16(x);
                }
            }
        }
    }

    fn parameters(&mut self, p: &Parameters) {
        self.u16(p.tensors.len() as u16);
        for t in &p.tensors {
            self.tensor(t);
        }
    }

    fn scalar(&mut self, s: &Scalar) {
        match s {
            Scalar::Bool(v) => {
                self.u8(0);
                self.u8(u8::from(*v));
            }
            Scalar::I64(v) => {
                self.u8(1);
                self.i64(*v);
            }
            Scalar::F64(v) => {
                self.u8(2);
                self.f64(*v);
            }
            Scalar::Str(v) => {
                self.u8(3);
                self.string(v);
            }
            Scalar::Bytes(v) => {
                self.u8(4);
                self.bytes(v);
            }
        }
    }

    fn config(&mut self, m: &ConfigMap) {
        self.u32(m.len() as u32);
        for (k, v) in m {
            self.string(k);
            self.scalar(v);
        }
    }

    fn status(&mut self, s: &Status) {
        let code = match s.code {
            StatusCode::Ok => 0u8,
            StatusCode::FitNotImplemented => 1,
            StatusCode::FitError => 2,
            StatusCode::EvaluateError => 3,
        };
        self.u8(code);
        self.string(&s.message);
    }
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// Wire-format reader: a [`LeReader`] with `Error::Codec` as its error
/// category, plus the protocol's composite decoders.
struct Reader<'a> {
    r: LeReader<'a>,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { r: LeReader::new(buf, Error::Codec) }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.r.take(n)
    }

    fn u8(&mut self) -> Result<u8> {
        self.r.u8()
    }
    fn u16(&mut self) -> Result<u16> {
        self.r.u16()
    }
    fn u32(&mut self) -> Result<u32> {
        self.r.u32()
    }
    fn u64(&mut self) -> Result<u64> {
        self.r.u64()
    }
    fn i64(&mut self) -> Result<i64> {
        self.r.i64()
    }
    fn f64(&mut self) -> Result<f64> {
        self.r.f64()
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b).map_err(|e| Error::Codec(format!("bad utf8 string: {e}")))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let dtype = self.u8()?;
        let rank = self.u8()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u32()? as usize);
        }
        let n = self.u32()? as usize;
        let expect: usize = shape.iter().product();
        if expect != n {
            return Err(Error::Codec(format!(
                "tensor shape {shape:?} wants {expect} elements, wire says {n}"
            )));
        }
        let data = match dtype {
            0 => {
                let raw = self.take(n * 4)?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                TensorData::F32(v)
            }
            1 => {
                let raw = self.take(n * 4)?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(4) {
                    v.push(i32::from_le_bytes(c.try_into().unwrap()));
                }
                TensorData::I32(v)
            }
            2 => {
                let raw = self.take(n * 2)?;
                let mut v = Vec::with_capacity(n);
                for c in raw.chunks_exact(2) {
                    v.push(u16::from_le_bytes(c.try_into().unwrap()));
                }
                TensorData::F16(v)
            }
            other => return Err(Error::Codec(format!("unknown tensor dtype {other}"))),
        };
        Ok(Tensor { shape, data })
    }

    fn parameters(&mut self) -> Result<Parameters> {
        let count = self.u16()? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            tensors.push(self.tensor()?);
        }
        Ok(Parameters { tensors })
    }

    fn scalar(&mut self) -> Result<Scalar> {
        match self.u8()? {
            0 => Ok(Scalar::Bool(self.u8()? != 0)),
            1 => Ok(Scalar::I64(self.i64()?)),
            2 => Ok(Scalar::F64(self.f64()?)),
            3 => Ok(Scalar::Str(self.string()?)),
            4 => Ok(Scalar::Bytes(self.bytes()?)),
            other => Err(Error::Codec(format!("unknown scalar tag {other}"))),
        }
    }

    fn config(&mut self) -> Result<ConfigMap> {
        let count = self.u32()? as usize;
        let mut m = ConfigMap::new();
        for _ in 0..count {
            let k = self.string()?;
            let v = self.scalar()?;
            m.insert(k, v);
        }
        Ok(m)
    }

    fn status(&mut self) -> Result<Status> {
        let code = match self.u8()? {
            0 => StatusCode::Ok,
            1 => StatusCode::FitNotImplemented,
            2 => StatusCode::FitError,
            3 => StatusCode::EvaluateError,
            other => return Err(Error::Codec(format!("unknown status code {other}"))),
        };
        Ok(Status { code, message: self.string()? })
    }

    fn finish(&self) -> Result<()> {
        self.r.expect_end("message")
    }
}

fn read_header(r: &mut Reader) -> Result<u8> {
    let magic = r.u16()?;
    if magic != MAGIC {
        return Err(Error::Codec(format!("bad magic {magic:#06x}")));
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(Error::Codec(format!("unsupported protocol version {version}")));
    }
    r.u8()
}

// ---------------------------------------------------------------------------
// Public encode/decode
// ---------------------------------------------------------------------------

/// Encode a server→client message to bytes.
pub fn encode_server_message(msg: &ServerMessage) -> Vec<u8> {
    match msg {
        ServerMessage::GetParametersIns(ins) => {
            let mut w = Writer::with_header(TAG_GET_PARAMETERS_INS, 64);
            w.config(&ins.config);
            w.finish()
        }
        ServerMessage::FitIns(ins) => {
            let mut w = Writer::with_header(TAG_FIT_INS, ins.parameters.byte_len() + 256);
            w.parameters(&ins.parameters);
            w.config(&ins.config);
            w.finish()
        }
        ServerMessage::EvaluateIns(ins) => {
            let mut w = Writer::with_header(TAG_EVALUATE_INS, ins.parameters.byte_len() + 256);
            w.parameters(&ins.parameters);
            w.config(&ins.config);
            w.finish()
        }
        ServerMessage::Reconnect { seconds } => {
            let mut w = Writer::with_header(TAG_RECONNECT, 8);
            w.u64(*seconds);
            w.finish()
        }
    }
}

/// Decode a server→client message.
pub fn decode_server_message(buf: &[u8]) -> Result<ServerMessage> {
    let mut r = Reader::new(buf);
    let tag = read_header(&mut r)?;
    let msg = match tag {
        TAG_GET_PARAMETERS_INS => {
            ServerMessage::GetParametersIns(GetParametersIns { config: r.config()? })
        }
        TAG_FIT_INS => ServerMessage::FitIns(FitIns {
            parameters: r.parameters()?,
            config: r.config()?,
        }),
        TAG_EVALUATE_INS => ServerMessage::EvaluateIns(EvaluateIns {
            parameters: r.parameters()?,
            config: r.config()?,
        }),
        TAG_RECONNECT => ServerMessage::Reconnect { seconds: r.u64()? },
        other => return Err(Error::Codec(format!("unknown server message tag {other:#04x}"))),
    };
    r.finish()?;
    Ok(msg)
}

/// Encode a client→server message to bytes.
pub fn encode_client_message(msg: &ClientMessage) -> Vec<u8> {
    match msg {
        ClientMessage::Register(info) => {
            let mut w = Writer::with_header(TAG_REGISTER, 128);
            w.string(&info.client_id);
            w.string(&info.device);
            w.string(&info.os);
            w.u64(info.num_examples);
            w.finish()
        }
        ClientMessage::GetParametersRes(res) => {
            let mut w = Writer::with_header(TAG_GET_PARAMETERS_RES, res.parameters.byte_len() + 64);
            w.status(&res.status);
            w.parameters(&res.parameters);
            w.finish()
        }
        ClientMessage::FitRes(res) => {
            let mut w = Writer::with_header(TAG_FIT_RES, res.parameters.byte_len() + 256);
            w.status(&res.status);
            w.parameters(&res.parameters);
            w.u64(res.num_examples);
            w.config(&res.metrics);
            w.finish()
        }
        ClientMessage::EvaluateRes(res) => {
            let mut w = Writer::with_header(TAG_EVALUATE_RES, 256);
            w.status(&res.status);
            w.f64(res.loss);
            w.u64(res.num_examples);
            w.config(&res.metrics);
            w.finish()
        }
        ClientMessage::Disconnect { reason } => {
            let mut w = Writer::with_header(TAG_DISCONNECT, reason.len() + 8);
            w.string(reason);
            w.finish()
        }
    }
}

/// Decode a client→server message.
pub fn decode_client_message(buf: &[u8]) -> Result<ClientMessage> {
    let mut r = Reader::new(buf);
    let tag = read_header(&mut r)?;
    let msg = match tag {
        TAG_REGISTER => ClientMessage::Register(ClientInfo {
            client_id: r.string()?,
            device: r.string()?,
            os: r.string()?,
            num_examples: r.u64()?,
        }),
        TAG_GET_PARAMETERS_RES => ClientMessage::GetParametersRes(GetParametersRes {
            status: r.status()?,
            parameters: r.parameters()?,
        }),
        TAG_FIT_RES => ClientMessage::FitRes(FitRes {
            status: r.status()?,
            parameters: r.parameters()?,
            num_examples: r.u64()?,
            metrics: r.config()?,
        }),
        TAG_EVALUATE_RES => ClientMessage::EvaluateRes(EvaluateRes {
            status: r.status()?,
            loss: r.f64()?,
            num_examples: r.u64()?,
            metrics: r.config()?,
        }),
        TAG_DISCONNECT => ClientMessage::Disconnect { reason: r.string()? },
        other => return Err(Error::Codec(format!("unknown client message tag {other:#04x}"))),
    };
    r.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn params(n: usize) -> Parameters {
        Parameters::from_flat((0..n).map(|i| i as f32 * 0.5).collect())
    }

    #[test]
    fn fit_ins_roundtrip() {
        let msg = ServerMessage::FitIns(FitIns {
            parameters: params(1000),
            config: config! { "epochs" => 5i64, "lr" => 0.05f64, "model" => "cifar_cnn" },
        });
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn evaluate_ins_roundtrip() {
        let msg = ServerMessage::EvaluateIns(EvaluateIns {
            parameters: params(7),
            config: config! { "batches" => 2i64 },
        });
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn reconnect_roundtrip() {
        let msg = ServerMessage::Reconnect { seconds: 30 };
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn fit_res_roundtrip() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(64),
            num_examples: 320,
            metrics: config! {
                "compute_time_s" => 12.5f64,
                "energy_j" => 88.0f64,
                "steps" => 80i64,
                "truncated" => false,
            },
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    #[test]
    fn evaluate_res_roundtrip() {
        let msg = ClientMessage::EvaluateRes(EvaluateRes {
            status: Status { code: StatusCode::EvaluateError, message: "oom".into() },
            loss: 2.3,
            num_examples: 100,
            metrics: config! { "accuracy" => 0.67f64 },
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    #[test]
    fn register_roundtrip() {
        let msg = ClientMessage::Register(ClientInfo {
            client_id: "tx2-07".into(),
            device: "jetson_tx2_gpu".into(),
            os: "Linux tegra".into(),
            num_examples: 320,
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    #[test]
    fn f16_tensor_roundtrip() {
        let p = Parameters::from_flat(vec![0.5, -1.25, 3.0])
            .quantize_f16()
            .unwrap();
        let msg = ServerMessage::FitIns(FitIns { parameters: p, config: ConfigMap::new() });
        let buf = encode_server_message(&msg);
        assert_eq!(decode_server_message(&buf).unwrap(), msg);
    }

    #[test]
    fn int_tensor_roundtrip() {
        let msg = ClientMessage::GetParametersRes(GetParametersRes {
            status: Status::ok(),
            parameters: Parameters {
                tensors: vec![Tensor::i32(vec![2, 2], vec![1, -2, 3, -4]).unwrap()],
            },
        });
        let buf = encode_client_message(&msg);
        assert_eq!(decode_client_message(&buf).unwrap(), msg);
    }

    /// Golden wire vectors: these exact bytes are the protocol — a
    /// foreign-language client implements against them, so they must
    /// never drift (they pinned the hand-rolled encoder before the
    /// `util::bytes` unification and pin the unified one now).
    #[test]
    fn wire_bytes_are_pinned() {
        let buf = encode_server_message(&ServerMessage::Reconnect {
            seconds: 0x0102_0304_0506_0708,
        });
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, // magic 0xF10E LE
                0x01, // version
                0x04, // TAG_RECONNECT
                0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // seconds LE
            ]
        );

        let buf = encode_client_message(&ClientMessage::Disconnect {
            reason: "ok".into(),
        });
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, 0x01, 0x85, // header, TAG_DISCONNECT
                0x02, 0x00, 0x00, 0x00, // string length u32 LE
                b'o', b'k',
            ]
        );

        // one tensor-bearing message: f32 raw-bit LE payload
        let msg = ServerMessage::FitIns(FitIns {
            parameters: Parameters::from_flat(vec![1.0]),
            config: ConfigMap::new(),
        });
        let buf = encode_server_message(&msg);
        assert_eq!(
            buf,
            vec![
                0x0E, 0xF1, 0x01, 0x02, // header, TAG_FIT_INS
                0x01, 0x00, // tensor count u16
                0x00, // dtype f32
                0x01, // rank 1
                0x01, 0x00, 0x00, 0x00, // dim 1
                0x01, 0x00, 0x00, 0x00, // element count
                0x00, 0x00, 0x80, 0x3F, // 1.0f32 bits LE
                0x00, 0x00, 0x00, 0x00, // empty config map
            ]
        );
    }

    #[test]
    fn bad_magic_rejected() {
        let msg = ServerMessage::Reconnect { seconds: 1 };
        let mut buf = encode_server_message(&msg);
        buf[0] ^= 0xFF;
        assert!(matches!(decode_server_message(&buf), Err(Error::Codec(_))));
    }

    #[test]
    fn bad_version_rejected() {
        let msg = ServerMessage::Reconnect { seconds: 1 };
        let mut buf = encode_server_message(&msg);
        buf[2] = 99;
        assert!(decode_server_message(&buf).is_err());
    }

    #[test]
    fn truncation_rejected_everywhere() {
        let msg = ClientMessage::FitRes(FitRes {
            status: Status::ok(),
            parameters: params(32),
            num_examples: 1,
            metrics: config! { "a" => 1i64 },
        });
        let buf = encode_client_message(&msg);
        for cut in 1..buf.len() {
            assert!(
                decode_client_message(&buf[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let msg = ServerMessage::Reconnect { seconds: 1 };
        let mut buf = encode_server_message(&msg);
        buf.push(0);
        assert!(decode_server_message(&buf).is_err());
    }

    #[test]
    fn client_server_tags_disjoint() {
        // A client message must never decode as a server message.
        let msg = ClientMessage::Disconnect { reason: "done".into() };
        let buf = encode_client_message(&msg);
        assert!(decode_server_message(&buf).is_err());
    }
}
