//! The Flower server: RPC registration, the FL loop, and round accounting.
//!
//! Mirrors the paper's Figure 1: a `ClientManager` tracks connections, the
//! FL loop orchestrates rounds, and every *decision* (who trains, with
//! what config, how results merge) is delegated to the configured
//! [`crate::strategy::Strategy`].
//!
//! The loop also produces the paper's evaluation currency: per-round
//! modeled wall time (slowest participant + server overhead) and energy
//! (compute + radio + optional idle-while-waiting), accumulated into a
//! [`History`].

pub mod async_loop;
pub mod client_manager;
pub mod history;
pub mod proxy;

pub use async_loop::{AsyncServer, AsyncStats};
pub use client_manager::ClientManager;
pub use history::{History, RoundRecord};
pub use proxy::ClientProxy;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::client::keys;
use crate::error::{Error, Result};
use crate::proto::scalar::ConfigExt;
use crate::proto::{ClientMessage, Parameters};
use crate::sched::policy::{Candidate, SelectionContext, SelectionPolicy};
use crate::sim::cost::CostModel;
use crate::strategy::{fedavg, ClientHandle, Strategy};
use crate::telemetry::log;
use crate::transport::tcp::TcpTransportListener;
use crate::transport::Connection;

/// Server-side knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub num_rounds: u64,
    /// Per-client deadline for one fit/evaluate exchange (wall clock).
    pub round_timeout: Duration,
    /// Wait for this many clients before round 1.
    pub quorum: usize,
    pub quorum_timeout: Duration,
    /// Early-stop once federated accuracy reaches this.
    pub target_accuracy: Option<f64>,
    /// Charge idle power to fast clients while they wait for stragglers.
    pub count_idle_energy: bool,
    /// Async loop ([`AsyncServer`]): flush the aggregation buffer every K
    /// successful results. `None` = the synchronous barrier loop; callers
    /// (e.g. [`crate::sim::run_experiment`]) use this knob to pick the
    /// loop and size the FedBuff buffer.
    pub async_buffer: Option<usize>,
    /// Async loop: polynomial staleness-discount exponent
    /// (`w(s) = (1+s)^-alpha`).
    pub staleness_alpha: f64,
    /// Async loop: max concurrent fit dispatches (0 = every registered
    /// client stays in flight).
    pub max_concurrency: usize,
    /// Async loop: modeled local train steps per dispatch, used for
    /// virtual-time accounting of each in-flight exchange.
    pub steps_per_round: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            num_rounds: 10,
            round_timeout: Duration::from_secs(600),
            quorum: 1,
            quorum_timeout: Duration::from_secs(60),
            target_accuracy: None,
            count_idle_energy: true,
            async_buffer: None,
            staleness_alpha: crate::strategy::fedbuff::DEFAULT_STALENESS_ALPHA,
            max_concurrency: 0,
            steps_per_round: 8,
        }
    }
}

/// What the server-side selection hook needs to build a
/// [`SelectionContext`] each round (the payload size comes from the
/// current parameters).
#[derive(Debug, Clone)]
pub struct SelectionHints {
    /// How many clients to hand the strategy each round.
    pub target_cohort: usize,
    /// Round deadline τ for deadline/utility policies.
    pub deadline_s: Option<f64>,
    /// Modeled local train steps per selected client per round.
    pub steps_per_round: u64,
}

/// Per-client observations feeding cost-aware selection.
#[derive(Debug, Clone, Default)]
struct ClientStat {
    last_loss: Option<f64>,
    last_selected_round: Option<u64>,
}

/// The FL server.
pub struct Server {
    pub manager: Arc<ClientManager>,
    strategy: Box<dyn Strategy>,
    cost: CostModel,
    config: ServerConfig,
    /// Optional cost-aware selection hook: when set, cohort choice is
    /// delegated to the policy and the strategy only sees the pre-selected
    /// subset. A strategy with `fraction_fit < 1` still subsamples within
    /// that subset; leave it at 1.0 (the default) for full delegation.
    selector: Option<(Box<dyn SelectionPolicy>, SelectionHints)>,
    client_stats: HashMap<String, ClientStat>,
}

impl Server {
    pub fn new(
        manager: Arc<ClientManager>,
        strategy: Box<dyn Strategy>,
        cost: CostModel,
        config: ServerConfig,
    ) -> Self {
        Server {
            manager,
            strategy,
            cost,
            config,
            selector: None,
            client_stats: HashMap::new(),
        }
    }

    /// Delegate per-round cohort choice to a [`SelectionPolicy`] from the
    /// `sched` subsystem.
    pub fn with_selection(
        mut self,
        policy: Box<dyn SelectionPolicy>,
        hints: SelectionHints,
    ) -> Self {
        self.selector = Some((policy, hints));
        self
    }

    /// Run the configured number of rounds from `initial` parameters.
    pub fn run(&mut self, initial: Parameters) -> Result<History> {
        if !self
            .manager
            .wait_for(self.config.quorum, self.config.quorum_timeout)
        {
            return Err(Error::Timeout(format!(
                "quorum of {} clients not reached ({} connected)",
                self.config.quorum,
                self.manager.len()
            )));
        }
        let mut params = initial;
        let mut history = History::default();
        for round in 1..=self.config.num_rounds {
            let record = self.run_round(round, &mut params)?;
            log::info(&format!(
                "round {round:>3}: acc={:.4} loss={:.4} t={:.1}s (cum {:.1} min) E={:.1} kJ (cum {:.1} kJ){}",
                record.accuracy,
                record.eval_loss,
                record.round_time_s,
                (history.total_time_s() + record.round_time_s) / 60.0,
                record.round_energy_j / 1e3,
                (history.total_energy_j() + record.round_energy_j) / 1e3,
                if record.truncated_clients > 0 {
                    format!(" truncated={}", record.truncated_clients)
                } else {
                    String::new()
                },
            ));
            let acc = record.accuracy;
            history.push(record);
            if let Some(target) = self.config.target_accuracy {
                if acc >= target {
                    log::info(&format!("target accuracy {target} reached; stopping"));
                    break;
                }
            }
        }
        // Graceful shutdown. A client whose connection died mid-run (or
        // that already left) makes `reconnect` fail — that must never
        // hang or abort the shutdown sweep, but it must not be silent
        // either: surface which client it was.
        for proxy in self.manager.snapshot() {
            if let Err(e) = proxy.reconnect(0) {
                log::warn(&format!(
                    "client {}: reconnect at shutdown failed: {e}",
                    proxy.handle.id
                ));
            }
        }
        Ok(history)
    }

    fn run_round(&mut self, round: u64, params: &mut Parameters) -> Result<RoundRecord> {
        let all_proxies = self.manager.snapshot();
        if all_proxies.is_empty() {
            return Err(Error::Protocol("no clients connected".into()));
        }

        // ---- cost-aware selection hook ---------------------------------
        let proxies: Vec<Arc<ClientProxy>> = match &mut self.selector {
            Some((policy, hints)) => {
                // Bound the stats map under id churn: once it far exceeds
                // the live cohort, drop entries for clients no longer
                // registered (brief disconnects keep their history until
                // then; a pruned client just rejoins the explore pool).
                if self.client_stats.len() > all_proxies.len().saturating_mul(4).max(1024) {
                    let live: std::collections::HashSet<&str> =
                        all_proxies.iter().map(|p| p.handle.id.as_str()).collect();
                    self.client_stats.retain(|id, _| live.contains(id.as_str()));
                }
                let candidates: Vec<Candidate> = all_proxies
                    .iter()
                    .map(|p| {
                        let stat = self.client_stats.get(&p.handle.id);
                        Candidate {
                            device: p.handle.device,
                            num_examples: p.handle.num_examples,
                            last_loss: stat.and_then(|s| s.last_loss),
                            rounds_since_selected: stat
                                .and_then(|s| s.last_selected_round)
                                .map(|r| round.saturating_sub(r)),
                        }
                    })
                    .collect();
                let ctx = SelectionContext {
                    round,
                    cost: &self.cost,
                    steps_per_round: hints.steps_per_round,
                    model_bytes: params.byte_len(),
                    target_cohort: hints.target_cohort,
                    deadline_s: hints.deadline_s,
                };
                let picked = policy.select(&ctx, &candidates);
                picked
                    .into_iter()
                    .map(|i| Arc::clone(&all_proxies[i]))
                    .collect()
            }
            None => all_proxies,
        };
        if proxies.is_empty() {
            return Err(Error::Protocol("selection policy picked no clients".into()));
        }
        let handles: Vec<ClientHandle> = proxies.iter().map(|p| p.handle.clone()).collect();

        // ---- fit phase -------------------------------------------------
        let plan = self.strategy.configure_fit(round, params, &handles);
        if plan.is_empty() {
            return Err(Error::Protocol("strategy selected no clients".into()));
        }
        let fit_selected = plan.len();
        // Stats only feed the selection hook's candidates; don't grow the
        // map on servers that never read it.
        if self.selector.is_some() {
            for (idx, _) in &plan {
                self.client_stats
                    .entry(handles[*idx].id.clone())
                    .or_default()
                    .last_selected_round = Some(round);
            }
        }
        let timeout = self.config.round_timeout;
        let mut fit_results: Vec<(ClientHandle, crate::proto::FitRes)> = Vec::new();
        let mut fit_failures = 0usize;
        let mut down_bytes = 0usize;
        let mut up_bytes = 0usize;
        let mut client_times: Vec<(ClientHandle, f64, f64)> = Vec::new(); // (handle, t, energy)

        let outcomes: Vec<(usize, usize, Result<crate::proto::FitRes>)> =
            std::thread::scope(|scope| {
                let mut tasks = Vec::new();
                for (idx, ins) in &plan {
                    let proxy = Arc::clone(&proxies[*idx]);
                    let bytes_down = ins.parameters.byte_len();
                    let ins = ins.clone();
                    tasks.push((
                        *idx,
                        bytes_down,
                        scope.spawn(move || proxy.fit(ins, timeout)),
                    ));
                }
                tasks
                    .into_iter()
                    .map(|(idx, bytes_down, t)| {
                        (
                            idx,
                            bytes_down,
                            t.join().unwrap_or_else(|_| {
                                Err(Error::Client("fit thread panicked".into()))
                            }),
                        )
                    })
                    .collect()
            });

        for (idx, bytes_down, outcome) in outcomes {
            let handle = handles[idx].clone();
            match outcome {
                Ok(res) if res.status.is_ok() => {
                    down_bytes += bytes_down;
                    let bytes_up = res.parameters.byte_len();
                    up_bytes += bytes_up;
                    let down = self.cost.comm(handle.device, bytes_down);
                    let up = self.cost.comm(handle.device, bytes_up);
                    let compute_t = res.metrics.get_f64_or(keys::COMPUTE_TIME_S, 0.0);
                    let compute_e = res.metrics.get_f64_or(keys::ENERGY_J, 0.0);
                    let t = down.time_s + compute_t + up.time_s;
                    let e = down.energy_j + compute_e + up.energy_j;
                    let loss = res.metrics.get_f64_or(keys::TRAIN_LOSS, f64::NAN);
                    if self.selector.is_some() && loss.is_finite() {
                        self.client_stats
                            .entry(handle.id.clone())
                            .or_default()
                            .last_loss = Some(loss);
                    }
                    client_times.push((handle.clone(), t, e));
                    fit_results.push((handle, res));
                }
                Ok(res) => {
                    log::warn(&format!(
                        "client {} fit failed: {}",
                        handle.id, res.status.message
                    ));
                    fit_failures += 1;
                }
                Err(e) => {
                    log::warn(&format!("client {} fit error: {e}", handle.id));
                    fit_failures += 1;
                }
            }
        }

        let round_fit_time = client_times
            .iter()
            .map(|(_, t, _)| *t)
            .fold(0.0f64, f64::max);
        let mut round_energy: f64 = client_times.iter().map(|(_, _, e)| e).sum();
        if self.config.count_idle_energy {
            for (handle, t, _) in &client_times {
                round_energy += self
                    .cost
                    .idle(handle.device, (round_fit_time - t).max(0.0))
                    .energy_j;
            }
        }

        let train_loss = fedavg::mean_train_loss(&fit_results);
        let truncated_clients = fedavg::truncated_count(&fit_results);
        let steps: u64 = fit_results
            .iter()
            .map(|(_, res)| res.metrics.get_i64_or(keys::STEPS, 0).max(0) as u64)
            .sum();

        *params = self
            .strategy
            .aggregate_fit(round, &fit_results, fit_failures)?;

        // ---- evaluate phase --------------------------------------------
        let eval_plan = self.strategy.configure_evaluate(round, params, &handles);
        let eval_outcomes: Vec<(usize, Result<crate::proto::EvaluateRes>)> =
            std::thread::scope(|scope| {
                let mut tasks = Vec::new();
                for (idx, ins) in &eval_plan {
                    let proxy = Arc::clone(&proxies[*idx]);
                    let ins = ins.clone();
                    tasks.push((*idx, scope.spawn(move || proxy.evaluate(ins, timeout))));
                }
                tasks
                    .into_iter()
                    .map(|(idx, t)| {
                        (
                            idx,
                            t.join().unwrap_or_else(|_| {
                                Err(Error::Client("evaluate thread panicked".into()))
                            }),
                        )
                    })
                    .collect()
            });
        let mut eval_results = Vec::new();
        for (idx, outcome) in eval_outcomes {
            match outcome {
                Ok(res) => eval_results.push((handles[idx].clone(), res)),
                Err(e) => log::warn(&format!("client {} evaluate error: {e}", handles[idx].id)),
            }
        }
        let summary = self.strategy.aggregate_evaluate(round, &eval_results)?;

        Ok(RoundRecord {
            round,
            fit_selected,
            fit_completed: fit_results.len(),
            fit_failures,
            train_loss,
            eval_loss: summary.loss,
            accuracy: summary.accuracy,
            round_time_s: round_fit_time + self.cost.server_overhead_s,
            cum_time_s: 0.0,   // filled by History::push
            round_energy_j: round_energy,
            cum_energy_j: 0.0, // filled by History::push
            steps,
            truncated_clients,
            down_bytes,
            up_bytes,
            mean_staleness: 0.0, // barrier rounds are never stale
            max_staleness: 0,
            concurrency: fit_selected,
            fit_discarded: 0,
        })
    }
}

/// Serve TCP registrations in a background thread until `stop` is set.
/// Each accepted connection must open with a `Register` message; the
/// resulting proxy is added to the manager.
pub fn serve_registrations(
    listener: TcpTransportListener,
    manager: Arc<ClientManager>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // Nonblocking accept loop so `stop` is honored promptly.
        let std_listener = listener;
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match std_listener.accept() {
                Ok(mut conn) => {
                    match conn.recv_timeout(Duration::from_secs(5)) {
                        Ok(frame) => match crate::proto::decode_client_message(&frame) {
                            Ok(ClientMessage::Register(info)) => {
                                match crate::device::profiles::by_name(&info.device) {
                                    Ok(device) => {
                                        log::info(&format!(
                                            "registered client {} ({})",
                                            info.client_id, info.device
                                        ));
                                        manager.register(Arc::new(ClientProxy::new(
                                            ClientHandle {
                                                id: info.client_id,
                                                device,
                                                num_examples: info.num_examples,
                                            },
                                            Connection::Tcp(conn),
                                        )));
                                    }
                                    Err(e) => log::warn(&format!("rejecting client: {e}")),
                                }
                            }
                            Ok(other) => log::warn(&format!(
                                "expected Register as first message, got {other:?}"
                            )),
                            Err(e) => log::warn(&format!("bad registration frame: {e}")),
                        },
                        Err(e) => log::warn(&format!("registration read failed: {e}")),
                    }
                }
                Err(e) => {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    log::warn(&format!("accept failed: {e}"));
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::client::Client;
    use crate::device::profiles;
    use crate::proto::*;
    use crate::strategy::{fedavg::TrainingPlan, Aggregator, FedAvg};
    use crate::transport::inproc;

    /// A fake device: "training" adds +1 to every param; eval reports
    /// accuracy = min(1, mean(params)/10).
    struct FakeDevice;

    impl Client for FakeDevice {
        fn get_parameters(&mut self, _: GetParametersIns) -> Result<GetParametersRes> {
            Ok(GetParametersRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(vec![0.0; 4]),
            })
        }
        fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
            let mut p = ins.parameters.to_flat()?.to_vec();
            for v in &mut p {
                *v += 1.0;
            }
            let mut metrics = ConfigMap::new();
            metrics.insert(keys::STEPS.into(), Scalar::I64(8));
            metrics.insert(keys::COMPUTE_TIME_S.into(), Scalar::F64(12.0));
            metrics.insert(keys::ENERGY_J.into(), Scalar::F64(100.0));
            metrics.insert(keys::TRAIN_LOSS.into(), Scalar::F64(1.0));
            metrics.insert(keys::TRUNCATED.into(), Scalar::Bool(false));
            Ok(FitRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(p),
                num_examples: 256,
                metrics,
            })
        }
        fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes> {
            let p = ins.parameters.to_flat()?;
            let mean = p.iter().sum::<f32>() as f64 / p.len() as f64;
            let mut metrics = ConfigMap::new();
            metrics.insert(
                keys::ACCURACY.into(),
                Scalar::F64((mean / 10.0).min(1.0)),
            );
            Ok(EvaluateRes {
                status: Status::ok(),
                loss: (10.0 - mean).max(0.0),
                num_examples: 100,
                metrics,
            })
        }
    }

    /// Spawn one in-proc fake client per entry in `devices` (profile
    /// names); ids are `fake-0..`. Shared with the async-loop tests.
    pub(crate) fn spawn_fake_cohort_on(
        manager: &Arc<ClientManager>,
        devices: &[&str],
    ) -> Vec<std::thread::JoinHandle<()>> {
        devices
            .iter()
            .enumerate()
            .map(|(i, device)| {
                let (server_end, client_end) = inproc::pair();
                manager.register(Arc::new(ClientProxy::new(
                    ClientHandle {
                        id: format!("fake-{i}"),
                        device: profiles::by_name(device).unwrap(),
                        num_examples: 256,
                    },
                    Connection::InProc(server_end),
                )));
                std::thread::spawn(move || {
                    let mut dev = FakeDevice;
                    // client loop without the Register (already registered)
                    let mut conn = Connection::InProc(client_end);
                    loop {
                        let Ok(msg) = conn.recv_server_message() else { return };
                        match msg {
                            ServerMessage::FitIns(ins) => {
                                let res = dev.fit(ins).unwrap();
                                conn.send_client_message(&ClientMessage::FitRes(res)).unwrap();
                            }
                            ServerMessage::EvaluateIns(ins) => {
                                let res = dev.evaluate(ins).unwrap();
                                conn.send_client_message(&ClientMessage::EvaluateRes(res))
                                    .unwrap();
                            }
                            ServerMessage::GetParametersIns(ins) => {
                                let res = dev.get_parameters(ins).unwrap();
                                conn.send_client_message(&ClientMessage::GetParametersRes(res))
                                    .unwrap();
                            }
                            ServerMessage::Reconnect { .. } => {
                                let _ = conn.send_client_message(&ClientMessage::Disconnect {
                                    reason: "bye".into(),
                                });
                                return;
                            }
                        }
                    }
                })
            })
            .collect()
    }

    pub(crate) fn spawn_fake_cohort(
        manager: &Arc<ClientManager>,
        n: usize,
    ) -> Vec<std::thread::JoinHandle<()>> {
        spawn_fake_cohort_on(manager, &vec!["jetson_tx2_gpu"; n])
    }

    /// `fast` TX2 GPUs plus `slow` Raspberry Pis (6× the modeled compute
    /// time — the straggler class the async loop routes around).
    pub(crate) fn spawn_fake_straggler_cohort(
        manager: &Arc<ClientManager>,
        fast: usize,
        slow: usize,
    ) -> Vec<std::thread::JoinHandle<()>> {
        let mut devices = vec!["jetson_tx2_gpu"; fast];
        devices.extend(std::iter::repeat("raspberry_pi4").take(slow));
        spawn_fake_cohort_on(manager, &devices)
    }

    #[test]
    fn fl_loop_converges_and_accounts_costs() {
        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 4);
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                num_rounds: 5,
                quorum: 4,
                ..Default::default()
            },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds.len(), 5);
        // params grow by +1 per round -> accuracy mean/10 grows by 0.1
        let acc: Vec<f64> = history.rounds.iter().map(|r| r.accuracy).collect();
        assert!((acc[0] - 0.1).abs() < 1e-9, "{acc:?}");
        assert!((acc[4] - 0.5).abs() < 1e-9, "{acc:?}");
        // costs: 12s compute + comm + 1s overhead per round
        let r = &history.rounds[0];
        assert!(r.round_time_s > 13.0 && r.round_time_s < 14.0, "{}", r.round_time_s);
        assert!(r.round_energy_j >= 400.0); // 4 clients × 100 J + comm
        assert_eq!(r.steps, 32);
        assert_eq!(r.fit_completed, 4);
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn early_stop_on_target_accuracy() {
        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 2);
        let strategy = FedAvg::new(TrainingPlan::default(), Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                num_rounds: 50,
                quorum: 2,
                target_accuracy: Some(0.3),
                ..Default::default()
            },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds.len(), 3); // acc 0.1, 0.2, 0.3 → stop
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn selection_hook_limits_cohort_per_round() {
        use crate::sched::policy::UniformRandom;

        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 4);
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                num_rounds: 3,
                quorum: 4,
                ..Default::default()
            },
        )
        .with_selection(
            Box::new(UniformRandom::new(11)),
            SelectionHints { target_cohort: 2, deadline_s: None, steps_per_round: 8 },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds.len(), 3);
        for r in &history.rounds {
            assert_eq!(r.fit_selected, 2, "round {}: {r:?}", r.round);
            assert_eq!(r.fit_completed, 2);
        }
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn selection_hook_clamps_oversized_cohort() {
        use crate::sched::policy::UniformRandom;

        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 2);
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig { num_rounds: 1, quorum: 2, ..Default::default() },
        )
        .with_selection(
            Box::new(UniformRandom::new(5)),
            SelectionHints { target_cohort: 10, deadline_s: None, steps_per_round: 8 },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds[0].fit_selected, 2);
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn shutdown_with_dead_connection_warns_but_never_hangs() {
        // One live fake client plus one proxy whose peer hung up before
        // the run: the round counts the dead client as a failure, and the
        // graceful-shutdown sweep must log-and-continue past the dead
        // connection instead of hanging or erroring the whole run.
        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 1);
        let (server_end, client_end) = inproc::pair();
        drop(client_end); // dead on arrival
        manager.register(Arc::new(ClientProxy::new(
            ClientHandle {
                id: "dead-phone".into(),
                device: profiles::by_name("pixel4").unwrap(),
                num_examples: 64,
            },
            Connection::InProc(server_end),
        )));
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig { num_rounds: 1, quorum: 2, ..Default::default() },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let t = std::thread::spawn(move || {
            tx.send(server.run(Parameters::from_flat(vec![0.0; 4]))).ok();
        });
        let history = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("server hung during the run or at shutdown")
            .expect("one live client must be enough to finish the round");
        assert_eq!(history.rounds.len(), 1);
        assert_eq!(history.rounds[0].fit_completed, 1);
        assert_eq!(history.rounds[0].fit_failures, 1);
        t.join().unwrap();
        for th in threads {
            th.join().unwrap();
        }
    }

    #[test]
    fn quorum_timeout_errors() {
        let manager = Arc::new(ClientManager::new());
        let strategy = FedAvg::new(TrainingPlan::default(), Aggregator::Rust);
        let mut server = Server::new(
            manager,
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                quorum: 3,
                quorum_timeout: Duration::from_millis(50),
                ..Default::default()
            },
        );
        assert!(server.run(Parameters::from_flat(vec![0.0])).is_err());
    }
}
