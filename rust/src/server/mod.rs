//! The Flower server: RPC registration, the FL loop, and round accounting.
//!
//! Mirrors the paper's Figure 1: a `ClientManager` tracks connections, the
//! FL loop orchestrates rounds, and every *decision* (who trains, with
//! what config, how results merge) is delegated to the configured
//! [`crate::strategy::Strategy`].
//!
//! Both server surfaces are thin façades over the single execution core
//! in [`exec`]: [`Server`] runs it in barrier mode (one flush per
//! round), [`AsyncServer`] in FedBuff streaming mode (one flush per K
//! folds). Dispatch, outcome classification, accounting, evaluation and
//! the quorum/shutdown lifecycle are one implementation — only the
//! clock differs (client-reported barrier time vs. modeled virtual
//! time).
//!
//! The loop also produces the paper's evaluation currency: per-round
//! modeled wall time (slowest participant + server overhead) and energy
//! (compute + radio + optional idle-while-waiting), accumulated into a
//! [`History`].

pub mod async_loop;
pub mod client_manager;
pub mod edge;
pub mod exec;
pub mod history;
pub mod proxy;

pub use async_loop::AsyncServer;
pub use client_manager::ClientManager;
pub use edge::EdgeNode;
pub use exec::AsyncStats;
pub use history::{History, RoundRecord};
pub use proxy::ClientProxy;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::proto::{ClientMessage, Parameters};
use crate::sched::policy::SelectionPolicy;
use crate::sim::cost::CostModel;
use crate::strategy::{ClientHandle, Strategy};
use crate::telemetry::log;
use crate::transport::tcp::TcpTransportListener;
use crate::transport::Connection;

use exec::{Brain, ExecCore};

/// Server-side knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub num_rounds: u64,
    /// Per-client deadline for one fit/evaluate exchange (wall clock).
    pub round_timeout: Duration,
    /// Wait for this many clients before round 1.
    pub quorum: usize,
    pub quorum_timeout: Duration,
    /// Early-stop once federated accuracy reaches this.
    pub target_accuracy: Option<f64>,
    /// Charge idle power to fast clients while they wait for stragglers.
    pub count_idle_energy: bool,
    /// Async loop ([`AsyncServer`]): flush the aggregation buffer every K
    /// successful results. `None` = the synchronous barrier loop; callers
    /// (e.g. [`crate::sim::run_experiment`]) use this knob to pick the
    /// loop and size the FedBuff buffer.
    pub async_buffer: Option<usize>,
    /// Async loop: polynomial staleness-discount exponent
    /// (`w(s) = (1+s)^-alpha`).
    pub staleness_alpha: f64,
    /// Async loop: max concurrent fit dispatches (0 = every registered
    /// client stays in flight).
    pub max_concurrency: usize,
    /// Async loop: modeled local train steps per dispatch, used for
    /// virtual-time accounting of each in-flight exchange.
    pub steps_per_round: u64,
    /// Write atomic server checkpoints (parameters, history, whole-run
    /// accounting, selection observations — see [`crate::persist`]) to
    /// this directory at round/flush boundaries. `None` = off.
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Checkpoint every N rounds / model versions (0 = every flush).
    pub checkpoint_every_rounds: u64,
    /// Resume from this checkpoint file (or the newest valid checkpoint
    /// in this directory) before round 1: parameters, history,
    /// accounting and the selection hook's RNG position are restored
    /// and the loop continues at the next round (a mode flip or a
    /// parameter-shape mismatch is refused). In-flight work from the
    /// killed run was drained, not persisted — the resumed loop
    /// re-dispatches (inner strategy state, e.g. FedAvgM momentum,
    /// restarts fresh; the FedBuff buffer is empty at every flush
    /// boundary by construction).
    pub resume_from: Option<std::path::PathBuf>,
    /// External stop flag: when set, the loop exits cleanly at the next
    /// round/flush boundary (used by `flowrs loadgen` to bound a run by
    /// wall-clock duration). `None` = run to `num_rounds`.
    pub stop: Option<Arc<AtomicBool>>,
    /// Wire profile of the configured strategy
    /// ([`crate::strategy::wire::WireModel`]): the cost-aware selection
    /// hook models per-dispatch traffic from it, so live modeled round
    /// time/energy (and deadline-based selection) agree with the sched
    /// engine for compressed (halved payloads) and secagg (mask-exchange
    /// overhead) runs. Payload *accounting* still uses actual encoded
    /// sizes; this only feeds the selection model.
    pub wire: crate::config::SchedStrategyConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            num_rounds: 10,
            round_timeout: Duration::from_secs(600),
            quorum: 1,
            quorum_timeout: Duration::from_secs(60),
            target_accuracy: None,
            count_idle_energy: true,
            async_buffer: None,
            staleness_alpha: crate::strategy::fedbuff::DEFAULT_STALENESS_ALPHA,
            max_concurrency: 0,
            steps_per_round: 8,
            checkpoint_dir: None,
            checkpoint_every_rounds: 0,
            resume_from: None,
            stop: None,
            wire: crate::config::SchedStrategyConfig::FedAvg,
        }
    }
}

/// What the server-side selection hook needs to build a
/// [`crate::sched::policy::SelectionContext`] each round (the payload
/// size comes from the current parameters).
#[derive(Debug, Clone)]
pub struct SelectionHints {
    /// How many clients to hand the strategy each round.
    pub target_cohort: usize,
    /// Round deadline τ for deadline/utility policies.
    pub deadline_s: Option<f64>,
    /// Modeled local train steps per selected client per round.
    pub steps_per_round: u64,
}

/// The FL server — the barrier-mode façade over `exec::ExecCore`: one
/// buffer flush per round, zero staleness, client-reported costs.
pub struct Server {
    pub manager: Arc<ClientManager>,
    core: ExecCore,
}

impl Server {
    pub fn new(
        manager: Arc<ClientManager>,
        strategy: Box<dyn Strategy>,
        cost: CostModel,
        config: ServerConfig,
    ) -> Self {
        let core = ExecCore::new(Arc::clone(&manager), Brain::Sync(strategy), cost, config);
        Server { manager, core }
    }

    /// Delegate per-round cohort choice to a [`SelectionPolicy`] from the
    /// `sched` subsystem. A strategy with `fraction_fit < 1` still
    /// subsamples within the selected subset; leave it at 1.0 (the
    /// default) for full delegation.
    pub fn with_selection(
        mut self,
        policy: Box<dyn SelectionPolicy>,
        hints: SelectionHints,
    ) -> Self {
        self.core.set_selection(policy, hints);
        self
    }

    /// Run the configured number of rounds from `initial` parameters.
    pub fn run(&mut self, initial: Parameters) -> Result<History> {
        self.core.run(initial)
    }

    /// Whole-run accounting: the same `dispatched == folded + failures +
    /// discarded + drained` identity the streaming loop keeps.
    pub fn stats(&self) -> AsyncStats {
        self.core.stats()
    }
}

/// Serve TCP registrations in a background thread until `stop` is set.
///
/// Each accepted connection either opens with a `Hello` (version
/// negotiation, answered with `HelloAck` carrying the highest mutually
/// supported wire version — see `transport/PROTOCOL.md`) followed by
/// `Register`, or — legacy v1 peers — with a bare `Register` and stays
/// on wire v1. The resulting proxy is added to the manager with its
/// negotiated version.
pub fn serve_registrations(
    listener: TcpTransportListener,
    manager: Arc<ClientManager>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // Nonblocking accept loop so `stop` is honored promptly.
        let std_listener = listener;
        loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match std_listener.accept() {
                Ok(mut conn) => {
                    let mut wire = crate::proto::codec::VERSION;
                    let mut first = conn
                        .recv_timeout(Duration::from_secs(5))
                        .and_then(|frame| crate::proto::decode_client_message(&frame));
                    if let Ok(ClientMessage::Hello { max_version }) = first {
                        wire = crate::proto::negotiate_version(max_version);
                        let ack = crate::proto::encode_server_message(
                            &crate::proto::ServerMessage::HelloAck { version: wire },
                        );
                        first = conn.send(&ack).and_then(|()| {
                            conn.recv_timeout(Duration::from_secs(5)).and_then(|frame| {
                                crate::proto::decode_client_message(&frame)
                            })
                        });
                    }
                    match first {
                        Ok(ClientMessage::Register(info)) => {
                            match crate::device::profiles::by_name(&info.device) {
                                Ok(device) => {
                                    log::info(&format!(
                                        "registered client {} ({}, wire v{wire})",
                                        info.client_id, info.device
                                    ));
                                    manager.register(Arc::new(ClientProxy::with_wire(
                                        ClientHandle {
                                            id: info.client_id,
                                            device,
                                            num_examples: info.num_examples,
                                        },
                                        Connection::Tcp(conn),
                                        wire,
                                    )));
                                }
                                Err(e) => log::warn(&format!("rejecting client: {e}")),
                            }
                        }
                        Ok(other) => log::warn(&format!(
                            "expected Register as first message, got {other:?}"
                        )),
                        Err(e) => log::warn(&format!("registration failed: {e}")),
                    }
                }
                Err(e) => {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    log::warn(&format!("accept failed: {e}"));
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::client::{keys, Client};
    use crate::device::profiles;
    use crate::proto::*;
    use crate::strategy::{fedavg::TrainingPlan, Aggregator, FedAvg};
    use crate::transport::inproc;

    /// A fake device: "training" adds +1 to every param; eval reports
    /// accuracy = min(1, mean(params)/10).
    struct FakeDevice;

    impl Client for FakeDevice {
        fn get_parameters(&mut self, _: GetParametersIns) -> Result<GetParametersRes> {
            Ok(GetParametersRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(vec![0.0; 4]),
            })
        }
        fn fit(&mut self, ins: FitIns) -> Result<FitRes> {
            let mut p = ins.parameters.to_flat()?.to_vec();
            for v in &mut p {
                *v += 1.0;
            }
            let mut metrics = ConfigMap::new();
            metrics.insert(keys::STEPS.into(), Scalar::I64(8));
            metrics.insert(keys::COMPUTE_TIME_S.into(), Scalar::F64(12.0));
            metrics.insert(keys::ENERGY_J.into(), Scalar::F64(100.0));
            metrics.insert(keys::TRAIN_LOSS.into(), Scalar::F64(1.0));
            metrics.insert(keys::TRUNCATED.into(), Scalar::Bool(false));
            Ok(FitRes {
                status: Status::ok(),
                parameters: Parameters::from_flat(p),
                num_examples: 256,
                metrics,
            })
        }
        fn evaluate(&mut self, ins: EvaluateIns) -> Result<EvaluateRes> {
            let p = ins.parameters.to_flat()?;
            let mean = p.iter().sum::<f32>() as f64 / p.len() as f64;
            let mut metrics = ConfigMap::new();
            metrics.insert(
                keys::ACCURACY.into(),
                Scalar::F64((mean / 10.0).min(1.0)),
            );
            Ok(EvaluateRes {
                status: Status::ok(),
                loss: (10.0 - mean).max(0.0),
                num_examples: 100,
                metrics,
            })
        }
    }

    /// Spawn one in-proc fake client per entry in `devices` (profile
    /// names); ids are `fake-0..`. Shared with the async-loop tests.
    pub(crate) fn spawn_fake_cohort_on(
        manager: &Arc<ClientManager>,
        devices: &[&str],
    ) -> Vec<std::thread::JoinHandle<()>> {
        devices
            .iter()
            .enumerate()
            .map(|(i, device)| {
                let (server_end, client_end) = inproc::pair();
                manager.register(Arc::new(ClientProxy::new(
                    ClientHandle {
                        id: format!("fake-{i}"),
                        device: profiles::by_name(device).unwrap(),
                        num_examples: 256,
                    },
                    Connection::InProc(server_end),
                )));
                std::thread::spawn(move || {
                    let mut dev = FakeDevice;
                    // client loop without the Register (already registered)
                    let mut conn = Connection::InProc(client_end);
                    loop {
                        let Ok(msg) = conn.recv_server_message() else { return };
                        match msg {
                            ServerMessage::FitIns(ins) => {
                                let res = dev.fit(ins).unwrap();
                                conn.send_client_message(&ClientMessage::FitRes(res)).unwrap();
                            }
                            ServerMessage::EvaluateIns(ins) => {
                                let res = dev.evaluate(ins).unwrap();
                                conn.send_client_message(&ClientMessage::EvaluateRes(res))
                                    .unwrap();
                            }
                            ServerMessage::GetParametersIns(ins) => {
                                let res = dev.get_parameters(ins).unwrap();
                                conn.send_client_message(&ClientMessage::GetParametersRes(res))
                                    .unwrap();
                            }
                            ServerMessage::Reconnect { .. } => {
                                let _ = conn.send_client_message(&ClientMessage::Disconnect {
                                    reason: "bye".into(),
                                });
                                return;
                            }
                            // negotiation happens before registration;
                            // a stray ack is ignorable
                            ServerMessage::HelloAck { .. } => {}
                        }
                    }
                })
            })
            .collect()
    }

    pub(crate) fn spawn_fake_cohort(
        manager: &Arc<ClientManager>,
        n: usize,
    ) -> Vec<std::thread::JoinHandle<()>> {
        spawn_fake_cohort_on(manager, &vec!["jetson_tx2_gpu"; n])
    }

    /// `fast` TX2 GPUs plus `slow` Raspberry Pis (6× the modeled compute
    /// time — the straggler class the async loop routes around).
    pub(crate) fn spawn_fake_straggler_cohort(
        manager: &Arc<ClientManager>,
        fast: usize,
        slow: usize,
    ) -> Vec<std::thread::JoinHandle<()>> {
        let mut devices = vec!["jetson_tx2_gpu"; fast];
        devices.extend(std::iter::repeat("raspberry_pi4").take(slow));
        spawn_fake_cohort_on(manager, &devices)
    }

    #[test]
    fn fl_loop_converges_and_accounts_costs() {
        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 4);
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                num_rounds: 5,
                quorum: 4,
                ..Default::default()
            },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds.len(), 5);
        // params grow by +1 per round -> accuracy mean/10 grows by 0.1
        let acc: Vec<f64> = history.rounds.iter().map(|r| r.accuracy).collect();
        assert!((acc[0] - 0.1).abs() < 1e-9, "{acc:?}");
        assert!((acc[4] - 0.5).abs() < 1e-9, "{acc:?}");
        // costs: 12s compute + comm + 1s overhead per round
        let r = &history.rounds[0];
        assert!(r.round_time_s > 13.0 && r.round_time_s < 14.0, "{}", r.round_time_s);
        assert!(r.round_energy_j >= 400.0); // 4 clients × 100 J + comm
        assert_eq!(r.steps, 32);
        assert_eq!(r.fit_completed, 4);
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn early_stop_on_target_accuracy() {
        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 2);
        let strategy = FedAvg::new(TrainingPlan::default(), Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                num_rounds: 50,
                quorum: 2,
                target_accuracy: Some(0.3),
                ..Default::default()
            },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds.len(), 3); // acc 0.1, 0.2, 0.3 → stop
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn selection_hook_limits_cohort_per_round() {
        use crate::sched::policy::UniformRandom;

        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 4);
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                num_rounds: 3,
                quorum: 4,
                ..Default::default()
            },
        )
        .with_selection(
            Box::new(UniformRandom::new(11)),
            SelectionHints { target_cohort: 2, deadline_s: None, steps_per_round: 8 },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds.len(), 3);
        for r in &history.rounds {
            assert_eq!(r.fit_selected, 2, "round {}: {r:?}", r.round);
            assert_eq!(r.fit_completed, 2);
        }
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn selection_hook_clamps_oversized_cohort() {
        use crate::sched::policy::UniformRandom;

        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 2);
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig { num_rounds: 1, quorum: 2, ..Default::default() },
        )
        .with_selection(
            Box::new(UniformRandom::new(5)),
            SelectionHints { target_cohort: 10, deadline_s: None, steps_per_round: 8 },
        );
        let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
        assert_eq!(history.rounds[0].fit_selected, 2);
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn shutdown_with_dead_connection_warns_but_never_hangs() {
        // One live fake client plus one proxy whose peer hung up before
        // the run: the round counts the dead client as a failure, and the
        // graceful-shutdown sweep must log-and-continue past the dead
        // connection instead of hanging or erroring the whole run.
        let manager = Arc::new(ClientManager::new());
        let threads = spawn_fake_cohort(&manager, 1);
        let (server_end, client_end) = inproc::pair();
        drop(client_end); // dead on arrival
        manager.register(Arc::new(ClientProxy::new(
            ClientHandle {
                id: "dead-phone".into(),
                device: profiles::by_name("pixel4").unwrap(),
                num_examples: 64,
            },
            Connection::InProc(server_end),
        )));
        let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
        let mut server = Server::new(
            Arc::clone(&manager),
            Box::new(strategy),
            CostModel::default(),
            ServerConfig { num_rounds: 1, quorum: 2, ..Default::default() },
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let t = std::thread::spawn(move || {
            tx.send(server.run(Parameters::from_flat(vec![0.0; 4]))).ok();
        });
        let history = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("server hung during the run or at shutdown")
            .expect("one live client must be enough to finish the round");
        assert_eq!(history.rounds.len(), 1);
        assert_eq!(history.rounds[0].fit_completed, 1);
        assert_eq!(history.rounds[0].fit_failures, 1);
        t.join().unwrap();
        for th in threads {
            th.join().unwrap();
        }
    }

    #[test]
    fn barrier_checkpoint_resume_reproduces_uninterrupted_history() {
        let dir = std::env::temp_dir().join(format!(
            "flowrs-barrier-server-ckpt-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);

        let run = |rounds: u64, ckpt: bool, resume: bool| -> History {
            let manager = Arc::new(ClientManager::new());
            let threads = spawn_fake_cohort(&manager, 2);
            let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
            let mut server = Server::new(
                Arc::clone(&manager),
                Box::new(strategy),
                CostModel::default(),
                ServerConfig {
                    num_rounds: rounds,
                    quorum: 2,
                    checkpoint_dir: ckpt.then(|| dir.clone()),
                    resume_from: resume.then(|| dir.clone()),
                    ..Default::default()
                },
            );
            let history = server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
            for t in threads {
                t.join().unwrap();
            }
            history
        };

        let full = run(5, false, false);
        let killed = run(3, true, false); // checkpoints at rounds 1..=3
        assert_eq!(killed.rounds.len(), 3);
        let resumed = run(5, false, true);
        // the fake cohort is fully deterministic, so the spliced history
        // must be byte-identical to the uninterrupted run's
        assert_eq!(resumed.to_csv(), full.to_csv());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn barrier_resume_continues_the_selection_rng_stream() {
        use crate::persist::load_server_checkpoint;
        use crate::sched::policy::UniformRandom;

        let base = std::env::temp_dir().join(format!(
            "flowrs-server-ckpt-rng-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&base);
        let dir_full = base.join("full");
        let dir_kr = base.join("kill-resume");

        let run = |rounds: u64, ckpt: &std::path::Path, resume: bool| {
            let manager = Arc::new(ClientManager::new());
            let threads = spawn_fake_cohort(&manager, 3);
            let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
            let mut server = Server::new(
                Arc::clone(&manager),
                Box::new(strategy),
                CostModel::default(),
                ServerConfig {
                    num_rounds: rounds,
                    quorum: 3,
                    checkpoint_dir: Some(ckpt.to_path_buf()),
                    resume_from: resume.then(|| ckpt.to_path_buf()),
                    ..Default::default()
                },
            )
            .with_selection(
                Box::new(UniformRandom::new(11)),
                SelectionHints { target_cohort: 1, deadline_s: None, steps_per_round: 8 },
            );
            server.run(Parameters::from_flat(vec![0.0; 4])).unwrap();
            for t in threads {
                t.join().unwrap();
            }
        };

        run(5, &dir_full, false); // uninterrupted
        run(3, &dir_kr, false); // killed at round 3
        run(5, &dir_kr, true); // resumed to 5

        // The final checkpoints must be identical in every field —
        // including the selection policy's RNG position and the
        // per-client times_selected counters, which only match if the
        // resumed run *continued* the selection stream rather than
        // replaying it from the seed.
        let full = load_server_checkpoint(&dir_full).unwrap();
        let resumed = load_server_checkpoint(&dir_kr).unwrap();
        assert_eq!(full.history.len(), 5);
        assert!(full.policy_rng.is_some(), "selection RNG must be captured");
        assert_eq!(full, resumed);
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn resume_refuses_mode_flip_and_shape_mismatch() {
        use crate::persist::{CheckpointStore, ServerCheckpoint};
        use crate::server::AsyncStats;

        let dir = std::env::temp_dir().join(format!(
            "flowrs-server-ckpt-refuse-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = CheckpointStore::open(&dir).unwrap();

        let run_barrier_resume = |initial_dim: usize| -> Result<History> {
            let manager = Arc::new(ClientManager::new());
            let threads = spawn_fake_cohort(&manager, 1);
            let strategy = FedAvg::new(TrainingPlan { epochs: 1, lr: 0.1 }, Aggregator::Rust);
            let mut server = Server::new(
                Arc::clone(&manager),
                Box::new(strategy),
                CostModel::default(),
                ServerConfig {
                    num_rounds: 2,
                    quorum: 1,
                    resume_from: Some(dir.clone()),
                    ..Default::default()
                },
            );
            let out = server.run(Parameters::from_flat(vec![0.0; initial_dim]));
            // a refused resume still runs the shutdown sweep, so the
            // fake client gets its Reconnect and the thread exits
            for t in threads {
                t.join().unwrap();
            }
            out
        };

        // a streaming-mode checkpoint must not resume a barrier server
        let mut h = History::default();
        h.push(RoundRecord { round: 1, accuracy: 0.1, ..Default::default() });
        let async_ck = ServerCheckpoint::capture(
            true,
            None,
            &Parameters::from_flat(vec![1.0; 4]),
            &h,
            AsyncStats::default(),
            Vec::new(),
        )
        .unwrap();
        store.save(&async_ck.to_writer()).unwrap();
        let err = run_barrier_resume(4).expect_err("mode flip must be refused");
        assert!(err.to_string().contains("mode mismatch"), "{err}");

        // same mode, different parameter shape → refused too
        let sync_ck = ServerCheckpoint::capture(
            false,
            None,
            &Parameters::from_flat(vec![1.0; 8]),
            &h,
            AsyncStats::default(),
            Vec::new(),
        )
        .unwrap();
        store.save(&sync_ck.to_writer()).unwrap();
        let err = run_barrier_resume(4).expect_err("shape mismatch must be refused");
        assert!(err.to_string().contains("shape mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quorum_timeout_errors() {
        let manager = Arc::new(ClientManager::new());
        let strategy = FedAvg::new(TrainingPlan::default(), Aggregator::Rust);
        let mut server = Server::new(
            manager,
            Box::new(strategy),
            CostModel::default(),
            ServerConfig {
                quorum: 3,
                quorum_timeout: Duration::from_millis(50),
                ..Default::default()
            },
        );
        assert!(server.run(Parameters::from_flat(vec![0.0])).is_err());
    }
}
