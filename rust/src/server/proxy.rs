//! `ClientProxy`: the server-side stand-in for one connected client.
//!
//! Owns the connection; every call is a strict request/response exchange
//! with a deadline (the paper's RPC server "is responsible for monitoring
//! these connections and for sending and receiving Flower Protocol
//! messages").

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::obs;
use crate::proto::{
    BroadcastFrame, ClientMessage, EvaluateIns, EvaluateRes, FitIns, FitRes, GetParametersIns,
    GetParametersRes, ServerMessage,
};
use crate::strategy::ClientHandle;
use crate::transport::Connection;

/// Server-side handle + channel to one client.
pub struct ClientProxy {
    pub handle: ClientHandle,
    conn: Mutex<Connection>,
    /// Negotiated wire version (1 unless the client sent a `Hello`).
    wire: u8,
}

impl ClientProxy {
    pub fn new(handle: ClientHandle, conn: Connection) -> Self {
        Self::with_wire(handle, conn, crate::proto::codec::VERSION)
    }

    /// Build a proxy speaking a negotiated wire version (see
    /// `transport/PROTOCOL.md`).
    pub fn with_wire(handle: ClientHandle, conn: Connection, wire: u8) -> Self {
        ClientProxy { handle, conn: Mutex::new(conn), wire }
    }

    /// The negotiated wire version this proxy encodes with.
    pub fn wire(&self) -> u8 {
        self.wire
    }

    /// Record one request/response round trip into the live histogram
    /// (`transport_rtt_s`); with near-zero client compute this is frame
    /// RTT, which is what `flowrs loadgen` reports.
    fn record_rtt(started: Instant) {
        obs::registry()
            .histogram("transport_rtt_s")
            .record(started.elapsed().as_secs_f64());
    }

    fn exchange(&self, msg: &ServerMessage, timeout: Duration) -> Result<ClientMessage> {
        let mut conn = self
            .conn
            .lock()
            .map_err(|_| Error::Transport("proxy connection poisoned".into()))?;
        let started = Instant::now();
        conn.send_server_message_v(msg, self.wire)?;
        let res = conn.recv_client_message_timeout(timeout)?;
        Self::record_rtt(started);
        Ok(res)
    }

    /// Ask for the client's current parameters.
    pub fn get_parameters(
        &self,
        ins: GetParametersIns,
        timeout: Duration,
    ) -> Result<GetParametersRes> {
        match self.exchange(&ServerMessage::GetParametersIns(ins), timeout)? {
            ClientMessage::GetParametersRes(res) => Ok(res),
            other => Err(Error::Protocol(format!(
                "client {} answered get_parameters with {other:?}",
                self.handle.id
            ))),
        }
    }

    /// Run a round of local training on the client.
    pub fn fit(&self, ins: FitIns, timeout: Duration) -> Result<FitRes> {
        match self.exchange(&ServerMessage::FitIns(ins), timeout)? {
            ClientMessage::FitRes(res) => Ok(res),
            other => Err(Error::Protocol(format!(
                "client {} answered fit with {other:?}",
                self.handle.id
            ))),
        }
    }

    /// Run a round of local training from a pre-encoded broadcast
    /// frame: the `FitIns` encode cost is paid once per round and wire
    /// version ([`BroadcastFrame::bytes`]), not once per client.
    pub fn fit_prepared(&self, frame: &BroadcastFrame, timeout: Duration) -> Result<FitRes> {
        let bytes = frame.bytes(self.wire);
        let mut conn = self
            .conn
            .lock()
            .map_err(|_| Error::Transport("proxy connection poisoned".into()))?;
        let started = Instant::now();
        conn.send(&bytes)?;
        let res = conn.recv_client_message_timeout(timeout)?;
        Self::record_rtt(started);
        drop(conn);
        match res {
            ClientMessage::FitRes(res) => Ok(res),
            other => Err(Error::Protocol(format!(
                "client {} answered fit with {other:?}",
                self.handle.id
            ))),
        }
    }

    /// Evaluate parameters on the client's local test split.
    pub fn evaluate(&self, ins: EvaluateIns, timeout: Duration) -> Result<EvaluateRes> {
        match self.exchange(&ServerMessage::EvaluateIns(ins), timeout)? {
            ClientMessage::EvaluateRes(res) => Ok(res),
            other => Err(Error::Protocol(format!(
                "client {} answered evaluate with {other:?}",
                self.handle.id
            ))),
        }
    }

    /// Tell the client to go away (end of the experiment).
    pub fn reconnect(&self, seconds: u64) -> Result<()> {
        let mut conn = self
            .conn
            .lock()
            .map_err(|_| Error::Transport("proxy connection poisoned".into()))?;
        conn.send_server_message(&ServerMessage::Reconnect { seconds })?;
        // best-effort: the client answers Disconnect, but we don't insist
        let _ = conn.recv_client_message_timeout(Duration::from_millis(200));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles;
    use crate::proto::{Parameters, Status};
    use crate::transport::inproc;

    fn proxy_pair() -> (ClientProxy, Connection) {
        let (server_end, client_end) = inproc::pair();
        let handle = ClientHandle {
            id: "c0".into(),
            device: profiles::by_name("pixel4").unwrap(),
            num_examples: 100,
        };
        (
            ClientProxy::new(handle, Connection::InProc(server_end)),
            Connection::InProc(client_end),
        )
    }

    #[test]
    fn fit_roundtrip() {
        let (proxy, mut client) = proxy_pair();
        let t = std::thread::spawn(move || {
            let msg = client.recv_server_message().unwrap();
            assert!(matches!(msg, ServerMessage::FitIns(_)));
            client
                .send_client_message(&ClientMessage::FitRes(FitRes {
                    status: Status::ok(),
                    parameters: Parameters::from_flat(vec![1.0]),
                    num_examples: 10,
                    metrics: Default::default(),
                }))
                .unwrap();
        });
        let res = proxy
            .fit(
                FitIns {
                    parameters: Parameters::from_flat(vec![0.0]),
                    config: Default::default(),
                },
                Duration::from_secs(1),
            )
            .unwrap();
        assert_eq!(res.num_examples, 10);
        t.join().unwrap();
    }

    #[test]
    fn v2_proxy_fit_prepared_roundtrip() {
        use crate::proto::codec::VERSION_V2;
        let (server_end, client_end) = inproc::pair();
        let handle = ClientHandle {
            id: "c1".into(),
            device: profiles::by_name("pixel4").unwrap(),
            num_examples: 100,
        };
        let proxy =
            ClientProxy::with_wire(handle, Connection::InProc(server_end), VERSION_V2);
        assert_eq!(proxy.wire(), VERSION_V2);
        let mut client = Connection::InProc(client_end);
        let t = std::thread::spawn(move || {
            // the broadcast frame arrives as a v2 frame and decodes
            // transparently through the version dispatcher
            let msg = client.recv_server_message().unwrap();
            let ServerMessage::FitIns(ins) = msg else {
                panic!("expected FitIns")
            };
            assert_eq!(ins.parameters.to_flat().unwrap(), &[1.0, 2.0]);
            client
                .send_client_message_v(
                    &ClientMessage::FitRes(FitRes {
                        status: Status::ok(),
                        parameters: Parameters::from_flat(vec![3.0]),
                        num_examples: 5,
                        metrics: Default::default(),
                    }),
                    VERSION_V2,
                )
                .unwrap();
        });
        let frame = BroadcastFrame::new(ServerMessage::FitIns(FitIns {
            parameters: Parameters::from_flat(vec![1.0, 2.0]),
            config: Default::default(),
        }));
        let res = proxy.fit_prepared(&frame, Duration::from_secs(1)).unwrap();
        assert_eq!(res.parameters.to_flat().unwrap(), &[3.0]);
        t.join().unwrap();
    }

    #[test]
    fn wrong_answer_is_protocol_error() {
        let (proxy, mut client) = proxy_pair();
        let t = std::thread::spawn(move || {
            let _ = client.recv_server_message().unwrap();
            client
                .send_client_message(&ClientMessage::Disconnect { reason: "bye".into() })
                .unwrap();
        });
        let err = proxy
            .fit(
                FitIns {
                    parameters: Parameters::from_flat(vec![0.0]),
                    config: Default::default(),
                },
                Duration::from_secs(1),
            )
            .unwrap_err();
        assert!(matches!(err, Error::Protocol(_)));
        t.join().unwrap();
    }

    #[test]
    fn timeout_surfaces() {
        let (proxy, _client) = proxy_pair();
        let err = proxy
            .fit(
                FitIns {
                    parameters: Parameters::from_flat(vec![0.0]),
                    config: Default::default(),
                },
                Duration::from_millis(30),
            )
            .unwrap_err();
        assert!(matches!(err, Error::Timeout(_)));
    }
}
